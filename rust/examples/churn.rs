//! Dynamic membership (paper §3.2/§3.3b): clients join and leave while
//! training runs.  Shows the pie-cutter allocation reacting to churn, the
//! no-data-loss invariant, and training continuing through fleet changes.
//!
//!     cargo run --release --example churn

use mlitb::client::DeviceClass;
use mlitb::runtime::Engine;
use mlitb::sim::{ChurnEvent, SimConfig, Simulation};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut engine = Engine::from_default_artifacts()?;
    engine.load_model("mnist_mlp")?;
    let spec = engine.spec("mnist_mlp")?.clone();

    let mut cfg = SimConfig::paper_scaling(2, &spec);
    cfg.train_size = 2_000;
    cfg.test_size = 320;
    cfg.iterations = 24;
    cfg.master.capacity = 600;
    cfg.master.learning_rate = 0.03;
    cfg.power_scale = 0.15;
    cfg.seed = 3;
    // Scripted churn: phones join at 4 and 8, a workstation dies at 12,
    // two more devices join at 16.
    cfg.churn.insert(4, vec![ChurnEvent::Join(DeviceClass::Mobile)]);
    cfg.churn.insert(8, vec![ChurnEvent::Join(DeviceClass::Mobile)]);
    cfg.churn.insert(12, vec![ChurnEvent::Leave(1)]);
    cfg.churn.insert(
        16,
        vec![
            ChurnEvent::Join(DeviceClass::Laptop),
            ChurnEvent::Join(DeviceClass::Workstation),
        ],
    );

    let mut sim = Simulation::new(cfg, spec, &mut engine);
    println!("starting fleet: {} clients", sim.n_clients());
    println!("\niter  clients  loss     vectors  transfers  unallocated");
    let mut last_transfers = 0u64;
    for i in 0..24u64 {
        sim.step()?;
        let alloc = sim.master().allocator();
        alloc.check_invariants().expect("allocation invariant");
        let rec = sim.master().timeline().last().unwrap().clone();
        let transfers = alloc.transfer_count();
        if i % 2 == 0 || [4, 8, 12, 16].contains(&i) {
            println!(
                "{:>4}  {:>7}  {:>7}  {:>7}  {:>9}  {:>11}",
                i,
                sim.n_clients(),
                rec.loss.map_or("-".into(), |l| format!("{l:.4}")),
                rec.vectors,
                transfers - last_transfers,
                alloc.unallocated().len(),
            );
        }
        last_transfers = transfers;
    }
    let report_workers = sim.n_clients();
    println!(
        "\nfinal fleet: {report_workers} clients; allocation invariants held through all churn"
    );
    Ok(())
}
