//! End-to-end driver (the repo's validation workload, see EXPERIMENTS.md):
//! distributed synchronized-SGD training of the paper's convolutional NN
//! on the 60k-vector synthetic-MNIST corpus with a heterogeneous simulated
//! fleet — workstations, laptops, and phones on different link classes —
//! for a few hundred iterations, with real PJRT gradient computation and
//! the loss/test-error curve logged.
//!
//!     cargo run --release --example mnist_scaling -- \
//!         --nodes 8 --iters 200 --track-every 20 --csv /tmp/run.csv
//!
//! Flags: --model, --nodes, --iters, --t-secs, --lr, --capacity,
//!        --train-size, --test-size, --power-scale, --mix, --csv, --seed.

use mlitb::cli::Args;
use mlitb::client::DeviceClass;
use mlitb::runtime::Engine;
use mlitb::sim::{SimConfig, Simulation};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let model = args.get_or("model", "mnist_conv").to_string();
    let nodes = args.get_usize("nodes", 8)?;
    let iters = args.get_u64("iters", 200)?;

    let mut engine = Engine::from_default_artifacts()?;
    engine.load_model(&model)?;
    let spec = engine.spec(&model)?.clone();

    let mut cfg = SimConfig::paper_scaling(nodes, &spec);
    cfg.iterations = iters;
    cfg.train_size = args.get_usize("train-size", 60_000)?;
    cfg.test_size = args.get_usize("test-size", 2_000)?;
    cfg.track_every = args.get_u64("track-every", 20)?;
    cfg.master.learning_rate = args.get_f64("lr", 0.03)? as f32;
    cfg.master.iter_duration_s = args.get_f64("t-secs", 4.0)?;
    cfg.master.capacity = args.get_usize("capacity", 3000)?;
    cfg.power_scale = args.get_f64("power-scale", 0.1)?;
    cfg.seed = args.get_u64("seed", 1)?;

    // Heterogeneous fleet (the paper's Fig 1 scenario): default mix is
    // half workstations, a quarter laptops, a quarter mobiles.
    if args.get_or("mix", "hetero") == "hetero" {
        cfg.fleet = (0..nodes)
            .map(|i| match i % 4 {
                0 | 1 => DeviceClass::Workstation,
                2 => DeviceClass::Laptop,
                _ => DeviceClass::Mobile,
            })
            .collect();
    }

    println!(
        "E2E driver: {model} ({} params) | {} clients | {} iterations | T={}s | lr={}",
        spec.param_count,
        nodes,
        iters,
        cfg.master.iter_duration_s,
        cfg.master.learning_rate,
    );
    let mut sim = Simulation::new(cfg, spec, &mut engine);
    println!(
        "corpus coverage at start: {:.1}% ({} clients)",
        sim.coverage() * 100.0,
        sim.n_clients()
    );

    let t0 = std::time::Instant::now();
    let report = sim.run()?;
    let wall = t0.elapsed().as_secs_f64();
    drop(sim); // release the engine borrow for the stats below

    println!("\niter    loss    test_err  vectors  latency_ms");
    for r in report.timeline.records() {
        if r.iteration % 10 == 0 || r.test_error.is_some() {
            println!(
                "{:>5}  {:>7}  {:>8}  {:>7}  {:>8.1}",
                r.iteration,
                r.loss.map_or("-".into(), |l| format!("{l:.4}")),
                r.test_error.map_or("-".into(), |e| format!("{e:.4}")),
                r.vectors,
                r.mean_latency_ms
            );
        }
    }
    println!("\n{}", report.summary());
    println!(
        "real wall {wall:.1}s for {:.0}s virtual ({:.1}x), {} PJRT executions",
        report.virtual_secs,
        report.virtual_secs / wall,
        engine.executions()
    );

    if let Some(path) = args.get("csv") {
        std::fs::write(path, report.timeline.to_csv())?;
        println!("timeline written to {path}");
    }
    Ok(())
}
