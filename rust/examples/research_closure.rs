//! Research closures + tracking mode (paper §2.3, §3.6, Figs 6–8):
//! train briefly, archive the model as a JSON research closure, reload it,
//! verify bit-exact parameters, resume training, and run the tracking-mode
//! prediction table of Fig 7 (class-probability ranking for one image).
//!
//!     cargo run --release --example research_closure

use mlitb::model::ResearchClosure;
use mlitb::runtime::Engine;
use mlitb::sim::{SimConfig, Simulation};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let model = "cifar_conv";
    let mut engine = Engine::from_default_artifacts()?;
    engine.load_model(model)?;
    let spec = engine.spec(model)?.clone();

    // ---- phase 1: short training run (the "researcher" of Fig 1)
    let mut cfg = SimConfig::paper_scaling(3, &spec);
    cfg.train_size = 3_000;
    cfg.test_size = 320;
    cfg.iterations = 30;
    cfg.master.capacity = 1000;
    cfg.master.learning_rate = 0.05;
    cfg.power_scale = 0.15;
    cfg.seed = 11;
    let (params, iteration, first_loss, last_loss) = {
        let mut sim = Simulation::new(cfg.clone(), spec.clone(), &mut engine);
        let report = sim.run()?;
        let first = report.timeline.records()[0].loss.unwrap();
        let last = report.timeline.records().iter().rev().find_map(|r| r.loss).unwrap();
        (sim.master().params().to_vec(), sim.master().iteration(), first, last)
    };
    println!("phase 1: trained {iteration} iterations, loss {first_loss:.3} -> {last_loss:.3}");

    // ---- phase 2: archive to a JSON research closure
    let mut closure = ResearchClosure::new(&spec, &params);
    closure.iteration = iteration;
    closure.learning_rate = cfg.master.learning_rate;
    closure.iter_duration_s = cfg.master.iter_duration_s;
    closure.notes = "research_closure example, synthetic-CIFAR".into();
    let path = std::env::temp_dir().join("mlitb_cifar_closure.json");
    closure.save(&path)?;
    let size = std::fs::metadata(&path)?.len();
    println!(
        "phase 2: archived to {} ({:.1} KB JSON, universally readable)",
        path.display(),
        size as f64 / 1024.0
    );

    // ---- phase 3: reload, verify, resume ("another researcher")
    let loaded = ResearchClosure::load(&path)?;
    loaded.check_compatible(&spec)?;
    assert_eq!(loaded.params, params, "closure round trip must be bit-exact");
    println!(
        "phase 3: reloaded closure — model '{}', {} params, iteration {}, bit-exact ✓",
        loaded.model_name, loaded.param_count, loaded.iteration
    );
    let mut cfg2 = cfg.clone();
    cfg2.iterations = 5;
    cfg2.master.learning_rate = 0.01; // resume with a cooler step size
    let resumed_last = {
        let mut sim = Simulation::new(cfg2, spec.clone(), &mut engine);
        sim.load_params(loaded.params.clone());
        let report = sim.run()?;
        report.timeline.records().iter().rev().find_map(|r| r.loss).unwrap()
    };
    println!("         resumed 5 more iterations, loss {last_loss:.3} -> {resumed_last:.3}");

    // ---- phase 4: tracking mode, Fig 7 — classify one image and print
    //      the ranked class-probability table.
    let synth = mlitb::data::Synthesizer::new(mlitb::data::SynthSpec::cifar(11 ^ 0xDA7A));
    let true_label = 7u8;
    let sample = synth.sample(true_label, 123_456);
    let mut batch = mlitb::runtime::BatchBuilder::new(spec.batch_size, spec.input_len());
    batch.fill_cyclic(&[std::sync::Arc::new(sample)], 0);
    let probs = engine.predict(model, &loaded.params, batch.images())?;
    let row = &probs[..spec.classes];
    let mut ranked: Vec<(usize, f32)> = row.iter().copied().enumerate().collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("\nphase 4: tracking mode — Fig 7 table (true class: {true_label})");
    println!("  Index  Label     Probability");
    for (idx, p) in ranked.iter().take(4) {
        println!("  {:>5}  class_{:<3} {:.6}", idx, idx, p);
    }
    std::fs::remove_file(&path).ok();
    Ok(())
}
