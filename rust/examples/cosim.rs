//! Co-simulation walkthrough: two live training masters (two hosted
//! projects, §3.1) publishing byte-accounted snapshots into one shared
//! sharded serving tier mid-traffic, on one virtual clock.
//!
//!     cargo run --release --example cosim
//!
//! Runs without AOT artifacts: training uses the drifting modeled
//! backend (parameters actually move, so staleness is measurable),
//! serving the deterministic modeled predictor.

use mlitb::cosim::{run_cosim, CosimConfig, CosimProject, PublicationPolicy};
use mlitb::netsim::LinkProfile;
use mlitb::runtime::{Compute, DriftingCompute, ModeledCompute};
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, FleetConfig, ProjectId, RouterConfig, RoutingPolicy,
    ServeConfig, ServerProfile,
};
use mlitb::sim::SimConfig;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let spec = demo_spec();
    let iters = 12u64;
    let project = |seed: u64, publish_every: u64| {
        let mut train = SimConfig::paper_scaling(3, &spec);
        train.iterations = iters;
        train.train_size = 1_000;
        train.test_size = 256;
        train.track_every = 3;
        train.master.iter_duration_s = 2.0;
        train.seed = seed;
        CosimProject {
            spec: spec.clone(),
            train,
            publish: PublicationPolicy {
                every: publish_every,
                min_improvement: 0.0,
                hysteresis: 0,
            },
            retain: 2,
            weight: 1.0,
        }
    };
    let fleet = |rate_rps: f64, seed: u64| FleetConfig {
        groups: vec![ClientSpec {
            link: LinkProfile::Wifi,
            rate_rps,
            count: 6,
        }],
        duration_s: iters as f64 * 2.0,
        input_pool: 64,
        seed,
    };

    let cfg = CosimConfig {
        // Project 0 publishes fast, project 1 slowly — two freshness
        // policies behind one tier.
        projects: vec![project(1, 3), project(2, 6)],
        serve: ServeConfig {
            fleets: vec![fleet(10.0, 9), fleet(6.0, 10)],
            policy: BatchPolicy::default(),
            server: ServerProfile::default(),
            router: RouterConfig {
                shards: 2,
                policy: RoutingPolicy::JoinShortestQueue,
                coalesce: true,
                ..RouterConfig::single()
            },
            shard_profiles: Vec::new(),
            drained_shards: Vec::new(),
            cache_capacity: 512,
            response_bytes: 256,
            keep_log: false,
        },
        // ~51 KB per snapshot at 2 MB/min: transfers take ~1.5 s of the
        // 2 s iteration window — activation visibly trails publication.
        egress_bytes_per_min: 2.0e6,
        measure_delta: true,
    };

    let mut train_a = DriftingCompute { param_count: spec.param_count };
    let mut train_b = DriftingCompute { param_count: spec.param_count };
    let mut serve_compute = ModeledCompute { param_count: spec.param_count };
    let report = run_cosim(
        &cfg,
        vec![
            &mut train_a as &mut dyn Compute,
            &mut train_b as &mut dyn Compute,
        ],
        &mut serve_compute,
    )?;

    println!("one shared clock, two projects, two pillars:");
    for (i, train) in report.train.iter().enumerate() {
        println!("  train p{i}: {}", train.summary());
    }
    println!("  serve: {}", report.serve.summary());
    println!("\npublications (byte-accounted, hot-swapped mid-traffic):");
    for p in &report.publications {
        println!(
            "  {} at iteration {} (t={:.1}s, {}, {} KB) → active t={:.1}s iter {}{}",
            p.version,
            p.iteration,
            p.t_ms / 1000.0,
            p.trigger.name(),
            p.bytes / 1000,
            p.activated_ms / 1000.0,
            p.activated_iteration,
            if p.evicted.is_empty() {
                String::new()
            } else {
                format!(
                    " — GC reclaimed {}",
                    p.evicted
                        .iter()
                        .map(ToString::to_string)
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            }
        );
    }
    println!(
        "\negress: {:.0} KB of snapshots crossed the master link",
        report.egress_bytes as f64 / 1000.0
    );
    println!("\ntraffic by version (every answer names its project's snapshot):");
    for (version, n) in report.staleness.by_version() {
        println!("  {version}: {n} requests");
    }
    for i in 0..2u32 {
        let project = ProjectId::new(i);
        let stale = report.staleness.for_project(project);
        let ages = stale.age_iters_summary();
        println!(
            "{project} staleness: p50 {:.1} / p99 {:.1} iterations behind its master \
             (mean delta {:.4}, class flips {:.3}) over {} answers",
            ages.median(),
            ages.quantile(0.99),
            stale.delta_summary().mean(),
            stale.stale_class_rate(),
            stale.len(),
        );
    }
    println!("done: {}", report.summary());
    Ok(())
}
