//! Co-simulation walkthrough: a live training master publishing
//! snapshots into a sharded serving tier mid-traffic, on one shared
//! virtual clock.
//!
//!     cargo run --release --example cosim
//!
//! Runs without AOT artifacts: training uses the drifting modeled
//! backend (parameters actually move, so staleness is measurable),
//! serving the deterministic modeled predictor.

use mlitb::cosim::{run_cosim, CosimConfig, PublicationPolicy};
use mlitb::netsim::LinkProfile;
use mlitb::runtime::{DriftingCompute, ModeledCompute};
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, FleetConfig, RouterConfig, RoutingPolicy, ServeConfig,
    ServerProfile,
};
use mlitb::sim::SimConfig;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let spec = demo_spec();
    let mut train = SimConfig::paper_scaling(3, &spec);
    train.iterations = 12;
    train.train_size = 1_000;
    train.test_size = 256;
    train.track_every = 3;
    train.master.iter_duration_s = 2.0;

    let cfg = CosimConfig {
        serve: ServeConfig {
            fleet: FleetConfig {
                groups: vec![ClientSpec {
                    link: LinkProfile::Wifi,
                    rate_rps: 10.0,
                    count: 6,
                }],
                duration_s: train.iterations as f64 * train.master.iter_duration_s,
                input_pool: 64,
                seed: 9,
            },
            policy: BatchPolicy::default(),
            server: ServerProfile::default(),
            router: RouterConfig {
                shards: 2,
                policy: RoutingPolicy::JoinShortestQueue,
                coalesce: true,
                autotune: false,
                window_ms: 1_000.0,
            },
            shard_profiles: Vec::new(),
            drained_shards: Vec::new(),
            cache_capacity: 512,
            response_bytes: 256,
        },
        train,
        publish: PublicationPolicy {
            every: 3,
            min_improvement: 0.0,
        },
        retain: 2,
        measure_delta: true,
    };

    let mut train_compute = DriftingCompute { param_count: spec.param_count };
    let mut serve_compute = ModeledCompute { param_count: spec.param_count };
    let report = run_cosim(&cfg, &spec, &mut train_compute, &mut serve_compute)?;

    println!("one shared clock, two pillars:");
    println!("  train: {}", report.train.summary());
    println!("  serve: {}", report.serve.summary());
    println!("\npublications (hot-swapped mid-traffic):");
    for p in &report.publications {
        println!(
            "  v{} at iteration {} (t={:.1}s, {}){}",
            p.snapshot,
            p.iteration,
            p.t_ms / 1000.0,
            p.trigger.name(),
            if p.evicted.is_empty() {
                String::new()
            } else {
                format!(
                    " — GC reclaimed {}",
                    p.evicted
                        .iter()
                        .map(|v| format!("v{v}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            }
        );
    }
    println!("\ntraffic by version (every answer names its snapshot):");
    for (version, n) in report.staleness.by_snapshot() {
        println!("  v{version}: {n} requests");
    }
    let ages = report.staleness.age_iters_summary();
    println!(
        "\nstaleness: p50 {:.1} / p99 {:.1} iterations behind the live master \
         (mean prediction delta {:.4}, class flips {:.3})",
        ages.median(),
        ages.quantile(0.99),
        report.staleness.delta_summary().mean(),
        report.staleness.stale_class_rate(),
    );
    println!("done: {}", report.summary());
    Ok(())
}
