//! Serving walkthrough: publish a model snapshot, put it behind the
//! micro-batching endpoint, and drive it with a heterogeneous request
//! fleet.
//!
//!     cargo run --release --example serving
//!
//! Runs without AOT artifacts: the built-in demo spec + the deterministic
//! modeled predictor stand in for the PJRT engine (swap in
//! `Engine::from_default_artifacts()` + `--features pjrt` for real
//! predictions; every call below is `Compute`-generic).

use mlitb::model::{init_params, ResearchClosure};
use mlitb::netsim::LinkProfile;
use mlitb::runtime::{Compute, ModeledCompute};
use mlitb::serve::{
    demo_spec, BatchExecutor, BatchPolicy, ClientSpec, ControlPlane, FleetConfig, ProjectId,
    RouterConfig, RoutingPolicy, ServeConfig, ServeSim, ServerProfile,
};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. A trained model arrives as a research closure — the paper's
    //    universally readable model object (here: fresh init params).
    let spec = demo_spec();
    let mut closure = ResearchClosure::new(&spec, &init_params(&spec, 42));
    closure.iteration = 1_000;
    closure.notes = "demo: pretend this finished training".into();

    // 2. The control plane hosts the project; its registry versions the
    //    closure and makes it servable under a typed ModelVersion.
    let mut plane = ControlPlane::single(spec.clone());
    let project = ProjectId::new(0);
    let v1 = plane.registry_mut(project).publish_closure(&closure, 0.0)?;
    println!(
        "published {} snapshot {v1} ({} params, iteration {})",
        spec.name, spec.param_count, closure.iteration
    );

    // 3. Micro-batching must never change an answer: run one request
    //    through a full batch and alone, compare.
    let mut compute = ModeledCompute { param_count: spec.param_count };
    let mut executor = BatchExecutor::new(spec.clone(), ServerProfile::default());
    let snapshot = plane.active(project).unwrap().clone();
    let inputs: Vec<Vec<f32>> = (0..8)
        .map(|i| (0..spec.input_len()).map(|j| ((i * 97 + j) % 255) as f32 / 255.0).collect())
        .collect();
    let refs: Vec<&[f32]> = inputs.iter().map(Vec::as_slice).collect();
    let (batched, batched_ms) = executor.execute(&mut compute, &snapshot.params, &refs)?;
    let (alone, alone_ms) = executor.execute(&mut compute, &snapshot.params, &refs[..1])?;
    assert_eq!(batched[0], alone[0], "batching changed a prediction");
    println!(
        "batch of 8 served in {batched_ms:.2} ms ({:.2} ms/req) vs {alone_ms:.2} ms alone — same answer (class {})",
        batched_ms / 8.0,
        alone[0].class
    );

    // 4. Simulated production: 12 clients across LAN/wifi/cellular firing
    //    open-loop requests for 10 virtual seconds.
    let cfg = ServeConfig {
        fleets: vec![FleetConfig {
            groups: vec![
                ClientSpec { link: LinkProfile::Lan, rate_rps: 12.0, count: 4 },
                ClientSpec { link: LinkProfile::Wifi, rate_rps: 8.0, count: 4 },
                ClientSpec { link: LinkProfile::Cellular, rate_rps: 4.0, count: 4 },
            ],
            duration_s: 10.0,
            input_pool: 64, // small pool → repeated inputs → cache hits
            seed: 7,
        }],
        policy: BatchPolicy { max_batch: 32, max_wait_ms: 5.0, queue_depth: 128 },
        server: ServerProfile::default(),
        router: RouterConfig::single(),
        shard_profiles: Vec::new(),
        drained_shards: Vec::new(),
        cache_capacity: 512,
        response_bytes: 256,
        keep_log: false,
    };
    let mut sim = ServeSim::new(cfg.clone(), plane.clone(), &mut compute as &mut dyn Compute);
    let report = sim.run()?;
    println!("\nserve-sim (single endpoint): {}", report.summary());
    let lat = report.latency();
    println!(
        "latency p50/p95/p99: {:.1} / {:.1} / {:.1} ms over {} completed requests",
        lat.median(),
        lat.p95(),
        lat.quantile(0.99),
        report.completed
    );
    println!(
        "cache absorbed {:.0}% of traffic; batches averaged {:.1} requests",
        report.hit_rate() * 100.0,
        report.mean_batch()
    );

    // 5. The same fleet against a routed tier: 3 shards behind
    //    join-shortest-queue, duplicate in-flight inputs coalesced, and
    //    each shard's batching deadline autotuned to its arrival rate.
    let mut routed_cfg = cfg;
    routed_cfg.router = RouterConfig {
        shards: 3,
        policy: RoutingPolicy::JoinShortestQueue,
        coalesce: true,
        autotune: true,
        ..RouterConfig::single()
    };
    let mut routed_sim = ServeSim::new(routed_cfg, plane, &mut compute as &mut dyn Compute);
    let routed = routed_sim.run()?;
    println!("\nserve-sim (routed fleet): {}", routed.summary());
    for s in &routed.per_shard {
        println!(
            "  shard {}: routed {}, completed {}, coalesced {}, mean batch {:.1}, wait {:.2} ms",
            s.shard,
            s.routed,
            s.completed(),
            s.coalesced,
            s.mean_batch(),
            s.max_wait_ms
        );
    }
    println!(
        "coalescing answered {} duplicates without executing them; answers are\n\
         identical to the single-endpoint run (routing is answer-preserving).",
        routed.coalesced
    );
    Ok(())
}
