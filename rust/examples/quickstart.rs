//! Quickstart: train the paper's convnet on a small synthetic-MNIST corpus
//! with a handful of simulated browser clients, then evaluate.
//!
//!     cargo run --release --example quickstart
//!
//! This is the 60-second tour of the public API: load the AOT artifacts
//! (`make artifacts` first), build a [`Simulation`] around the paper's
//! master event loop, run it, and read the timeline.

use mlitb::runtime::Engine;
use mlitb::sim::{SimConfig, Simulation};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. PJRT engine over the AOT artifacts (HLO text compiled once).
    let mut engine = Engine::from_default_artifacts()?;
    engine.load_model("mnist_conv")?;
    let spec = engine.spec("mnist_conv")?.clone();
    println!(
        "model {}: {} params, batch {}",
        spec.name, spec.param_count, spec.batch_size
    );

    // 2. The paper's §3.5 setup, scaled down for a quick demo:
    //    4 LAN workstations, T = 4 s iterations, AdaGrad reduce.
    let mut cfg = SimConfig::paper_scaling(4, &spec);
    cfg.train_size = 4_000;
    cfg.test_size = 640;
    cfg.iterations = 25;
    cfg.track_every = 5; // tracker worker evaluates every 5 iterations
    cfg.master.capacity = 500; // data-vector cap per client
    cfg.master.learning_rate = 0.05;
    cfg.power_scale = 0.25; // slow the virtual devices for demo runtime

    // 3. Run the master event loop.
    let mut sim = Simulation::new(cfg, spec, &mut engine);
    println!(
        "training on {} clients, coverage {:.0}% of the corpus",
        sim.n_clients(),
        sim.coverage() * 100.0
    );
    let report = sim.run()?;

    // 4. Inspect the timeline (what Fig 5/8 are drawn from).
    for r in report.timeline.records() {
        if let Some(err) = r.test_error {
            println!(
                "iter {:>3}: loss {:.4}  test error {:.1}%  ({} vectors, {:.0} ms latency)",
                r.iteration,
                r.loss.unwrap_or(f64::NAN),
                err * 100.0,
                r.vectors,
                r.mean_latency_ms
            );
        }
    }
    println!("summary: {}", report.summary());
    Ok(())
}
