//! Integration: PJRT runtime over the real AOT artifacts.
//!
//! Requires `make artifacts` (the Makefile's `test` target guarantees it).
//! These tests verify the L1/L2 → HLO-text → L3 bridge end to end:
//! numerics (gradient descent direction, eval/predict consistency) and
//! the manifest contract.
//!
//! Needs the compiled AOT artifacts, so the whole file is gated on the
//! `pjrt` feature: `cargo test --features pjrt` after `make artifacts`.
#![cfg(feature = "pjrt")]

use mlitb::model::{init_params, Manifest};
use mlitb::runtime::{BatchBuilder, Engine};

fn engine_with(model: &str) -> Engine {
    let manifest = Manifest::load_default().expect("artifacts present (run `make artifacts`)");
    let mut engine = Engine::new(manifest).expect("PJRT cpu client");
    engine.load_model(model).expect("compile artifacts");
    engine
}

fn toy_batch(spec: &mlitb::model::ModelSpec, seed: u64) -> (Vec<f32>, Vec<i32>) {
    use mlitb::rng::Pcg32;
    let mut rng = Pcg32::new(seed);
    let n = spec.batch_size * spec.input_len();
    let images: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
    let labels: Vec<i32> = (0..spec.batch_size)
        .map(|_| rng.gen_range_usize(spec.classes) as i32)
        .collect();
    (images, labels)
}

#[test]
fn grad_output_shapes_and_finiteness() {
    let mut engine = engine_with("mnist_conv");
    let spec = engine.spec("mnist_conv").unwrap().clone();
    let params = init_params(&spec, 0);
    let (images, labels) = toy_batch(&spec, 1);
    let out = engine.grad("mnist_conv", &params, &images, &labels).unwrap();
    assert_eq!(out.grads.len(), spec.param_count);
    assert!(out.grads.iter().all(|g| g.is_finite()));
    assert!(out.loss_sum.is_finite() && out.loss_sum > 0.0);
    assert!((0.0..=spec.batch_size as f32).contains(&out.correct));
    // loss near batch * ln(10) at init
    let per_ex = out.loss_sum / spec.batch_size as f32;
    assert!((per_ex - 2.302).abs() < 0.7, "per-example loss {per_ex}");
}

#[test]
fn gradient_points_downhill() {
    // A small step against the gradient must reduce the loss — validates
    // sign conventions across the whole AOT bridge.
    let mut engine = engine_with("mnist_mlp");
    let spec = engine.spec("mnist_mlp").unwrap().clone();
    let mut params = init_params(&spec, 3);
    let (images, labels) = toy_batch(&spec, 2);
    let out0 = engine.grad("mnist_mlp", &params, &images, &labels).unwrap();
    for (p, g) in params.iter_mut().zip(out0.grads.iter()) {
        *p -= 0.01 * g / spec.batch_size as f32;
    }
    let out1 = engine.eval("mnist_mlp", &params, &images, &labels).unwrap();
    assert!(
        out1.loss_sum < out0.loss_sum,
        "loss went up: {} -> {}",
        out0.loss_sum,
        out1.loss_sum
    );
}

#[test]
fn eval_matches_grad_loss() {
    // eval and grad lower the same loss graph; on identical inputs the
    // loss sums must agree to f32 tolerance.
    let mut engine = engine_with("mnist_mlp");
    let spec = engine.spec("mnist_mlp").unwrap().clone();
    let params = init_params(&spec, 5);
    let (images, labels) = toy_batch(&spec, 7);
    let g = engine.grad("mnist_mlp", &params, &images, &labels).unwrap();
    let e = engine.eval("mnist_mlp", &params, &images, &labels).unwrap();
    assert!(
        (g.loss_sum - e.loss_sum).abs() < 1e-2 * g.loss_sum.abs().max(1.0),
        "grad loss {} vs eval loss {}",
        g.loss_sum,
        e.loss_sum
    );
    assert_eq!(g.correct, e.correct);
}

#[test]
fn predict_rows_are_distributions() {
    let mut engine = engine_with("mnist_conv");
    let spec = engine.spec("mnist_conv").unwrap().clone();
    let params = init_params(&spec, 1);
    let (images, _) = toy_batch(&spec, 3);
    let probs = engine.predict("mnist_conv", &params, &images).unwrap();
    assert_eq!(probs.len(), spec.batch_size * spec.classes);
    for row in probs.chunks(spec.classes) {
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "row sum {sum}");
        assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let mut engine = engine_with("mnist_mlp");
    let spec = engine.spec("mnist_mlp").unwrap().clone();
    let params = init_params(&spec, 0);
    let (images, labels) = toy_batch(&spec, 1);
    // wrong param len
    assert!(engine
        .grad("mnist_mlp", &params[1..], &images, &labels)
        .is_err());
    // wrong image len
    assert!(engine
        .grad("mnist_mlp", &params, &images[1..], &labels)
        .is_err());
    // label out of range
    let mut bad = labels.clone();
    bad[0] = 99;
    assert!(engine.grad("mnist_mlp", &params, &images, &bad).is_err());
    // unknown model
    assert!(engine.grad("nope", &params, &images, &labels).is_err());
}

#[test]
fn batch_builder_matches_engine_contract() {
    let mut engine = engine_with("mnist_conv");
    let spec = engine.spec("mnist_conv").unwrap().clone();
    let params = init_params(&spec, 0);
    let mut batch = BatchBuilder::new(spec.batch_size, spec.input_len());
    let synth = mlitb::data::Synthesizer::new(mlitb::data::SynthSpec::mnist(4));
    let samples: Vec<_> = synth
        .corpus(10)
        .into_iter()
        .map(std::sync::Arc::new)
        .collect();
    batch.fill_cyclic(&samples, 0);
    let out = engine
        .grad("mnist_conv", &params, batch.images(), batch.labels())
        .unwrap();
    assert!(out.loss_sum.is_finite());
}

#[test]
fn all_manifest_models_compile_and_run() {
    let manifest = Manifest::load_default().unwrap();
    let names: Vec<String> = manifest.models.keys().cloned().collect();
    let mut engine = Engine::new(manifest).unwrap();
    for name in names {
        engine.load_model(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
        let spec = engine.spec(&name).unwrap().clone();
        let params = init_params(&spec, 0);
        let (images, labels) = toy_batch(&spec, 9);
        let out = engine.grad(&name, &params, &images, &labels).unwrap();
        assert_eq!(out.grads.len(), spec.param_count, "{name}");
    }
}
