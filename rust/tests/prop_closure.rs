//! Property: research closures survive a JSON round-trip exactly —
//! `from_json` ∘ `parse` ∘ `write` ∘ `to_json` is the identity over
//! randomized closures (the paper's §2.3 reproducibility object must not
//! drift through save/load).  Uses the in-repo seeded property harness
//! and PRNG; replay failures with `MLITB_PROP_SEED=<seed>`.

use mlitb::json;
use mlitb::model::{ModelSpec, ResearchClosure, TensorSpec};
use mlitb::rng::Pcg32;
use mlitb::testing::{check, gen};

/// Random model spec whose param_count matches a single tensor.
fn random_spec(rng: &mut Pcg32) -> ModelSpec {
    let param_count = gen::usize_in(rng, 0, 64);
    ModelSpec {
        name: format!("model_{}", gen::usize_in(rng, 0, 9)),
        param_count,
        batch_size: 4,
        micro_batches: vec![4, 1],
        input: vec![2, 2, 1],
        classes: 10,
        tensors: vec![TensorSpec {
            name: "w".into(),
            shape: vec![param_count],
            offset: 0,
            size: param_count,
            fan_in: 2,
        }],
        artifacts: Default::default(),
    }
}

/// Random provenance notes exercising the string escaper: quotes,
/// backslashes, newlines, control chars, non-ASCII.
fn random_notes(rng: &mut Pcg32) -> String {
    const POOL: &[char] = &[
        'a', 'Z', '9', ' ', '"', '\\', '\n', '\r', '\t', '\u{1}', 'é', '→', '{', '}',
    ];
    (0..gen::usize_in(rng, 0, 24))
        .map(|_| POOL[rng.gen_range_usize(POOL.len())])
        .collect()
}

fn random_closure(rng: &mut Pcg32) -> ResearchClosure {
    let spec = random_spec(rng);
    // f32 params in [-1, 1]; scale some to extreme-but-finite magnitudes
    // so shortest-round-trip float printing is actually exercised.
    let mut params = gen::f32_vec(rng, spec.param_count);
    for p in params.iter_mut() {
        if rng.gen_bool(0.2) {
            *p *= 1.0e30;
        } else if rng.gen_bool(0.2) {
            *p *= 1.0e-30;
        }
    }
    let mut c = ResearchClosure::new(&spec, &params);
    c.optimizer = ["sgd", "momentum", "adagrad", "rmsprop"][rng.gen_range_usize(4)].into();
    c.learning_rate = rng.gen_f32() * 0.5;
    c.iteration = rng.next_u32() as u64;
    c.iter_duration_s = rng.gen_f64() * 30.0;
    c.notes = random_notes(rng);
    c
}

#[test]
fn prop_closure_compact_json_roundtrip_is_identity() {
    check("closure-compact-roundtrip", |rng| {
        let c = random_closure(rng);
        let text = json::to_string(&c.to_json());
        let value = json::parse(&text).map_err(|e| format!("parse: {e:?}"))?;
        let back = ResearchClosure::from_json(&value)?;
        if back != c {
            return Err(format!("closure drifted through JSON:\n{c:?}\nvs\n{back:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_closure_pretty_json_roundtrip_is_identity() {
    check("closure-pretty-roundtrip", |rng| {
        let c = random_closure(rng);
        let text = json::to_string_pretty(&c.to_json());
        let value = json::parse(&text).map_err(|e| format!("parse: {e:?}"))?;
        let back = ResearchClosure::from_json(&value)?;
        if back != c {
            return Err("pretty-printed closure drifted through JSON".into());
        }
        Ok(())
    });
}

#[test]
fn prop_closure_value_tree_roundtrips_before_typing() {
    // The weaker layer-by-layer property: the serializer/parser pair is
    // the identity on the closure's raw value tree (catches float/string
    // formatting bugs independently of `from_json` validation).
    check("closure-value-roundtrip", |rng| {
        let v = random_closure(rng).to_json();
        let back = json::parse(&json::to_string(&v)).map_err(|e| format!("{e:?}"))?;
        if back != v {
            return Err("value tree changed through write+parse".into());
        }
        Ok(())
    });
}
