//! Integration: the durable snapshot registry — a multi-project
//! `ControlPlane` persists its registries as segment files + manifests,
//! a fresh plane restarts warm (active pointer, staged versions and the
//! rollback target all survive), restored registries stay compactable
//! (`gc` deletes the retired versions' segment files with no orphans),
//! and a manifest pointing at a deleted segment surfaces as corruption
//! instead of silently serving a cold registry.

use std::path::PathBuf;

use mlitb::model::init_params;
use mlitb::serve::{demo_spec, ControlPlane, ProjectId};
use mlitb::storage::registry_store;

fn temp_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "mlitb-registry-persist-{}-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Two projects behind one plane; project 0 carries the interesting
/// lifecycle: three published versions, a staged fourth, and a rollback
/// onto v2 (so v3 is retired, neither active nor staged).
fn populated_plane() -> ControlPlane {
    let spec = demo_spec();
    let mut plane = ControlPlane::new();
    let p0 = plane.register(spec.clone(), 2.0);
    let p1 = plane.register(spec.clone(), 1.0);

    let reg0 = plane.registry_mut(p0);
    for (i, at) in [(100u64, 1_000.0f64), (200, 2_000.0), (300, 3_000.0)] {
        reg0.publish_params(init_params(&spec, i), i, format!("iter {i}"), at)
            .expect("publish");
    }
    reg0.stage_params(init_params(&spec, 9), 400, "in flight".into(), 4_000.0)
        .expect("stage");
    let v2 = reg0.handle(2);
    reg0.activate(v2).expect("rollback to v2");

    plane
        .registry_mut(p1)
        .publish_params(init_params(&spec, 77), 50, "p1 v1".into(), 500.0)
        .expect("publish p1");
    plane
}

/// A cold plane with the same project layout, as a restarting server
/// would build from its static config before restoring state.
fn cold_plane() -> ControlPlane {
    let mut plane = ControlPlane::new();
    plane.register(demo_spec(), 2.0);
    plane.register(demo_spec(), 1.0);
    plane
}

#[test]
fn serving_restart_warms_from_persisted_segments() {
    let root = temp_root("warm");
    let plane = populated_plane();
    let p0 = ProjectId::new(0);
    let p1 = ProjectId::new(1);
    plane.persist(&root).expect("persist");

    let mut fresh = cold_plane();
    assert!(fresh.registry(p0).is_empty(), "cold plane starts empty");
    let restored = fresh.restore_registries(&root).expect("restore");
    assert_eq!(restored, 2, "both project registries restored");

    // Full-state equality: versions, params, notes, timestamps.
    assert_eq!(
        fresh.registry(p0).export_state(),
        plane.registry(p0).export_state()
    );
    assert_eq!(
        fresh.registry(p1).export_state(),
        plane.registry(p1).export_state()
    );

    // The lifecycle details a restarting server actually depends on.
    let reg0 = fresh.registry(p0);
    assert_eq!(
        reg0.active().map(|s| s.version),
        Some(reg0.handle(2)),
        "rollback target is the active version after restart"
    );
    assert!(reg0.is_staged(reg0.handle(4)), "in-flight stage survives");
    assert_eq!(reg0.len(), 4);
    assert_eq!(fresh.registry(p1).len(), 1);

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn restored_registry_stays_compactable_without_orphans() {
    let root = temp_root("gc");
    populated_plane().persist(&root).expect("persist");

    let mut fresh = cold_plane();
    fresh.restore_registries(&root).expect("restore");

    // Restored pin-free retired versions are compactable: keep=1 over
    // [v1, v2, v3] retires v1 and v3 (v2 is active, v4 is staged — both
    // protected), and their segment files go with them.
    let p0_dir = root.join("p0");
    let reg0 = fresh.registry_mut(ProjectId::new(0));
    assert_eq!(registry_store::segment_versions(&p0_dir).unwrap(), [1, 2, 3, 4]);
    let dropped = registry_store::gc(&p0_dir, reg0, 1).expect("gc");
    let dropped_versions: Vec<u64> = dropped.iter().map(|v| v.version).collect();
    assert_eq!(dropped_versions, [1, 3]);
    assert_eq!(
        registry_store::segment_versions(&p0_dir).unwrap(),
        [2, 4],
        "retired versions' segment files are deleted, no orphans"
    );

    // The compacted store still restarts warm.
    let mut again = cold_plane();
    again.restore_registries(&root).expect("restore after gc");
    let reg0 = again.registry(ProjectId::new(0));
    assert_eq!(reg0.len(), 2);
    assert_eq!(reg0.active().map(|s| s.version), Some(reg0.handle(2)));
    assert!(reg0.is_staged(reg0.handle(4)));

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn manifest_pointing_at_deleted_segment_fails_restore() {
    let root = temp_root("torn");
    populated_plane().persist(&root).expect("persist");
    let victim = root.join("p0").join(registry_store::segment_file_name(2));
    std::fs::remove_file(&victim).expect("delete segment");

    let err = cold_plane().restore_registries(&root).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("missing"), "corruption is loud: {msg}");

    let _ = std::fs::remove_dir_all(&root);
}
