//! Fixture tests for the `mlitb lint` determinism analyzer: every rule
//! firing on a bad snippet and silent on a good one, suppression with
//! and without a reason, lexer torture cases, and a self-lint asserting
//! the crate's own `src/` is clean.

use mlitb::analysis::{analyze_source, analyze_tree, Diagnostic, Report, RuleId};

fn live(path: &str, src: &str) -> Vec<Diagnostic> {
    analyze_source(path, src).into_iter().filter(|d| !d.suppressed).collect()
}

fn fires(path: &str, src: &str, rule: RuleId) -> bool {
    live(path, src).iter().any(|d| d.rule == rule)
}

// ---------------------------------------------------------------- rules

#[test]
fn unordered_iteration_fires_on_map_iter_in_scoped_plane() {
    let src = r#"
        use std::collections::HashMap;
        struct S { map: HashMap<u32, f32> }
        impl S {
            fn all(&self) -> Vec<f32> {
                self.map.values().copied().collect()
            }
        }
    "#;
    assert!(fires("src/sim/fx.rs", src, RuleId::UnorderedIteration));
    let found = live("src/sim/fx.rs", src);
    let d = &found[0];
    assert_eq!(d.rule, RuleId::UnorderedIteration);
    assert!(d.snippet.contains("map"), "snippet: {}", d.snippet);
    assert!(d.line >= 6, "position points at the iteration site");
}

#[test]
fn unordered_iteration_fires_on_for_loop_over_map_ref() {
    let src = r#"
        fn f() {
            let mut seen = std::collections::HashSet::new();
            seen.insert(1u32);
            for v in &seen {
                let _ = v;
            }
        }
    "#;
    assert!(fires("src/serve/fx.rs", src, RuleId::UnorderedIteration));
}

#[test]
fn unordered_iteration_silent_outside_scope_and_on_ordered_maps() {
    let src = r#"
        use std::collections::HashMap;
        fn f(map: &HashMap<u32, f32>) {
            let mut map2: HashMap<u32, f32> = HashMap::new();
            map2.insert(1, 2.0);
            let _ = map2.get(&1);
        }
    "#;
    // point access only → silent even in a scoped plane
    assert!(live("src/sim/fx.rs", src).is_empty());
    let btree = r#"
        fn f() {
            let mut m = std::collections::BTreeMap::new();
            m.insert(1u32, 2.0f32);
            for (k, v) in m.iter() {
                let _ = (k, v);
            }
        }
    "#;
    assert!(live("src/sim/fx.rs", btree).is_empty(), "BTreeMap iteration is ordered");
    let hash_elsewhere = r#"
        use std::collections::HashMap;
        fn f() {
            let mut m: HashMap<u32, u32> = HashMap::new();
            for (k, v) in m.iter() { let _ = (k, v); }
        }
    "#;
    assert!(
        live("src/model/fx.rs", hash_elsewhere).is_empty(),
        "model/ is not an order-sensitive plane"
    );
}

#[test]
fn unordered_iteration_does_not_flag_len_bounded_loops() {
    let src = r#"
        use std::collections::HashMap;
        fn f(m: &HashMap<u32, u32>) {
            let mut m2: HashMap<u32, u32> = HashMap::new();
            for i in 0..m2.len() {
                let _ = i;
            }
        }
    "#;
    assert!(live("src/sim/fx.rs", src).is_empty());
}

#[test]
fn float_ord_unwrap_fires_in_sort_and_on_unwrap_chain() {
    let sorted = r#"
        fn f(v: &mut Vec<f64>) {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
    "#;
    assert!(fires("src/model/fx.rs", sorted, RuleId::FloatOrdUnwrap));
    let min = r#"
        fn f(v: &[f64]) -> Option<&f64> {
            v.iter().min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
        }
    "#;
    assert!(fires("src/model/fx.rs", min, RuleId::FloatOrdUnwrap));
    let bare_unwrap = r#"
        fn f(a: f64, b: f64) -> std::cmp::Ordering {
            a.partial_cmp(&b).unwrap()
        }
    "#;
    assert!(fires("src/model/fx.rs", bare_unwrap, RuleId::FloatOrdUnwrap));
}

#[test]
fn float_ord_silent_on_total_cmp_and_bare_partial_cmp() {
    let good = r#"
        fn f(v: &mut Vec<f64>) {
            v.sort_by(|a, b| a.total_cmp(b));
        }
        fn g(a: f64, b: f64) -> bool {
            a.partial_cmp(&b) == Some(std::cmp::Ordering::Less)
        }
    "#;
    assert!(live("src/model/fx.rs", good).is_empty());
}

#[test]
fn wall_clock_fires_outside_bench_and_is_exempt_inside() {
    let src = r#"
        fn f() -> u64 {
            let t0 = std::time::Instant::now();
            t0.elapsed().as_nanos() as u64
        }
    "#;
    assert!(fires("src/sim/fx.rs", src, RuleId::WallClock));
    assert!(live("src/bench/fx.rs", src).is_empty(), "bench/ is exempt");
    assert!(live("rust/benches/fig_x.rs", src).is_empty(), "benches/ dir is exempt");
    let sleep = "fn f() { std::thread::sleep(std::time::Duration::from_millis(1)); }";
    assert!(fires("src/serve/fx.rs", sleep, RuleId::WallClock));
}

#[test]
fn wall_clock_silent_on_instant_enum_variant() {
    // `EventKind::Instant` (the trace plane's enum variant) must not
    // trip the rule: only qualified `Instant::now` / `std::time` do.
    let src = r#"
        enum EventKind { Span, Instant }
        fn f(k: &EventKind) -> &'static str {
            match k {
                EventKind::Instant => "i",
                EventKind::Span => "x",
            }
        }
    "#;
    assert!(live("src/trace/fx.rs", src).is_empty());
}

#[test]
fn unseeded_randomness_fires_outside_rng_module() {
    let src = "fn f() -> u64 { let mut r = rand::thread_rng(); 4 }";
    assert!(fires("src/sim/fx.rs", src, RuleId::UnseededRandomness));
    assert!(live("src/rng/fx.rs", src).is_empty(), "rng/ may construct RNGs");
    let good = "fn f() { let mut r = crate::rng::Pcg32::new(7); let _ = r.gen_f32(); }";
    assert!(live("src/sim/fx.rs", good).is_empty());
}

#[test]
fn raw_spawn_fires_outside_sharded_and_scoped_spawn_is_fine() {
    let src = "fn f() { std::thread::spawn(move || {}); }";
    assert!(fires("src/coordinator/fx.rs", src, RuleId::RawSpawn));
    assert!(
        live("src/params/sharded.rs", src).is_empty(),
        "params/sharded.rs owns thread management"
    );
    let scoped = r#"
        fn f() {
            std::thread::scope(|scope| {
                scope.spawn(|| {});
            });
        }
    "#;
    assert!(live("src/coordinator/fx.rs", scoped).is_empty(), "scoped spawn is deterministic");
}

#[test]
fn stray_print_fires_in_library_planes_only() {
    let src = "fn f() { println!(\"dbg\"); eprintln!(\"warn\"); }";
    let found = live("src/serve/fx.rs", src);
    assert_eq!(found.iter().filter(|d| d.rule == RuleId::StrayPrint).count(), 2);
    assert!(live("src/cli/fx.rs", src).is_empty(), "cli/ prints by design");
    assert!(live("src/main.rs", src).is_empty(), "main.rs prints by design");
    assert!(live("rust/examples/demo.rs", src).is_empty(), "examples print by design");
}

// --------------------------------------------------------- suppressions

#[test]
fn suppression_with_reason_above_the_line() {
    let src = r#"
        fn f() {
            // lint: allow(stray-print) — operator-facing progress line
            println!("progress");
        }
    "#;
    let all = analyze_source("src/serve/fx.rs", src);
    assert_eq!(all.len(), 1);
    assert!(all[0].suppressed, "reasoned allow suppresses the finding");
    assert!(live("src/serve/fx.rs", src).is_empty());
}

#[test]
fn suppression_with_reason_trailing_the_line() {
    let src = "fn f() { println!(\"x\"); } // lint: allow(stray-print) — demo output";
    assert!(live("src/serve/fx.rs", src).is_empty());
}

#[test]
fn suppression_without_reason_keeps_the_finding_live() {
    let src = r#"
        fn f() {
            // lint: allow(stray-print)
            println!("progress");
        }
    "#;
    let found = live("src/serve/fx.rs", src);
    assert_eq!(found.len(), 1);
    assert!(found[0].missing_reason, "reasonless allow is flagged, not honored");
    let rendered = {
        let mut r = Report::default();
        r.extend(found);
        r.sort();
        r.render()
    };
    assert!(rendered.contains("reason is missing"), "{rendered}");
}

#[test]
fn suppression_for_a_different_rule_does_not_cover() {
    let src = r#"
        fn f() {
            // lint: allow(wall-clock) — wrong rule on purpose
            println!("progress");
        }
    "#;
    assert!(fires("src/serve/fx.rs", src, RuleId::StrayPrint));
}

#[test]
fn unknown_rule_in_allow_is_itself_a_finding() {
    let src = r#"
        fn f() {
            // lint: allow(no-such-rule) — typo
            let x = 1;
            let _ = x;
        }
    "#;
    assert!(fires("src/serve/fx.rs", src, RuleId::BadSuppression));
}

// -------------------------------------------------------- lexer torture

#[test]
fn patterns_inside_strings_and_comments_never_fire() {
    let src = r####"
        fn f() -> String {
            let a = "std::time::Instant::now() and partial_cmp().unwrap()";
            let b = r#"println!("x"); thread::spawn; rand::thread_rng()"#;
            /* std::time::Instant::now();
               /* nested: println!("y"); */
               still inside the outer comment */
            format!("{a}{b}")
        }
    "####;
    assert!(live("src/sim/fx.rs", src).is_empty());
}

#[test]
fn lifetimes_do_not_confuse_the_lexer() {
    // `'a` (lifetime) vs `'x'` (char): a broken lexer would swallow
    // everything after a lifetime as a char literal and miss the real
    // finding on the next line.
    let src = r#"
        fn first<'a>(s: &'a str) -> char {
            let marker = 'x';
            println!("{marker}");
            s.chars().next().unwrap_or(marker)
        }
    "#;
    let found = live("src/serve/fx.rs", src);
    assert_eq!(found.len(), 1);
    assert_eq!(found[0].rule, RuleId::StrayPrint);
}

#[test]
fn raw_string_with_hashes_hides_a_fake_suppression() {
    // A `lint: allow` *inside a string literal* is not a comment and
    // must not suppress anything.
    let src = r##"
        fn f() {
            let fake = r#"lint: allow(stray-print) — not a real comment"#;
            println!("{fake}");
        }
    "##;
    assert!(fires("src/serve/fx.rs", src, RuleId::StrayPrint));
}

// ------------------------------------------------------------ self-lint

#[test]
fn self_lint_crate_src_is_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut report = Report::default();
    analyze_tree(&root, &mut report).expect("walk src");
    assert!(report.is_clean(), "rust/src must lint clean:\n{}", report.render());
    // Every suppression in the tree carries a reason (reasonless ones
    // surface as live findings above), and at least the Table::print
    // exemption exists — the discipline is exercised, not vacuous.
    assert!(report.suppressed_count() >= 1, "expected at least one reasoned allow");
    assert!(!report.all().is_empty());
}

#[test]
fn report_orders_findings_deterministically() {
    let src_b = "fn f() { println!(\"b\"); }";
    let src_a = "fn g() { std::thread::spawn(move || {}); }";
    let mut r = Report::default();
    // insert in reverse path order; render must come out sorted
    r.extend(analyze_source("src/serve/zz.rs", src_b));
    r.extend(analyze_source("src/serve/aa.rs", src_a));
    r.sort();
    let rendered = r.render();
    let a_pos = rendered.find("aa.rs").expect("aa finding rendered");
    let b_pos = rendered.find("zz.rs").expect("zz finding rendered");
    assert!(a_pos < b_pos, "stable path order:\n{rendered}");
    assert_eq!(r.unsuppressed_count(), 2);
    assert!(!r.is_clean());
}
