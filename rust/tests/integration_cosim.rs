//! Integration: the serve × train co-simulation end-to-end — shared
//! clock, multi-project control plane, byte-accounted snapshot
//! publication, hot-swap answer consistency, traffic-driven GC, and the
//! staleness-vs-cadence relationship — on the modeled backends (no
//! artifacts needed; the path is `Compute`-generic).

use std::collections::BTreeMap;

use mlitb::cosim::{run_cosim, CosimConfig, CosimProject, PublicationPolicy, PublishTrigger};
use mlitb::model::{ModelSpec, TensorSpec};
use mlitb::netsim::LinkProfile;
use mlitb::runtime::{Compute, DriftingCompute, ModeledCompute};
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, ControlPlane, FleetConfig, ModelVersion, NoopObserver,
    ProjectId, RouterConfig, RoutingPolicy, ServeConfig, ServeEngine, ServerProfile,
};
use mlitb::sim::SimConfig;

fn fleet(duration_s: f64, seed: u64) -> FleetConfig {
    FleetConfig {
        groups: vec![
            ClientSpec { link: LinkProfile::Lan, rate_rps: 8.0, count: 3 },
            ClientSpec { link: LinkProfile::Wifi, rate_rps: 5.0, count: 3 },
        ],
        duration_s,
        input_pool: 32,
        seed,
    }
}

fn serve_config(duration_s: f64, seed: u64) -> ServeConfig {
    ServeConfig {
        fleets: vec![fleet(duration_s, seed)],
        policy: BatchPolicy {
            max_batch: 32,
            max_wait_ms: 5.0,
            queue_depth: 512,
        },
        server: ServerProfile::default(),
        router: RouterConfig {
            shards: 2,
            policy: RoutingPolicy::JoinShortestQueue,
            coalesce: true,
            ..RouterConfig::single()
        },
        shard_profiles: Vec::new(),
        drained_shards: Vec::new(),
        cache_capacity: 256,
        response_bytes: 256,
        keep_log: true,
    }
}

fn train_config(spec: &ModelSpec, iterations: u64, seed: u64) -> SimConfig {
    let mut train = SimConfig::paper_scaling(2, spec);
    train.iterations = iterations;
    train.train_size = 600;
    train.test_size = 128;
    train.track_every = 1;
    train.master.iter_duration_s = 2.0;
    train.seed = seed;
    train
}

fn cosim_config(iterations: u64, publish: PublicationPolicy, seed: u64) -> CosimConfig {
    let spec = demo_spec();
    CosimConfig {
        projects: vec![CosimProject {
            train: train_config(&spec, iterations, seed),
            spec,
            publish,
            retain: 2,
            weight: 1.0,
        }],
        serve: serve_config(iterations as f64 * 2.0, seed ^ 0xC0517),
        egress_bytes_per_min: 0.0,
        measure_delta: true,
    }
}

fn run(cfg: &CosimConfig) -> mlitb::cosim::CosimReport {
    let mut train_computes: Vec<DriftingCompute> = cfg
        .projects
        .iter()
        .map(|p| DriftingCompute { param_count: p.spec.param_count })
        .collect();
    let train_refs: Vec<&mut dyn Compute> = train_computes
        .iter_mut()
        .map(|c| c as &mut dyn Compute)
        .collect();
    let mut serve_compute = ModeledCompute {
        param_count: cfg.projects[0].spec.param_count,
    };
    run_cosim(cfg, train_refs, &mut serve_compute).expect("cosim run")
}

#[test]
fn cosim_is_byte_deterministic_per_seed() {
    // The acceptance criterion: equal seeds ⇒ byte-identical StalenessLog
    // (and request log); a different seed diverges.
    let cfg = cosim_config(6, PublicationPolicy::every(2), 7);
    let a = run(&cfg);
    let b = run(&cfg);
    assert!(!a.staleness.is_empty());
    assert_eq!(a.staleness.to_csv(), b.staleness.to_csv());
    assert_eq!(a.serve.log.to_csv(), b.serve.log.to_csv());
    assert_eq!(a.summary(), b.summary());
    let c = run(&cosim_config(6, PublicationPolicy::every(2), 8));
    assert_ne!(a.staleness.to_csv(), c.staleness.to_csv());
}

#[test]
fn staleness_decreases_with_publication_cadence() {
    // Faster cadence ⇒ fresher served answers: smaller snapshot age and
    // (with drifting training) smaller prediction delta vs the live
    // master.
    let fresh = run(&cosim_config(8, PublicationPolicy::every(1), 11));
    let stale = run(&cosim_config(8, PublicationPolicy::every(6), 11));
    assert!(fresh.serve.completed > 0 && stale.serve.completed > 0);
    let fresh_age = fresh.staleness.age_iters_summary().mean();
    let stale_age = stale.staleness.age_iters_summary().mean();
    assert!(
        fresh_age < stale_age,
        "cadence-1 age {fresh_age:.2} must undercut cadence-6 age {stale_age:.2}"
    );
    let fresh_delta = fresh.staleness.delta_summary().mean();
    let stale_delta = stale.staleness.delta_summary().mean();
    assert!(
        fresh_delta < stale_delta,
        "cadence-1 delta {fresh_delta:.5} must undercut cadence-6 delta {stale_delta:.5}"
    );
    // Drifting parameters really diverge: staleness shows up as nonzero
    // prediction deltas under the slow cadence.
    assert!(stale_delta > 1e-6, "drifting master must move predictions");
}

#[test]
fn error_improvement_triggers_publication() {
    // δ-triggered publication: the drifting trainer's tracked test error
    // improves steadily, so publications fire without any cadence.
    let cfg = cosim_config(
        6,
        PublicationPolicy {
            every: 0,
            min_improvement: 1e-4,
            hysteresis: 0,
        },
        13,
    );
    let report = run(&cfg);
    assert!(
        report.publications.len() > 2,
        "expected repeated error-triggered publications, got {:?}",
        report.publications
    );
    assert!(report
        .publications
        .iter()
        .skip(1)
        .all(|p| p.trigger == PublishTrigger::ErrorImprovement));
    // The training error really decreased over the run.
    let errs: Vec<f64> = report.train[0]
        .timeline
        .records()
        .iter()
        .filter_map(|r| r.test_error)
        .collect();
    assert!(errs.len() >= 2);
    assert!(
        errs.last().unwrap() < errs.first().unwrap(),
        "drifting training must reduce test error: {errs:?}"
    );
}

#[test]
fn hysteresis_publishes_fewer_versions_on_the_same_run() {
    // The flap-throttling satellite end-to-end: same training trace, the
    // m = 3 policy must publish strictly fewer versions than m = 0 (it
    // waits for three consecutive improved evaluations), and every one
    // of its publications is still error-attributed.
    let trigger = |hysteresis: u64| {
        cosim_config(
            8,
            PublicationPolicy {
                every: 0,
                min_improvement: 1e-4,
                hysteresis,
            },
            13,
        )
    };
    let eager = run(&trigger(0));
    let steady = run(&trigger(3));
    let live = |r: &mlitb::cosim::CosimReport| {
        r.publications
            .iter()
            .filter(|p| p.trigger != PublishTrigger::Initial)
            .count()
    };
    assert!(live(&eager) > 0);
    assert!(
        live(&steady) < live(&eager),
        "hysteresis 3 must publish fewer versions: {} vs {}",
        live(&steady),
        live(&eager)
    );
}

#[test]
fn every_answer_names_a_published_version_and_reconciles() {
    let cfg = cosim_config(6, PublicationPolicy::every(2), 17);
    let report = run(&cfg);
    assert_eq!(
        report.serve.completed + report.serve.rejected,
        report.serve.offered
    );
    assert_eq!(report.staleness.len() as u64, report.serve.completed);
    let published: Vec<ModelVersion> = report.publications.iter().map(|p| p.version).collect();
    // The staleness log and the request log agree on the serving version.
    let by_id: BTreeMap<u64, ModelVersion> = report
        .staleness
        .records()
        .iter()
        .map(|r| (r.id, r.version))
        .collect();
    for r in report.serve.log.records() {
        assert!(published.contains(&r.version), "{r:?}");
        assert_eq!(by_id.get(&r.id), Some(&r.version), "{r:?}");
    }
    // Conservation: published = evicted + resident.
    assert_eq!(
        report.publications.len() as u64,
        report.evicted + report.resident as u64
    );
}

/// (id → class) for records served under version number `version` of
/// project 0.
fn classes_under(log: &mlitb::metrics::RequestLog, version: u64) -> BTreeMap<u64, u32> {
    log.records()
        .iter()
        .filter(|r| r.version.version == version)
        .map(|r| (r.id, r.class))
        .collect()
}

#[test]
fn hot_swap_is_answer_consistent_and_rollback_is_byte_identical() {
    // Engine-level: the same request schedule served three ways.
    //   A: v1 for the whole run (the reference).
    //   B: v1 → hot-swap to v2 mid-traffic → roll back to v1.
    //   C: v2 for the whole run (the v2 reference).
    // Every B answer must be byte-identical to the reference of the
    // version that served it — a swap never leaks the other version's
    // parameters into a request (and batches admitted under v1 that
    // flush after the swap still execute against v1; the debug assert in
    // the engine checks no batch mixes versions).
    let spec = demo_spec();
    let project = ProjectId::new(0);
    let mut cfg = serve_config(4.0, 31);
    cfg.cache_capacity = 0; // every answer executes: pure version identity
    cfg.router.coalesce = false;
    cfg.router.shards = 1;
    let p1 = mlitb::model::init_params(&spec, 42);
    let p2: Vec<f32> = p1.iter().map(|x| -x).collect();

    let full_run = |params: Vec<f32>| {
        let mut plane = ControlPlane::single(spec.clone());
        plane
            .registry_mut(project)
            .publish_params(params, 0, "ref".into(), 0.0)
            .unwrap();
        let mut compute = ModeledCompute { param_count: spec.param_count };
        let mut eng = ServeEngine::new(&cfg, &plane).expect("engine");
        eng.pump(None, &mut plane, &mut compute, &mut NoopObserver).unwrap();
        eng.into_report()
    };
    let ref_v1 = full_run(p1.clone());
    let ref_v2 = full_run(p2.clone());

    let mut plane = ControlPlane::single(spec.clone());
    plane
        .registry_mut(project)
        .publish_params(p1.clone(), 0, "v1".into(), 0.0)
        .unwrap();
    let mut compute = ModeledCompute { param_count: spec.param_count };
    let mut eng = ServeEngine::new(&cfg, &plane).expect("engine");
    // Phase 1: v1 traffic.
    eng.pump(Some(1_500.0), &mut plane, &mut compute, &mut NoopObserver).unwrap();
    // Hot swap to v2 mid-traffic (pending v1 admissions still drain as
    // v1; `publish_params` is the instant-activation path).
    plane
        .registry_mut(project)
        .publish_params(p2, 10, "v2".into(), 1_500.0)
        .unwrap();
    eng.pump(Some(3_000.0), &mut plane, &mut compute, &mut NoopObserver).unwrap();
    // Rollback: pin serving back to v1.
    let v1_handle = plane.registry(project).handle(1);
    plane.registry_mut(project).activate(v1_handle).unwrap();
    eng.pump(None, &mut plane, &mut compute, &mut NoopObserver).unwrap();
    let swapped = eng.into_report();

    assert_eq!(swapped.completed, ref_v1.completed, "same schedule");
    let under_v1 = classes_under(&swapped.log, 1);
    let under_v2 = classes_under(&swapped.log, 2);
    assert!(!under_v1.is_empty() && !under_v2.is_empty(), "both versions served");
    let ref1 = classes_under(&ref_v1.log, 1);
    let ref2 = classes_under(&ref_v2.log, 1);
    for (id, class) in &under_v1 {
        assert_eq!(
            ref1.get(id),
            Some(class),
            "request {id}: v1 answer (incl. post-rollback) must match the v1 reference"
        );
    }
    for (id, class) in &under_v2 {
        assert_eq!(
            ref2.get(id),
            Some(class),
            "request {id}: v2 answer must match the v2 reference"
        );
    }
    // The swap was observable: the two parameter vectors disagree on at
    // least some of the schedule's answers.
    let differs = under_v2
        .iter()
        .filter(|(id, class)| ref1.get(id) != Some(class))
        .count();
    assert!(differs > 0, "sign-flipped parameters must change some answers");
    // Rollback really happened: v1 answers exist after the v2 window.
    let last_v2_done = swapped
        .log
        .records()
        .iter()
        .filter(|r| r.version.version == 2)
        .map(|r| r.done_ms)
        .fold(0.0f64, f64::max);
    assert!(
        swapped
            .log
            .records()
            .iter()
            .any(|r| r.version.version == 1 && r.done_ms > last_v2_done),
        "post-rollback traffic must serve v1 again"
    );
}

#[test]
fn gc_waits_for_inflight_readers_under_live_traffic() {
    // Slow shards + fast publication: batches regularly straddle
    // publication boundaries, so GC sees pinned versions.  The run must
    // complete (an evicted-while-pinned version would error the flush),
    // release every pin, and still reclaim old versions eventually.
    let mut cfg = cosim_config(8, PublicationPolicy::every(1), 19);
    cfg.projects[0].retain = 1;
    cfg.serve.shard_profiles = vec![
        ServerProfile {
            power_vps: 800.0,
            ..ServerProfile::default()
        },
        ServerProfile {
            power_vps: 800.0,
            ..ServerProfile::default()
        },
    ];
    let report = run(&cfg);
    assert!(report.evicted > 0, "retention 1 must reclaim versions");
    assert_eq!(
        report.publications.len() as u64,
        report.evicted + report.resident as u64
    );
    assert_eq!(
        report.serve.completed + report.serve.rejected,
        report.serve.offered
    );
}

// ─────────────────────── multi-project acceptance ─────────────────────

/// A second, smaller hosted model with a *different input shape* than
/// `demo_spec` — the sharpest project-purity probe there is: if any
/// batch, cache entry or probe execution ever mixed the projects, the
/// executor would reject the wrong-length input and the run would error.
fn small_spec() -> ModelSpec {
    ModelSpec {
        name: "small_mlp".into(),
        param_count: 12,
        batch_size: 4,
        micro_batches: vec![4, 1],
        input: vec![3, 1, 1],
        classes: 4,
        tensors: vec![TensorSpec {
            name: "w".into(),
            shape: vec![12],
            offset: 0,
            size: 12,
            fan_in: 3,
        }],
        artifacts: Default::default(),
    }
}

/// Two projects — the big `demo_spec` and the tiny `small_spec` — behind
/// one shared 2-shard tier, both training live, publication throttled to
/// `egress_bytes_per_min`.
fn two_project_config(iterations: u64, egress_bytes_per_min: f64) -> CosimConfig {
    let demo = demo_spec();
    let small = small_spec();
    let duration_s = iterations as f64 * 2.0;
    CosimConfig {
        projects: vec![
            CosimProject {
                train: train_config(&demo, iterations, 3),
                spec: demo,
                publish: PublicationPolicy::every(2),
                retain: 2,
                weight: 1.0,
            },
            CosimProject {
                train: {
                    let mut t = train_config(&small, iterations, 4);
                    t.train_size = 300;
                    t.test_size = 64;
                    t
                },
                spec: small,
                publish: PublicationPolicy::every(2),
                retain: 2,
                weight: 1.0,
            },
        ],
        serve: ServeConfig {
            fleets: vec![fleet(duration_s, 37), fleet(duration_s, 38)],
            policy: BatchPolicy {
                max_batch: 32,
                max_wait_ms: 5.0,
                queue_depth: 512,
            },
            server: ServerProfile::default(),
            router: RouterConfig {
                shards: 2,
                policy: RoutingPolicy::JoinShortestQueue,
                coalesce: true,
                ..RouterConfig::single()
            },
            shard_profiles: Vec::new(),
            drained_shards: Vec::new(),
            cache_capacity: 256,
            response_bytes: 256,
            keep_log: true,
        },
        egress_bytes_per_min,
        measure_delta: true,
    }
}

#[test]
fn two_project_cosim_never_mixes_projects_and_reconciles_per_project() {
    // Acceptance (a): batches are never mixed across projects or
    // versions.  The two specs have different input lengths, so a mixed
    // batch could not even execute — a completing run plus per-record
    // version joins pin the property end-to-end.
    let report = run(&two_project_config(6, 0.0));
    let p0 = ProjectId::new(0);
    let p1 = ProjectId::new(1);
    assert!(report.serve.completed > 0);
    assert_eq!(
        report.serve.completed + report.serve.rejected,
        report.serve.offered
    );
    // Both projects trained and served.
    assert_eq!(report.train.len(), 2);
    assert_eq!(report.train[0].timeline.len(), 6);
    assert_eq!(report.train[1].timeline.len(), 6);
    let s0 = report.serve.project(p0);
    let s1 = report.serve.project(p1);
    assert!(s0.completed > 0 && s1.completed > 0);
    assert_eq!(s0.completed + s1.completed, report.serve.completed);
    // Every record's version belongs to its own project's published set —
    // never the other's.
    let published_by: BTreeMap<ModelVersion, ProjectId> = report
        .publications
        .iter()
        .map(|p| (p.version, p.project()))
        .collect();
    for r in report.serve.log.records() {
        assert_eq!(published_by.get(&r.version), Some(&r.version.project), "{r:?}");
    }
    // Per-project staleness views partition the interleaved log exactly
    // (the isolation property, end-to-end).
    let v0 = report.staleness.for_project(p0);
    let v1 = report.staleness.for_project(p1);
    assert_eq!(v0.len() + v1.len(), report.staleness.len());
    assert!(v0.records().iter().all(|r| r.version.project == p0));
    assert!(v1.records().iter().all(|r| r.version.project == p1));
    // Each project's staleness is bounded by its own run — a
    // cross-project master_iteration leak would blow this bound.
    for r in v0.records().iter().chain(v1.records()) {
        assert!(r.age_iters() <= 6, "{r:?}");
    }
    // Publications interleave but stay project-scoped: initial + cadence
    // at iterations 2, 4, 6 for each project.
    for p in [p0, p1] {
        let pubs = report.publications_for(p);
        assert_eq!(pubs.len(), 4, "initial + 3 cadence for {p}");
        assert_eq!(
            pubs.iter().skip(1).map(|r| r.iteration).collect::<Vec<_>>(),
            vec![2, 4, 6]
        );
    }
}

#[test]
fn throttled_publication_charges_egress_and_delays_activation() {
    // Acceptance (b): publication of a large model charges master-egress
    // bytes and measurably delays its activation.  At 0.3 MB/min the
    // demo project's ~51 KB snapshot needs ~10 s of link time (≥ 4
    // iteration windows), and the small project's 48 B snapshots queue
    // behind it on the *shared* budget.
    let report = run(&two_project_config(6, 0.3e6));
    let live: Vec<_> = report
        .publications
        .iter()
        .filter(|p| p.trigger != PublishTrigger::Initial)
        .collect();
    assert!(!live.is_empty());
    // Egress bytes: every live publication charged param_count × 4.
    let expected: u64 = live.iter().map(|p| p.bytes).sum();
    assert!(expected > 0);
    assert_eq!(report.egress_bytes, expected);
    for p in &live {
        let param_bytes = if p.project() == ProjectId::new(0) {
            demo_spec().param_count * 4
        } else {
            small_spec().param_count * 4
        } as u64;
        assert_eq!(p.bytes, param_bytes);
        assert!(p.activated_ms >= p.t_ms, "{p:?}");
    }
    // The big model's first publication visibly outlives its window:
    // activation lands iterations after the publish decision.
    let first_demo = live
        .iter()
        .find(|p| p.project() == ProjectId::new(0))
        .expect("demo project published");
    assert!(
        first_demo.transfer_ms() >= 9_000.0,
        "~51 KB at 0.3 MB/min is ~10 s of link time: {first_demo:?}"
    );
    assert!(
        first_demo.activated_iteration > first_demo.iteration,
        "activation must trail publication by whole iterations: {first_demo:?}"
    );
    // Mid-transfer traffic kept serving the previous version: no answer
    // may predate its own version's activation.
    let activated_at: BTreeMap<ModelVersion, f64> = report
        .publications
        .iter()
        .map(|p| (p.version, p.activated_ms))
        .collect();
    for r in report.serve.log.records() {
        let act = activated_at.get(&r.version).copied().unwrap_or(0.0);
        assert!(r.done_ms >= act, "{r:?}");
    }
    // Unthrottled twin run: same schedules, zero activation lag — the
    // delay really came from the budget.
    let instant = run(&two_project_config(6, 0.0));
    assert!(instant
        .publications
        .iter()
        .all(|p| p.activated_ms == p.t_ms));
    assert!(instant.egress_bytes > 0, "bytes accounted even unthrottled");
}
