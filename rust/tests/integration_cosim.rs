//! Integration: the serve × train co-simulation end-to-end — shared
//! clock, live snapshot publication, hot-swap answer consistency,
//! traffic-driven GC, and the staleness-vs-cadence relationship — on the
//! modeled backends (no artifacts needed; the path is `Compute`-generic).

use std::collections::BTreeMap;

use mlitb::cosim::{run_cosim, CosimConfig, PublicationPolicy, PublishTrigger};
use mlitb::netsim::LinkProfile;
use mlitb::runtime::{DriftingCompute, ModeledCompute};
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, FleetConfig, NoopObserver, RouterConfig, RoutingPolicy,
    ServeConfig, ServeEngine, ServerProfile, SnapshotRegistry,
};
use mlitb::sim::SimConfig;

fn serve_config(duration_s: f64, seed: u64) -> ServeConfig {
    ServeConfig {
        fleet: FleetConfig {
            groups: vec![
                ClientSpec { link: LinkProfile::Lan, rate_rps: 8.0, count: 3 },
                ClientSpec { link: LinkProfile::Wifi, rate_rps: 5.0, count: 3 },
            ],
            duration_s,
            input_pool: 32,
            seed,
        },
        policy: BatchPolicy {
            max_batch: 32,
            max_wait_ms: 5.0,
            queue_depth: 512,
        },
        server: ServerProfile::default(),
        router: RouterConfig {
            shards: 2,
            policy: RoutingPolicy::JoinShortestQueue,
            coalesce: true,
            autotune: false,
            window_ms: 1_000.0,
        },
        shard_profiles: Vec::new(),
        drained_shards: Vec::new(),
        cache_capacity: 256,
        response_bytes: 256,
    }
}

fn cosim_config(iterations: u64, publish: PublicationPolicy, seed: u64) -> CosimConfig {
    let spec = demo_spec();
    let mut train = SimConfig::paper_scaling(2, &spec);
    train.iterations = iterations;
    train.train_size = 600;
    train.test_size = 128;
    train.track_every = 1;
    train.master.iter_duration_s = 2.0;
    train.seed = seed;
    CosimConfig {
        serve: serve_config(iterations as f64 * 2.0, seed ^ 0xC0517),
        train,
        publish,
        retain: 2,
        measure_delta: true,
    }
}

fn run(cfg: &CosimConfig) -> mlitb::cosim::CosimReport {
    let spec = demo_spec();
    let mut train_compute = DriftingCompute { param_count: spec.param_count };
    let mut serve_compute = ModeledCompute { param_count: spec.param_count };
    run_cosim(cfg, &spec, &mut train_compute, &mut serve_compute).expect("cosim run")
}

#[test]
fn cosim_is_byte_deterministic_per_seed() {
    // The acceptance criterion: equal seeds ⇒ byte-identical StalenessLog
    // (and request log); a different seed diverges.
    let cfg = cosim_config(6, PublicationPolicy::every(2), 7);
    let a = run(&cfg);
    let b = run(&cfg);
    assert!(!a.staleness.is_empty());
    assert_eq!(a.staleness.to_csv(), b.staleness.to_csv());
    assert_eq!(a.serve.log.to_csv(), b.serve.log.to_csv());
    assert_eq!(a.summary(), b.summary());
    let c = run(&cosim_config(6, PublicationPolicy::every(2), 8));
    assert_ne!(a.staleness.to_csv(), c.staleness.to_csv());
}

#[test]
fn staleness_decreases_with_publication_cadence() {
    // Faster cadence ⇒ fresher served answers: smaller snapshot age and
    // (with drifting training) smaller prediction delta vs the live
    // master.
    let fresh = run(&cosim_config(8, PublicationPolicy::every(1), 11));
    let stale = run(&cosim_config(8, PublicationPolicy::every(6), 11));
    assert!(fresh.serve.completed > 0 && stale.serve.completed > 0);
    let fresh_age = fresh.staleness.age_iters_summary().mean();
    let stale_age = stale.staleness.age_iters_summary().mean();
    assert!(
        fresh_age < stale_age,
        "cadence-1 age {fresh_age:.2} must undercut cadence-6 age {stale_age:.2}"
    );
    let fresh_delta = fresh.staleness.delta_summary().mean();
    let stale_delta = stale.staleness.delta_summary().mean();
    assert!(
        fresh_delta < stale_delta,
        "cadence-1 delta {fresh_delta:.5} must undercut cadence-6 delta {stale_delta:.5}"
    );
    // Drifting parameters really diverge: staleness shows up as nonzero
    // prediction deltas under the slow cadence.
    assert!(stale_delta > 1e-6, "drifting master must move predictions");
}

#[test]
fn error_improvement_triggers_publication() {
    // δ-triggered publication: the drifting trainer's tracked test error
    // improves steadily, so publications fire without any cadence.
    let cfg = cosim_config(
        6,
        PublicationPolicy {
            every: 0,
            min_improvement: 1e-4,
        },
        13,
    );
    let report = run(&cfg);
    assert!(
        report.publications.len() > 2,
        "expected repeated error-triggered publications, got {:?}",
        report.publications
    );
    assert!(report
        .publications
        .iter()
        .skip(1)
        .all(|p| p.trigger == PublishTrigger::ErrorImprovement));
    // The training error really decreased over the run.
    let errs: Vec<f64> = report
        .train
        .timeline
        .records()
        .iter()
        .filter_map(|r| r.test_error)
        .collect();
    assert!(errs.len() >= 2);
    assert!(
        errs.last().unwrap() < errs.first().unwrap(),
        "drifting training must reduce test error: {errs:?}"
    );
}

#[test]
fn every_answer_names_a_published_version_and_reconciles() {
    let cfg = cosim_config(6, PublicationPolicy::every(2), 17);
    let report = run(&cfg);
    assert_eq!(
        report.serve.completed + report.serve.rejected,
        report.serve.offered
    );
    assert_eq!(report.staleness.len() as u64, report.serve.completed);
    let published: Vec<u64> = report.publications.iter().map(|p| p.snapshot).collect();
    // The staleness log and the request log agree on the serving version.
    let by_id: BTreeMap<u64, u64> = report
        .staleness
        .records()
        .iter()
        .map(|r| (r.id, r.snapshot))
        .collect();
    for r in report.serve.log.records() {
        assert!(published.contains(&r.snapshot), "{r:?}");
        assert_eq!(by_id.get(&r.id), Some(&r.snapshot), "{r:?}");
    }
    // Conservation: published = evicted + resident.
    assert_eq!(
        report.publications.len() as u64,
        report.evicted + report.resident as u64
    );
}

/// (id → class) for records served under `version`.
fn classes_under(
    log: &mlitb::metrics::RequestLog,
    version: u64,
) -> BTreeMap<u64, u32> {
    log.records()
        .iter()
        .filter(|r| r.snapshot == version)
        .map(|r| (r.id, r.class))
        .collect()
}

#[test]
fn hot_swap_is_answer_consistent_and_rollback_is_byte_identical() {
    // Engine-level: the same request schedule served three ways.
    //   A: v1 for the whole run (the reference).
    //   B: v1 → hot-swap to v2 mid-traffic → roll back to v1.
    //   C: v2 for the whole run (the v2 reference).
    // Every B answer must be byte-identical to the reference of the
    // version that served it — a swap never leaks the other version's
    // parameters into a request (and batches admitted under v1 that
    // flush after the swap still execute against v1; the debug assert in
    // the engine checks no batch mixes versions).
    let spec = demo_spec();
    let mut cfg = serve_config(4.0, 31);
    cfg.cache_capacity = 0; // every answer executes: pure version identity
    cfg.router.coalesce = false;
    cfg.router.shards = 1;
    let p1 = mlitb::model::init_params(&spec, 42);
    let p2: Vec<f32> = p1.iter().map(|x| -x).collect();

    let full_run = |params: Vec<f32>| {
        let mut reg = SnapshotRegistry::new(spec.clone());
        reg.publish_params(params, 0, "ref".into(), 0.0).unwrap();
        let mut compute = ModeledCompute { param_count: spec.param_count };
        let mut eng = ServeEngine::new(&cfg, &spec);
        eng.pump(None, &mut reg, &mut compute, &mut NoopObserver).unwrap();
        eng.into_report()
    };
    let ref_v1 = full_run(p1.clone());
    let ref_v2 = full_run(p2.clone());

    let mut reg = SnapshotRegistry::new(spec.clone());
    reg.publish_params(p1.clone(), 0, "v1".into(), 0.0).unwrap();
    let mut compute = ModeledCompute { param_count: spec.param_count };
    let mut eng = ServeEngine::new(&cfg, &spec);
    // Phase 1: v1 traffic.
    eng.pump(Some(1_500.0), &mut reg, &mut compute, &mut NoopObserver).unwrap();
    // Hot swap to v2 mid-traffic (pending v1 admissions still drain as v1).
    reg.publish_params(p2, 10, "v2".into(), 1_500.0).unwrap();
    eng.pump(Some(3_000.0), &mut reg, &mut compute, &mut NoopObserver).unwrap();
    // Rollback: pin serving back to v1.
    reg.set_active(1).unwrap();
    eng.pump(None, &mut reg, &mut compute, &mut NoopObserver).unwrap();
    let swapped = eng.into_report();

    assert_eq!(swapped.completed, ref_v1.completed, "same schedule");
    let under_v1 = classes_under(&swapped.log, 1);
    let under_v2 = classes_under(&swapped.log, 2);
    assert!(!under_v1.is_empty() && !under_v2.is_empty(), "both versions served");
    let ref1 = classes_under(&ref_v1.log, 1);
    let ref2 = classes_under(&ref_v2.log, 1);
    for (id, class) in &under_v1 {
        assert_eq!(
            ref1.get(id),
            Some(class),
            "request {id}: v1 answer (incl. post-rollback) must match the v1 reference"
        );
    }
    for (id, class) in &under_v2 {
        assert_eq!(
            ref2.get(id),
            Some(class),
            "request {id}: v2 answer must match the v2 reference"
        );
    }
    // The swap was observable: the two parameter vectors disagree on at
    // least some of the schedule's answers.
    let differs = under_v2
        .iter()
        .filter(|(id, class)| ref1.get(id) != Some(class))
        .count();
    assert!(differs > 0, "sign-flipped parameters must change some answers");
    // Rollback really happened: v1 answers exist after the v2 window.
    let last_v2_done = swapped
        .log
        .records()
        .iter()
        .filter(|r| r.snapshot == 2)
        .map(|r| r.done_ms)
        .fold(0.0f64, f64::max);
    assert!(
        swapped
            .log
            .records()
            .iter()
            .any(|r| r.snapshot == 1 && r.done_ms > last_v2_done),
        "post-rollback traffic must serve v1 again"
    );
}

#[test]
fn gc_waits_for_inflight_readers_under_live_traffic() {
    // Slow shards + fast publication: batches regularly straddle
    // publication boundaries, so GC sees pinned versions.  The run must
    // complete (an evicted-while-pinned version would error the flush),
    // release every pin, and still reclaim old versions eventually.
    let spec = demo_spec();
    let mut cfg = cosim_config(8, PublicationPolicy::every(1), 19);
    cfg.retain = 1;
    cfg.serve.shard_profiles = vec![
        ServerProfile {
            power_vps: 800.0,
            ..ServerProfile::default()
        },
        ServerProfile {
            power_vps: 800.0,
            ..ServerProfile::default()
        },
    ];
    let mut train_compute = DriftingCompute { param_count: spec.param_count };
    let mut serve_compute = ModeledCompute { param_count: spec.param_count };
    let report =
        run_cosim(&cfg, &spec, &mut train_compute, &mut serve_compute).expect("cosim with GC");
    assert!(report.evicted > 0, "retention 1 must reclaim versions");
    assert_eq!(
        report.publications.len() as u64,
        report.evicted + report.resident as u64
    );
    assert_eq!(
        report.serve.completed + report.serve.rejected,
        report.serve.offered
    );
}
