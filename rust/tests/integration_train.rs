//! Integration: full distributed training through the coordinator with the
//! real PJRT engine — the system's core claim (distributed synchronized
//! SGD with real gradients converges) at test scale.
//!
//! Needs the compiled AOT artifacts, so the whole file is gated on the
//! `pjrt` feature: `cargo test --features pjrt` after `make artifacts`.
#![cfg(feature = "pjrt")]

use mlitb::client::DeviceClass;
use mlitb::coordinator::ReducePolicy;
use mlitb::model::{Manifest, ResearchClosure};
use mlitb::runtime::Engine;
use mlitb::sim::{ChurnEvent, SimConfig, Simulation};

fn engine() -> Engine {
    let manifest = Manifest::load_default().expect("run `make artifacts` first");
    Engine::new(manifest).unwrap()
}

fn small_cfg(model: &str, nodes: usize, engine: &Engine) -> SimConfig {
    let spec = engine.spec(model).unwrap().clone();
    let mut cfg = SimConfig::paper_scaling(nodes, &spec);
    cfg.train_size = 1200;
    cfg.test_size = 160;
    cfg.iterations = 12;
    cfg.master.capacity = 400;
    cfg.master.learning_rate = 0.05;
    cfg.power_scale = 0.15; // keep test runtime modest
    cfg.seed = 42;
    cfg
}

#[test]
fn distributed_training_reduces_loss_and_error() {
    let mut eng = engine();
    eng.load_model("mnist_mlp").unwrap();
    let spec = eng.spec("mnist_mlp").unwrap().clone();
    let mut cfg = small_cfg("mnist_mlp", 3, &eng);
    cfg.track_every = 6;
    let mut sim = Simulation::new(cfg, spec, &mut eng);
    let report = sim.run().unwrap();
    let first_loss = report.timeline.records()[0].loss.unwrap();
    let last_loss = report
        .timeline
        .records()
        .iter()
        .rev()
        .find_map(|r| r.loss)
        .unwrap();
    assert!(
        last_loss < first_loss * 0.8,
        "no convergence: {first_loss} -> {last_loss}"
    );
    let err = report.final_test_error.expect("tracking ran");
    assert!(err < 0.85, "test error no better than chance: {err}");
    sim.master().allocator().check_invariants().unwrap();
}

#[test]
fn churn_mid_training_preserves_convergence_and_data() {
    let mut eng = engine();
    eng.load_model("mnist_mlp").unwrap();
    let spec = eng.spec("mnist_mlp").unwrap().clone();
    let mut cfg = small_cfg("mnist_mlp", 2, &eng);
    cfg.churn.insert(3, vec![ChurnEvent::Join(DeviceClass::Laptop)]);
    cfg.churn.insert(6, vec![ChurnEvent::Leave(1)]);
    cfg.churn.insert(8, vec![ChurnEvent::Join(DeviceClass::Mobile)]);
    let mut sim = Simulation::new(cfg, spec, &mut eng);
    let report = sim.run().unwrap();
    // fleet: 2 +1 -1 +1 = 3
    assert_eq!(report.workers, 3);
    let first_loss = report.timeline.records()[0].loss.unwrap();
    let last_loss = report
        .timeline
        .records()
        .iter()
        .rev()
        .find_map(|r| r.loss)
        .unwrap();
    assert!(last_loss < first_loss, "{first_loss} -> {last_loss}");
    sim.master().allocator().check_invariants().unwrap();
}

#[test]
fn partial_gradient_policy_still_trains() {
    let mut eng = engine();
    eng.load_model("mnist_mlp").unwrap();
    let spec = eng.spec("mnist_mlp").unwrap().clone();
    let mut cfg = small_cfg("mnist_mlp", 2, &eng);
    cfg.master.policy = ReducePolicy::PartialSync { keep_fraction: 0.25 };
    let mut sim = Simulation::new(cfg, spec.clone(), &mut eng);
    let report = sim.run().unwrap();
    let first_loss = report.timeline.records()[0].loss.unwrap();
    let last_loss = report
        .timeline
        .records()
        .iter()
        .rev()
        .find_map(|r| r.loss)
        .unwrap();
    assert!(
        last_loss < first_loss * 0.9,
        "partial gradients broke training: {first_loss} -> {last_loss}"
    );
    // bandwidth actually dropped: keep=0.25 with (u32 idx, f32 val) pairs
    // costs 0.25 × 8/4 = 0.5× the dense bytes (plus envelopes)
    let dense_bytes = spec.param_count as u64 * 4 * 2; // 2 workers
    let rec = report.timeline.records().last().unwrap();
    assert!(
        rec.bytes_up <= dense_bytes * 55 / 100,
        "sparse bytes {} vs dense {}",
        rec.bytes_up,
        dense_bytes
    );
}

#[test]
fn closure_save_resume_roundtrip() {
    let mut eng = engine();
    eng.load_model("mnist_mlp").unwrap();
    let spec = eng.spec("mnist_mlp").unwrap().clone();
    let cfg = small_cfg("mnist_mlp", 2, &eng);

    // train a few iterations, save a closure
    let (params_after, iteration) = {
        let mut sim = Simulation::new(cfg.clone(), spec.clone(), &mut eng);
        sim.run().unwrap();
        (
            sim.master().params().to_vec(),
            sim.master().iteration(),
        )
    };
    let mut closure = ResearchClosure::new(&spec, &params_after);
    closure.iteration = iteration;
    let path = std::env::temp_dir().join("mlitb_it_closure.json");
    closure.save(&path).unwrap();

    // load and resume: a fresh sim seeded with the closure's params must
    // start from the trained loss level, not from scratch
    let loaded = ResearchClosure::load(&path).unwrap();
    loaded.check_compatible(&spec).unwrap();
    let mut cfg2 = cfg;
    cfg2.iterations = 2;
    let mut sim2 = Simulation::new(cfg2, spec, &mut eng);
    // fresh-init loss is ~2.3; continue-from-closure should be well below
    sim2.master_mut_for_test().set_params(loaded.params.clone());
    let report = sim2.run().unwrap();
    let resumed_loss = report.timeline.records()[0].loss.unwrap();
    assert!(
        resumed_loss < 2.0,
        "resume did not keep trained params: loss {resumed_loss}"
    );
    std::fs::remove_file(&path).ok();
}
