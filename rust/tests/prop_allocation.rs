//! Property tests over the allocation and coordinator state machines,
//! using the in-repo seeded property harness (`mlitb::testing`).

use mlitb::allocation::{Allocator, WorkerId};
use mlitb::testing::{check, gen};

/// Drive an allocator through a random event sequence, checking the
/// structural invariants after every step.
fn fuzz_allocator(capacity: usize, events: &[gen::AllocEvent]) -> Result<Allocator, String> {
    let mut alloc = Allocator::new(capacity);
    let mut next_id: WorkerId = 1;
    let mut live: Vec<WorkerId> = Vec::new();
    for (step, ev) in events.iter().enumerate() {
        match *ev {
            gen::AllocEvent::AddData(n) => {
                alloc.add_data(n);
            }
            gen::AllocEvent::Join => {
                alloc.worker_join(next_id);
                live.push(next_id);
                next_id += 1;
            }
            gen::AllocEvent::Leave => {
                if let Some(w) = live.pop() {
                    alloc.worker_leave(w);
                }
            }
            gen::AllocEvent::Shed(n) => {
                if let Some(&w) = live.first() {
                    alloc.shed_load(w, n);
                }
            }
        }
        alloc
            .check_invariants()
            .map_err(|e| format!("step {step} ({ev:?}): {e}"))?;
    }
    Ok(alloc)
}

#[test]
fn prop_invariants_hold_under_arbitrary_churn() {
    check("alloc-churn-invariants", |rng| {
        let capacity = gen::usize_in(rng, 1, 500);
        let events = gen::alloc_events(rng, 60);
        fuzz_allocator(capacity, &events).map(|_| ())
    });
}

#[test]
fn prop_no_data_lost_ever() {
    // Every registered id is owned by exactly one worker or unallocated —
    // after ANY event sequence (the §3.2 robustness requirement).
    check("alloc-no-data-loss", |rng| {
        let capacity = gen::usize_in(rng, 10, 2000);
        let events = gen::alloc_events(rng, 40);
        let alloc = fuzz_allocator(capacity, &events)?;
        let total = alloc.total_data();
        let owned: usize = alloc
            .worker_ids()
            .iter()
            .map(|&w| alloc.owned_by(w).len())
            .sum();
        if owned + alloc.unallocated().len() != total {
            return Err(format!(
                "{} owned + {} unallocated != {total}",
                owned,
                alloc.unallocated().len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_allocation_is_balanced_when_capacity_allows() {
    // With all data fitting (total ≤ workers × capacity) and no shed
    // events, imbalance after a join storm is bounded by the pie-cutter
    // tolerance (fair share rounding).
    check("alloc-balance", |rng| {
        let n_workers = gen::usize_in(rng, 1, 12);
        let per = gen::usize_in(rng, 10, 300);
        let total = n_workers * per;
        let mut alloc = Allocator::new(per * 2);
        alloc.add_data(total);
        for w in 0..n_workers {
            alloc.worker_join(w as WorkerId);
        }
        alloc.check_invariants()?;
        if alloc.unallocated().len() > 0 {
            return Err(format!("{} ids unallocated", alloc.unallocated().len()));
        }
        // Pie-cutter guarantee: every worker ends within fair_share ±
        // (n_workers) of the mean (integer rounding per join round).
        let mean = total / n_workers;
        for w in alloc.worker_ids() {
            let got = alloc.owned_by(w).len();
            if got + n_workers < mean || got > mean + total {
                return Err(format!("worker {w} has {got}, mean {mean}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_join_transfer_cost_bounded_by_fair_share() {
    // The pie-cutter promise (§3.3b): adding the (k+1)-th worker moves
    // O(total/(k+1)) ids, never O(total).
    check("alloc-pie-cost", |rng| {
        let total = gen::usize_in(rng, 100, 5000);
        let k = gen::usize_in(rng, 1, 10);
        let mut alloc = Allocator::new(usize::MAX >> 1);
        alloc.add_data(total);
        for w in 0..k {
            alloc.worker_join(w as WorkerId);
        }
        let delta = alloc.worker_join(999);
        alloc.check_invariants()?;
        let fair = total / (k + 1);
        if delta.moved() > fair + k + 1 {
            return Err(format!(
                "join moved {} ids, fair share is {fair} (k={k}, total={total})",
                delta.moved()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_leave_reallocates_up_to_capacity() {
    check("alloc-leave-realloc", |rng| {
        let capacity = gen::usize_in(rng, 5, 100);
        let n_workers = gen::usize_in(rng, 2, 8);
        let total = gen::usize_in(rng, 10, capacity * n_workers);
        let mut alloc = Allocator::new(capacity);
        alloc.add_data(total);
        for w in 0..n_workers {
            alloc.worker_join(w as WorkerId);
        }
        alloc.worker_leave(0);
        alloc.check_invariants()?;
        // survivors can hold (n-1)·capacity; anything beyond is unallocated
        let survivors_cap = (n_workers - 1) * capacity;
        let expect_unallocated = total.saturating_sub(survivors_cap);
        if alloc.unallocated().len() < expect_unallocated {
            return Err(format!(
                "unallocated {} < expected {expect_unallocated}",
                alloc.unallocated().len()
            ));
        }
        if expect_unallocated == 0 && !alloc.unallocated().is_empty() {
            return Err(format!(
                "capacity allows full reallocation but {} ids stranded",
                alloc.unallocated().len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    use mlitb::json::{parse, to_string, Value};

    fn random_value(rng: &mut mlitb::rng::Pcg32, depth: usize) -> Value {
        match if depth > 3 { rng.gen_range_usize(4) } else { rng.gen_range_usize(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.gen_bool(0.5)),
            2 => {
                // mix of integer-valued and fractional numbers
                if rng.gen_bool(0.5) {
                    Value::Number(rng.gen_range_u32(1_000_000) as f64)
                } else {
                    Value::Number(rng.gen_f64() * 2e6 - 1e6)
                }
            }
            3 => {
                let len = rng.gen_range_usize(12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.gen_range_u32(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' {
                            c as char
                        } else {
                            '\\'
                        }
                    })
                    .collect();
                Value::String(s)
            }
            4 => Value::Array(
                (0..rng.gen_range_usize(5))
                    .map(|_| random_value(rng, depth + 1))
                    .collect(),
            ),
            _ => {
                let mut map = std::collections::BTreeMap::new();
                for i in 0..rng.gen_range_usize(5) {
                    map.insert(format!("k{i}"), random_value(rng, depth + 1));
                }
                Value::Object(map)
            }
        }
    }

    check("json-roundtrip", |rng| {
        let v = random_value(rng, 0);
        let s = to_string(&v);
        let back = parse(&s).map_err(|e| format!("{e} in {s}"))?;
        if back != v {
            return Err(format!("roundtrip mismatch: {s}"));
        }
        Ok(())
    });
}
