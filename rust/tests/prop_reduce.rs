//! Reduce-equivalence property tests: the parameter-sharded merge must be
//! *bitwise-identical* to the single-threaded reference for any shard
//! count, payload kind, and submission order — determinism is what makes
//! the multi-threaded reduce a pure perf change (DESIGN.md).

use mlitb::coordinator::Payload;
use mlitb::params::{
    AggregationMode, GradAccumulator, GradView, RobustCombiner, ShardedAccumulator,
};
use mlitb::rng::Pcg32;
use mlitb::testing::{check, gen};

/// One random iteration's worth of submissions: (gradient, examples).
fn gen_submissions(rng: &mut Pcg32, dim: usize, n: usize) -> Vec<(Vec<f32>, u64)> {
    (0..n)
        .map(|_| {
            let g = gen::f32_vec(rng, dim);
            let examples = gen::usize_in(rng, 0, 40) as u64;
            (g, examples)
        })
        .collect()
}

/// Single-threaded reference: dense adds in submission order.
fn reference_average(dim: usize, subs: &[(Vec<f32>, u64)]) -> Vec<f32> {
    let mut acc = GradAccumulator::new(dim);
    for (g, n) in subs {
        acc.add(g, *n);
    }
    acc.weighted_average()
}

#[test]
fn dense_sparse_and_sharded_averages_are_bitwise_identical() {
    check("reduce dense/sparse/sharded equivalence", |rng| {
        let dim = gen::usize_in(rng, 1, 257);
        let n = gen::usize_in(rng, 0, 7);
        let subs = gen_submissions(rng, dim, n);
        let want = reference_average(dim, &subs);

        // Sparse with keep-everything carries all coordinates in index
        // order — the add order per element matches the dense reference.
        let sparse_payloads: Vec<Payload> = subs
            .iter()
            .map(|(g, _)| Payload::sparsify(g, 1.0))
            .collect();
        let mut sparse_acc = GradAccumulator::new(dim);
        for (p, (_, examples)) in sparse_payloads.iter().zip(&subs) {
            let Payload::Sparse(entries) = p else { panic!() };
            sparse_acc.add_sparse(entries, *examples);
        }
        if sparse_acc.weighted_average() != want {
            return Err("sparse(keep=1.0) differs from dense reference".into());
        }

        for shards in [1usize, 2, 4, 7] {
            let mut acc = ShardedAccumulator::new(dim, shards);
            let batch: Vec<(GradView<'_>, u64)> = subs
                .iter()
                .map(|(g, examples)| (GradView::Dense(g.as_slice()), *examples))
                .collect();
            acc.merge(&batch);
            if acc.weighted_average() != want {
                return Err(format!("sharded S={shards} dense differs (dim={dim}, n={n})"));
            }

            let mut acc = ShardedAccumulator::new(dim, shards);
            let batch: Vec<(GradView<'_>, u64)> = sparse_payloads
                .iter()
                .zip(&subs)
                .map(|(p, (_, examples))| (p.as_view(), *examples))
                .collect();
            acc.merge(&batch);
            if acc.weighted_average() != want {
                return Err(format!("sharded S={shards} sparse differs (dim={dim}, n={n})"));
            }
        }
        Ok(())
    });
}

#[test]
fn partial_sparse_payloads_route_identically_across_shard_counts() {
    // Top-k payloads (keep < 1) aren't equal to the dense reduce, but all
    // shard counts must agree with the single-threaded sparse reference.
    check("partial sparse shard-routing equivalence", |rng| {
        let dim = gen::usize_in(rng, 2, 300);
        let n = gen::usize_in(rng, 1, 6);
        let subs = gen_submissions(rng, dim, n);
        let keep = 0.05 + 0.9 * rng.gen_f64();
        let payloads: Vec<Payload> = subs
            .iter()
            .map(|(g, _)| Payload::sparsify(g, keep))
            .collect();

        let mut reference = GradAccumulator::new(dim);
        for (p, (_, examples)) in payloads.iter().zip(&subs) {
            let Payload::Sparse(entries) = p else { panic!() };
            reference.add_sparse(entries, *examples);
        }
        let want = reference.weighted_average();

        for shards in [1usize, 2, 4, 7] {
            let mut acc = ShardedAccumulator::new(dim, shards);
            let batch: Vec<(GradView<'_>, u64)> = payloads
                .iter()
                .zip(&subs)
                .map(|(p, (_, examples))| (p.as_view(), *examples))
                .collect();
            acc.merge(&batch);
            if acc.weighted_average() != want {
                return Err(format!(
                    "S={shards} disagrees with sparse reference (dim={dim}, keep={keep:.3})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn empty_and_single_worker_cases() {
    for shards in [1usize, 2, 4, 7] {
        // Empty iteration: zeros, no contributions.
        let mut acc = ShardedAccumulator::new(10, shards);
        acc.merge(&[]);
        assert!(acc.is_empty());
        assert_eq!(acc.weighted_average(), vec![0.0; 10]);

        // Single worker: average = grad / examples.
        let g: Vec<f32> = (0..10).map(|i| i as f32 - 4.5).collect();
        let mut acc = ShardedAccumulator::new(10, shards);
        acc.merge(&[(GradView::Dense(&g), 2)]);
        let want: Vec<f32> = g.iter().map(|x| x * 0.5).collect();
        assert_eq!(acc.weighted_average(), want, "S={shards}");
    }
}

#[test]
fn non_dividing_shard_counts_cover_every_parameter() {
    // dim not divisible by S: boundaries still partition exactly.
    for (dim, shards) in [(11usize, 4usize), (13, 7), (5, 2), (7, 7), (6, 4)] {
        let g = vec![1.0f32; dim];
        let mut acc = ShardedAccumulator::new(dim, shards);
        acc.merge(&[(GradView::Dense(&g), 1)]);
        assert_eq!(
            acc.weighted_average(),
            vec![1.0; dim],
            "dim={dim} S={shards}"
        );
        let bounds = acc.shard_bounds();
        assert_eq!(*bounds.first().unwrap(), 0);
        assert_eq!(*bounds.last().unwrap(), dim);
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "{bounds:?}");
    }
}

#[test]
fn nan_gradients_flow_through_sparsify_and_merge_without_panicking() {
    // A diverged worker (NaN coordinates) must not kill the reduce path:
    // sparsify selects without panicking and the merge propagates the NaN.
    // This pins the *raw accumulator* behavior — the master never lets a
    // non-finite payload reach it (the sanitation gate quarantines the
    // submission and strikes the worker before the merge; see
    // coordinator::master and DESIGN.md "Robustness"), so NaN surfacing
    // here is the substrate contract, not the production outcome.
    let mut g = vec![0.5f32; 64];
    g[7] = f32::NAN;
    g[33] = f32::INFINITY;
    let payload = Payload::sparsify(&g, 0.25);
    let Payload::Sparse(entries) = &payload else {
        panic!()
    };
    assert!(entries.iter().any(|(_, v)| v.is_nan()));
    let mut acc = ShardedAccumulator::new(64, 4);
    acc.merge(&[(payload.as_view(), 1)]);
    let avg = acc.weighted_average();
    assert!(avg[7].is_nan(), "NaN must surface at the raw accumulator");
}

#[test]
fn robust_aggregation_is_bitwise_identical_across_shard_counts() {
    // The robust estimators must be a pure perf change too: for any mode,
    // shard count, payload mix and dimension, the sharded per-range
    // combination equals the serial single-range reference bit for bit.
    check("robust sharded/serial equivalence", |rng| {
        let dim = gen::usize_in(rng, 1, 200);
        let n = gen::usize_in(rng, 1, 6);
        let subs = gen_submissions(rng, dim, n);
        let keep = 0.05 + 0.9 * rng.gen_f64();
        let payloads: Vec<Payload> = subs
            .iter()
            .enumerate()
            .map(|(i, (g, _))| {
                // Mix dense and top-k sparse rows in one batch.
                if i % 2 == 0 {
                    Payload::dense(g.clone())
                } else {
                    Payload::sparsify(g, keep)
                }
            })
            .collect();
        let batch: Vec<(GradView<'_>, u64)> = payloads
            .iter()
            .zip(&subs)
            .map(|(p, (_, examples))| (p.as_view(), *examples))
            .collect();

        let modes = [
            AggregationMode::TrimmedMean { k: 1 },
            AggregationMode::CoordinateMedian,
            AggregationMode::ClipByNorm { max_norm: 0.75 },
        ];
        for mode in modes {
            let mut want = vec![0.0f32; dim];
            RobustCombiner::new(mode, &batch).combine_range(&batch, 0, &mut want);
            for shards in [1usize, 2, 4, 7] {
                let acc = ShardedAccumulator::new(dim, shards);
                let mut got = vec![0.0f32; dim];
                acc.robust_aggregate_into(mode, &batch, &mut got);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                if bits(&got) != bits(&want) {
                    return Err(format!(
                        "{} S={shards} differs from serial (dim={dim}, n={n})",
                        mode.name()
                    ));
                }
            }
        }
        Ok(())
    });
}
