//! Integration: the serving subsystem end-to-end — closure → registry →
//! routing → admission/coalescing/batching → execution → per-request log
//! — on the modeled predictor (no artifacts needed; the path is
//! `Compute`-generic).

use mlitb::model::{init_params, ResearchClosure};
use mlitb::netsim::LinkProfile;
use mlitb::runtime::ModeledCompute;
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, ControlPlane, FleetConfig, ProjectId, RequestFleet,
    RouterConfig, RoutingPolicy, ServeConfig, ServeReport, ServeSim, ServerProfile,
};

fn plane_from_closure() -> ControlPlane {
    let spec = demo_spec();
    let mut closure = ResearchClosure::new(&spec, &init_params(&spec, 3));
    closure.iteration = 500;
    closure.notes = "integration".into();
    let mut plane = ControlPlane::single(spec);
    plane
        .registry_mut(ProjectId::new(0))
        .publish_closure(&closure, 0.0)
        .expect("publish");
    plane
}

fn config(max_batch: usize, cache: usize) -> ServeConfig {
    ServeConfig {
        fleets: vec![FleetConfig {
            groups: vec![
                ClientSpec { link: LinkProfile::Lan, rate_rps: 6.0, count: 3 },
                ClientSpec { link: LinkProfile::Wifi, rate_rps: 4.0, count: 3 },
                ClientSpec { link: LinkProfile::Cellular, rate_rps: 2.0, count: 2 },
            ],
            duration_s: 8.0,
            input_pool: 48,
            seed: 21,
        }],
        policy: BatchPolicy {
            max_batch,
            max_wait_ms: if max_batch == 1 { 0.0 } else { 5.0 },
            queue_depth: 256,
        },
        server: ServerProfile::default(),
        router: RouterConfig::single(),
        shard_profiles: Vec::new(),
        drained_shards: Vec::new(),
        cache_capacity: cache,
        response_bytes: 256,
        keep_log: true,
    }
}

fn run(cfg: ServeConfig) -> ServeReport {
    let mut compute = ModeledCompute {
        param_count: demo_spec().param_count,
    };
    let mut sim = ServeSim::new(cfg, plane_from_closure(), &mut compute);
    sim.run().expect("serve run")
}

/// Sorted (id, class) pairs — the answer-identity fingerprint.
fn classes(r: &ServeReport) -> Vec<(u64, u32)> {
    let mut v: Vec<(u64, u32)> = r.log.records().iter().map(|x| (x.id, x.class)).collect();
    v.sort_unstable();
    v
}

#[test]
fn closure_to_served_requests_end_to_end() {
    let report = run(config(32, 256));
    assert!(report.offered > 50, "{}", report.summary());
    assert_eq!(report.completed + report.rejected, report.offered);
    assert_eq!(report.rejected, 0, "no shedding at this load");
    assert!(report.span_s >= report.duration_s * 0.5);
    assert!(report.throughput_rps() > 0.0);
    // Every record is causally sane.
    for r in report.log.records() {
        assert!(r.done_ms > r.sent_ms, "{r:?}");
        assert!((r.latency_ms - (r.done_ms - r.sent_ms)).abs() < 1e-9);
        assert!((r.class as usize) < demo_spec().classes);
    }
    // CSV export carries one line per request + header.
    assert_eq!(
        report.log.to_csv().lines().count(),
        report.completed as usize + 1
    );
}

#[test]
fn batched_serving_matches_unbatched_predictions() {
    // The PR-1 acceptance criterion: identical per-request answers with
    // micro-batching on (≤32) and off (=1).  Cache disabled so every
    // request actually executes.
    let collect = |max_batch: usize| {
        let report = run(config(max_batch, 0));
        assert_eq!(report.rejected, 0);
        classes(&report)
    };
    let unbatched = collect(1);
    let batched = collect(32);
    assert!(!unbatched.is_empty());
    assert_eq!(unbatched, batched, "micro-batching changed served answers");
}

#[test]
fn cached_answers_match_executed_ones() {
    // With a cache, a repeated input's hit must serve the same class its
    // original execution did — compare against a cache-off run.
    let with_cache = run(config(32, 1024));
    let without = run(config(32, 0));
    assert!(with_cache.cache_hits > 0, "{}", with_cache.summary());
    assert_eq!(classes(&with_cache), classes(&without));
}

#[test]
fn routed_and_coalesced_answers_match_single_shard_baseline() {
    // This PR's acceptance criterion (answer-preserving routing): for the
    // same fleet seed, every combination of shard count, routing policy
    // and coalescing serves exactly the same (id → class) map as the
    // single-shard uncoalesced baseline — and completes the same request
    // set (no shedding at this load).
    let mut base_cfg = config(32, 0);
    base_cfg.fleets[0].input_pool = 12; // duplicate-heavy: coalescing engages
    let baseline = run(base_cfg.clone());
    assert_eq!(baseline.rejected, 0);
    let expect = classes(&baseline);
    assert!(!expect.is_empty());
    for policy in [
        RoutingPolicy::RoundRobin,
        RoutingPolicy::JoinShortestQueue,
        RoutingPolicy::InputAffinity,
    ] {
        for coalesce in [false, true] {
            for cache in [0usize, 512] {
                let mut cfg = base_cfg.clone();
                cfg.cache_capacity = cache;
                cfg.router = RouterConfig {
                    shards: 3,
                    policy,
                    coalesce,
                    autotune: coalesce, // exercise autotune on half the grid
                    ..RouterConfig::single()
                };
                let routed = run(cfg);
                assert_eq!(routed.rejected, 0, "{}", routed.summary());
                assert_eq!(
                    classes(&routed),
                    expect,
                    "policy {} coalesce {coalesce} cache {cache} changed answers",
                    policy.name()
                );
                // Full accounting: hits + waiters + executed = completed.
                assert_eq!(
                    routed.batch_examples + routed.cache_hits + routed.coalesced,
                    routed.completed,
                    "{}",
                    routed.summary()
                );
            }
        }
    }
}

#[test]
fn coalescing_reduces_executed_examples_on_duplicates() {
    let mut cfg = config(32, 0);
    cfg.fleets[0].input_pool = 4;
    cfg.fleets[0].groups[0].rate_rps = 60.0; // push duplicates into flight
    let off = run(cfg.clone());
    cfg.router.coalesce = true;
    let on = run(cfg);
    assert_eq!(off.rejected, 0);
    assert_eq!(on.rejected, 0);
    assert_eq!(on.completed, off.completed);
    assert!(on.coalesced > 0, "{}", on.summary());
    assert!(
        on.batch_examples < off.batch_examples,
        "coalescing must cut executions: on {} vs off {}",
        on.summary(),
        off.summary()
    );
    assert_eq!(classes(&on), classes(&off));
}

#[test]
fn shedding_reconciles_per_client() {
    // Overload a tiny queue and check the previously-invisible sheds are
    // fully attributed: per client, offered = completed + rejected.
    let mut cfg = config(32, 0);
    for g in &mut cfg.fleets[0].groups {
        g.rate_rps = 400.0;
    }
    cfg.policy.queue_depth = 8;
    cfg.fleets[0].duration_s = 1.5; // overload: keep the executed volume modest
    let fleet = RequestFleet::generate(ProjectId::new(0), &cfg.fleets[0], &demo_spec());
    let report = run(cfg);
    assert!(report.rejected > 0, "{}", report.summary());
    assert_eq!(report.completed + report.rejected, report.offered);
    assert_eq!(report.log.rejections().len() as u64, report.rejected);
    let n_clients = fleet.links.len() as u32;
    let mut offered_by_client = vec![0u64; n_clients as usize];
    for e in &fleet.events {
        offered_by_client[e.client as usize] += 1;
    }
    let mut completed_by_client = vec![0u64; n_clients as usize];
    for r in report.log.records() {
        completed_by_client[r.client as usize] += 1;
    }
    let rejected_by_client = report.log.rejections_by_client();
    for c in 0..n_clients {
        let rejected = rejected_by_client.get(&c).copied().unwrap_or(0);
        assert_eq!(
            completed_by_client[c as usize] + rejected,
            offered_by_client[c as usize],
            "client {c} does not reconcile"
        );
    }
}

#[test]
fn serving_is_deterministic_per_seed() {
    let a = run(config(32, 128));
    let b = run(config(32, 128));
    assert_eq!(a.log.to_csv(), b.log.to_csv());
    assert_eq!(a.summary(), b.summary());
}
