//! Integration: the serving subsystem end-to-end — closure → registry →
//! admission/batching → execution → per-request log — on the modeled
//! predictor (no artifacts needed; the path is `Compute`-generic).

use mlitb::model::{init_params, ResearchClosure};
use mlitb::netsim::LinkProfile;
use mlitb::runtime::ModeledCompute;
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, FleetConfig, ServeConfig, ServeReport, ServeSim,
    ServerProfile, SnapshotRegistry,
};

fn registry_from_closure() -> SnapshotRegistry {
    let spec = demo_spec();
    let mut closure = ResearchClosure::new(&spec, &init_params(&spec, 3));
    closure.iteration = 500;
    closure.notes = "integration".into();
    let mut registry = SnapshotRegistry::new(spec);
    registry.publish_closure(&closure, 0.0).expect("publish");
    registry
}

fn config(max_batch: usize, cache: usize) -> ServeConfig {
    ServeConfig {
        fleet: FleetConfig {
            groups: vec![
                ClientSpec { link: LinkProfile::Lan, rate_rps: 6.0, count: 3 },
                ClientSpec { link: LinkProfile::Wifi, rate_rps: 4.0, count: 3 },
                ClientSpec { link: LinkProfile::Cellular, rate_rps: 2.0, count: 2 },
            ],
            duration_s: 8.0,
            input_pool: 48,
            seed: 21,
        },
        policy: BatchPolicy {
            max_batch,
            max_wait_ms: if max_batch == 1 { 0.0 } else { 5.0 },
            queue_depth: 256,
        },
        server: ServerProfile::default(),
        cache_capacity: cache,
        response_bytes: 256,
    }
}

fn run(cfg: ServeConfig) -> ServeReport {
    let mut compute = ModeledCompute {
        param_count: demo_spec().param_count,
    };
    let mut sim = ServeSim::new(cfg, registry_from_closure(), &mut compute);
    sim.run().expect("serve run")
}

#[test]
fn closure_to_served_requests_end_to_end() {
    let report = run(config(32, 256));
    assert!(report.offered > 50, "{}", report.summary());
    assert_eq!(report.completed + report.rejected, report.offered);
    assert_eq!(report.rejected, 0, "no shedding at this load");
    assert!(report.span_s >= report.duration_s * 0.5);
    assert!(report.throughput_rps() > 0.0);
    // Every record is causally sane.
    for r in report.log.records() {
        assert!(r.done_ms > r.sent_ms, "{r:?}");
        assert!((r.latency_ms - (r.done_ms - r.sent_ms)).abs() < 1e-9);
        assert!((r.class as usize) < demo_spec().classes);
    }
    // CSV export carries one line per request + header.
    assert_eq!(
        report.log.to_csv().lines().count(),
        report.completed as usize + 1
    );
}

#[test]
fn batched_serving_matches_unbatched_predictions() {
    // The PR's acceptance criterion: identical per-request answers with
    // micro-batching on (≤32) and off (=1).  Cache disabled so every
    // request actually executes.
    let collect = |max_batch: usize| {
        let report = run(config(max_batch, 0));
        assert_eq!(report.rejected, 0);
        let mut by_id: Vec<(u64, u32)> = report
            .log
            .records()
            .iter()
            .map(|r| (r.id, r.class))
            .collect();
        by_id.sort_unstable();
        by_id
    };
    let unbatched = collect(1);
    let batched = collect(32);
    assert!(!unbatched.is_empty());
    assert_eq!(unbatched, batched, "micro-batching changed served answers");
}

#[test]
fn cached_answers_match_executed_ones() {
    // With a cache, a repeated input's hit must serve the same class its
    // original execution did — compare against a cache-off run.
    let with_cache = run(config(32, 1024));
    let without = run(config(32, 0));
    assert!(with_cache.cache_hits > 0, "{}", with_cache.summary());
    let classes = |r: &ServeReport| {
        let mut v: Vec<(u64, u32)> = r.log.records().iter().map(|x| (x.id, x.class)).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(classes(&with_cache), classes(&without));
}

#[test]
fn serving_is_deterministic_per_seed() {
    let a = run(config(32, 128));
    let b = run(config(32, 128));
    assert_eq!(a.log.to_csv(), b.log.to_csv());
    assert_eq!(a.summary(), b.summary());
}
