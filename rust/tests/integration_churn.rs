//! Fleet-churn and robustness integration drills (paper §3.2/§3.3b plus
//! the fault-injection plane): clients join and leave while training
//! runs, storms disconnect half the fleet, adversaries upload poison —
//! and the allocation invariants, quorum barrier and robust aggregation
//! must hold through all of it.  Promoted from `examples/churn.rs` so CI
//! actually executes the schedules instead of shipping them as prose.

use mlitb::client::DeviceClass;
use mlitb::faults::FaultProfile;
use mlitb::model::{ModelSpec, TensorSpec};
use mlitb::params::{AggregationMode, OptimizerKind};
use mlitb::runtime::{DriftingCompute, ModeledCompute};
use mlitb::sim::{ChurnEvent, SimConfig, Simulation};

fn toy_spec(param_count: usize) -> ModelSpec {
    ModelSpec {
        name: "toy".into(),
        param_count,
        batch_size: 16,
        micro_batches: vec![16],
        input: vec![28, 28, 1],
        classes: 10,
        tensors: vec![TensorSpec {
            name: "w".into(),
            shape: vec![param_count],
            offset: 0,
            size: param_count,
            fan_in: 4,
        }],
        artifacts: Default::default(),
    }
}

fn base_cfg(n: usize, spec: &ModelSpec) -> SimConfig {
    let mut cfg = SimConfig::paper_scaling(n, spec);
    cfg.train_size = 800;
    cfg.test_size = 64;
    cfg.iterations = 8;
    cfg.master.capacity = 200;
    cfg
}

/// Step the sim to completion, checking the allocation invariants and
/// the no-data-loss identity after *every* iteration (not just at the
/// end — a transient violation mid-churn must fail the run).
fn run_checked(sim: &mut Simulation<'_>, iterations: u64) {
    for i in 0..iterations {
        sim.step().unwrap();
        let alloc = sim.master().allocator();
        alloc
            .check_invariants()
            .unwrap_or_else(|e| panic!("iteration {i}: {e}"));
        assert_eq!(
            alloc.allocated_count() + alloc.unallocated().len(),
            alloc.total_data(),
            "iteration {i}: data ids lost or duplicated across churn"
        );
    }
}

#[test]
fn scripted_churn_preserves_allocation_invariants() {
    // The example's schedule: phones join at 4 and 8, a workstation dies
    // at 12, two more devices join at 16 — 24 iterations of churn with
    // the pie-cutter reacting each time.
    let spec = toy_spec(8);
    let mut cfg = base_cfg(2, &spec);
    cfg.train_size = 2_000;
    cfg.iterations = 24;
    cfg.master.capacity = 600;
    cfg.seed = 3;
    cfg.churn.insert(4, vec![ChurnEvent::Join(DeviceClass::Mobile)]);
    cfg.churn.insert(8, vec![ChurnEvent::Join(DeviceClass::Mobile)]);
    cfg.churn.insert(12, vec![ChurnEvent::Leave(1)]);
    cfg.churn.insert(
        16,
        vec![
            ChurnEvent::Join(DeviceClass::Laptop),
            ChurnEvent::Join(DeviceClass::Workstation),
        ],
    );
    let mut compute = ModeledCompute { param_count: 8 };
    let mut sim = Simulation::new(cfg, spec, &mut compute);
    assert_eq!(sim.n_clients(), 2);
    run_checked(&mut sim, 24);
    // 2 start + 2 phones − 1 dead + 2 late joiners.
    assert_eq!(sim.n_clients(), 5);
    assert_eq!(sim.master().timeline().len(), 24);
    // The dead workstation's shard was redistributed, not dropped.
    assert!(sim.master().allocator().transfer_count() > 0);
}

#[test]
fn storm_profile_with_churn_completes_and_keeps_data() {
    // Correlated disconnect storms on top of scripted churn: workers that
    // are down contribute nothing for the burst, but their data ownership
    // (and the fleet bookkeeping) must survive untouched.
    let spec = toy_spec(8);
    let mut cfg = base_cfg(6, &spec);
    cfg.iterations = 18; // crosses storms at 8..10 and 16..18
    cfg.seed = 2;
    cfg.faults = FaultProfile::parse("storm").unwrap();
    cfg.churn.insert(5, vec![ChurnEvent::Join(DeviceClass::Laptop)]);
    cfg.churn.insert(11, vec![ChurnEvent::Leave(2)]);
    let mut compute = DriftingCompute { param_count: 8 };
    let mut sim = Simulation::new(cfg, spec, &mut compute);
    run_checked(&mut sim, 18);
    assert_eq!(sim.master().timeline().len(), 18);
    assert!(sim.master().params().iter().all(|p| p.is_finite()));
    // Honest-but-flaky fleet: nobody gets evicted, training progresses.
    assert_eq!(sim.n_clients(), 6);
}

#[test]
fn quorum_beats_strict_sync_under_stragglers() {
    // flaky @ seed 2 makes workers {1, 6} of the 6-worker fleet 3×
    // stragglers (pinned by the seeded plan).  Strict sync waits for
    // them every iteration; quorum 0.5 closes the barrier at the 3rd
    // completion and carries the stragglers over — same schedules, same
    // fleet, strictly less virtual wall time.
    let spec = toy_spec(8);
    let run = |quorum: f64| {
        let mut cfg = base_cfg(6, &spec);
        cfg.seed = 2;
        cfg.faults = FaultProfile::parse("flaky").unwrap();
        cfg.master.quorum = quorum;
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec.clone(), &mut compute);
        let report = sim.run().unwrap();
        sim.master().allocator().check_invariants().unwrap();
        report
    };
    let strict = run(0.0);
    let quorum = run(0.5);
    assert_eq!(strict.timeline.len(), 8);
    assert_eq!(quorum.timeline.len(), 8);
    assert!(
        quorum.virtual_secs < strict.virtual_secs,
        "quorum 0.5 must release the barrier early: {:.1}s !< {:.1}s",
        quorum.virtual_secs,
        strict.virtual_secs
    );
}

/// One attack run: 10 workstations, seed 1 (adversaries pinned to
/// workers {1, 6, 7} — exactly 3 of 10), SGD so the trajectory algebra
/// is transparent.  Returns the final test error.
fn attack_run(profile: &str, aggregation: AggregationMode) -> f64 {
    let spec = toy_spec(8);
    let mut cfg = base_cfg(10, &spec);
    cfg.iterations = 20;
    cfg.seed = 1;
    cfg.master.optimizer = OptimizerKind::Sgd;
    cfg.master.learning_rate = 0.1;
    cfg.master.aggregation = aggregation;
    cfg.faults = FaultProfile::parse(profile).unwrap();
    let mut compute = DriftingCompute { param_count: 8 };
    let mut sim = Simulation::new(cfg, spec, &mut compute);
    for _ in 0..20 {
        sim.step().unwrap();
    }
    sim.master().allocator().check_invariants().unwrap();
    sim.evaluate_test_error().unwrap()
}

#[test]
fn robust_aggregation_survives_a_30_percent_hostile_fleet() {
    // The paper's Fig-5-style headline for this PR: 3 of 10 workers
    // upload gradients scaled by −8.  Under the paper's plain mean the
    // effective step gradient flips sign (×−1.7) and training diverges;
    // trimmed mean (k = 3) and coordinate-median discard the poison per
    // coordinate and track the clean trajectory.
    let clean = attack_run("none", AggregationMode::Mean);
    let mean_attacked = attack_run("hostile:0.3:scaled:-8", AggregationMode::Mean);
    let trimmed = attack_run("hostile:0.3:scaled:-8", AggregationMode::TrimmedMean { k: 3 });
    let median = attack_run("hostile:0.3:scaled:-8", AggregationMode::CoordinateMedian);

    assert!(clean < 0.2, "clean baseline failed to converge: {clean}");
    assert!(
        mean_attacked > 0.6,
        "mean under attack should diverge: {mean_attacked}"
    );
    // Honest workers all see the same broadcast parameters, so trimming
    // the 3 poisoned rows recovers the clean per-coordinate gradient
    // (up to f32 rounding in a different summation order).
    assert!(
        (trimmed - clean).abs() < 0.02,
        "trimmed mean should track clean: {trimmed} vs {clean}"
    );
    assert!(
        (median - clean).abs() < 0.02,
        "median should track clean: {median} vs {clean}"
    );
}

#[test]
fn equal_seeds_mean_identical_fault_plans_and_parameters() {
    // The determinism acceptance gate: the fault plan is a pure function
    // of (profile, seed), and the whole attacked run — injection,
    // quarantine, aggregation — replays bit-for-bit under an equal seed.
    let spec = toy_spec(8);
    let run = |seed: u64| {
        let mut cfg = base_cfg(10, &spec);
        cfg.iterations = 10;
        cfg.seed = seed;
        cfg.master.optimizer = OptimizerKind::Sgd;
        cfg.master.learning_rate = 0.1;
        cfg.master.aggregation = AggregationMode::TrimmedMean { k: 3 };
        cfg.faults = FaultProfile::parse("hostile:0.3:scaled:-8").unwrap();
        let mut compute = DriftingCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec.clone(), &mut compute);
        let workers: Vec<u64> = (1..=10).collect();
        let plan_digest = sim.fault_plan().digest(&workers, 10);
        for _ in 0..10 {
            sim.step().unwrap();
        }
        let bits: Vec<u32> = sim.master().params().iter().map(|p| p.to_bits()).collect();
        (plan_digest, bits)
    };
    let (plan_a, params_a) = run(1);
    let (plan_b, params_b) = run(1);
    assert_eq!(plan_a, plan_b, "equal seed must mean an equal fault plan");
    assert_eq!(params_a, params_b, "equal seed must mean identical params");
    // Seed 2 draws a different adversary set ({8} vs {1, 6, 7}), so the
    // plan digest — and with it the trajectory — must move.
    let (plan_c, _) = run(2);
    assert_ne!(plan_a, plan_c, "different seeds must diverge the plan");
}
