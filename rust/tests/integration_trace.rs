//! Integration: the unified virtual-clock trace plane over a full
//! co-simulation — determinism (same seed+config ⇒ byte-identical
//! exports), span-balance invariants, the publication → first-serve
//! causal flow, and the Perfetto/Chrome trace-event JSON shape.

use std::collections::BTreeMap;

use mlitb::cosim::{run_cosim_traced, CosimConfig, CosimProject, PublicationPolicy};
use mlitb::json;
use mlitb::model::ModelSpec;
use mlitb::netsim::LinkProfile;
use mlitb::runtime::{Compute, DriftingCompute, ModeledCompute};
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, FleetConfig, RouterConfig, RoutingPolicy, ServeConfig,
    ServerProfile,
};
use mlitb::sim::SimConfig;
use mlitb::trace::analyze::TraceAnalysis;
use mlitb::trace::{ArgValue, Event, EventKind, TraceHandle};

fn serve_config(duration_s: f64, seed: u64) -> ServeConfig {
    ServeConfig {
        fleets: vec![FleetConfig {
            groups: vec![
                ClientSpec { link: LinkProfile::Lan, rate_rps: 8.0, count: 3 },
                ClientSpec { link: LinkProfile::Wifi, rate_rps: 5.0, count: 3 },
            ],
            duration_s,
            input_pool: 32,
            seed,
        }],
        policy: BatchPolicy {
            max_batch: 32,
            max_wait_ms: 5.0,
            queue_depth: 512,
        },
        server: ServerProfile::default(),
        router: RouterConfig {
            shards: 2,
            policy: RoutingPolicy::JoinShortestQueue,
            coalesce: true,
            ..RouterConfig::single()
        },
        shard_profiles: Vec::new(),
        drained_shards: Vec::new(),
        cache_capacity: 256,
        response_bytes: 256,
        keep_log: false,
    }
}

fn train_config(spec: &ModelSpec, iterations: u64, seed: u64) -> SimConfig {
    let mut train = SimConfig::paper_scaling(2, spec);
    train.iterations = iterations;
    train.train_size = 600;
    train.test_size = 128;
    train.track_every = 1;
    train.master.iter_duration_s = 2.0;
    train.seed = seed;
    train
}

fn cosim_config(iterations: u64, seed: u64) -> CosimConfig {
    let spec = demo_spec();
    CosimConfig {
        projects: vec![CosimProject {
            train: train_config(&spec, iterations, seed),
            spec,
            publish: PublicationPolicy::every(2),
            retain: 2,
            weight: 1.0,
        }],
        serve: serve_config(iterations as f64 * 2.0, seed ^ 0xC0517),
        egress_bytes_per_min: 0.0,
        measure_delta: false,
    }
}

/// Run a traced co-simulation, returning the trace handle.
fn run_traced(cfg: &CosimConfig) -> TraceHandle {
    let mut train_computes: Vec<DriftingCompute> = cfg
        .projects
        .iter()
        .map(|p| DriftingCompute { param_count: p.spec.param_count })
        .collect();
    let train_refs: Vec<&mut dyn Compute> = train_computes
        .iter_mut()
        .map(|c| c as &mut dyn Compute)
        .collect();
    let mut serve_compute = ModeledCompute {
        param_count: cfg.projects[0].spec.param_count,
    };
    let trace = TraceHandle::recording();
    run_cosim_traced(cfg, train_refs, &mut serve_compute, trace.clone()).expect("cosim run");
    trace
}

#[test]
fn trace_export_is_byte_identical_across_seeded_runs() {
    let cfg = cosim_config(6, 7);
    let a = run_traced(&cfg);
    let b = run_traced(&cfg);
    assert!(!a.is_empty());
    assert_eq!(a.export_chrome_json(), b.export_chrome_json());
    assert_eq!(a.export_csv(), b.export_csv());
    // A different seed must actually diverge — the determinism assertion
    // above is vacuous if the export ignores the run.
    let c = run_traced(&cosim_config(6, 8));
    assert_ne!(a.export_chrome_json(), c.export_chrome_json());
}

#[test]
fn spans_balance_and_all_planes_are_present() {
    let trace = run_traced(&cosim_config(6, 7));
    let evs = trace.snapshot();
    assert_eq!(trace.dropped(), 0, "test run must fit the ring");
    assert_eq!(trace.open_async(), 0, "every request span must close");

    // All three planes landed on the one timeline.
    for (cat, name) in [
        ("train", "iteration"),
        ("train", "compute"),
        ("train", "ingest"),
        ("serve", "request"),
        ("serve", "batch"),
        ("publish", "publish"),
        ("publish", "activate"),
    ] {
        assert!(
            evs.iter().any(|e| e.cat == cat && e.name == name),
            "missing {cat}/{name} events"
        );
    }

    // Every request id opens exactly once and closes exactly once, with
    // exactly one terminal outcome tag.
    let mut begins: BTreeMap<u64, u64> = BTreeMap::new();
    let mut ends: BTreeMap<u64, u64> = BTreeMap::new();
    for e in evs.iter().filter(|e| e.name == "request") {
        match e.kind {
            EventKind::AsyncBegin { id } => *begins.entry(id).or_default() += 1,
            EventKind::AsyncEnd { id } => {
                *ends.entry(id).or_default() += 1;
                let outcome = e
                    .args
                    .iter()
                    .find(|(k, _)| *k == "outcome")
                    .map(|(_, v)| v.to_string())
                    .expect("request end carries an outcome");
                assert!(
                    ["served", "shed", "coalesced"].contains(&outcome.as_str()),
                    "unexpected outcome {outcome}"
                );
            }
            _ => panic!("request events are async begin/end only"),
        }
    }
    assert!(!begins.is_empty());
    assert_eq!(begins, ends, "unbalanced request spans");
    assert!(begins.values().all(|&n| n == 1), "request id reused");

    // Span timestamps never run backwards within a track's seq order is
    // not required (multiple tracks interleave), but no event may sit at
    // a negative virtual time.
    assert!(evs.iter().all(|e| e.ts_ms >= 0.0));
}

#[test]
fn publication_flow_reaches_a_served_batch() {
    let trace = run_traced(&cosim_config(6, 7));
    let evs = trace.snapshot();
    let start_of = |id: u64| -> Option<&Event> {
        evs.iter().find(|e| e.kind == EventKind::FlowStart { id })
    };
    let finishes: Vec<&Event> = evs
        .iter()
        .filter(|e| matches!(e.kind, EventKind::FlowFinish { .. }))
        .collect();
    assert!(
        !finishes.is_empty(),
        "at least one publication must be picked up by a served batch"
    );
    for f in finishes {
        let EventKind::FlowFinish { id } = f.kind else { unreachable!() };
        let s = start_of(id).expect("flow finish without start");
        assert!(f.ts_ms >= s.ts_ms, "flow arrow runs backwards in time");
        assert_eq!(s.cat, "publish");
        assert_eq!(f.cat, "publish");
        // The arrow lands on a serving-shard track (tid 2000+s), i.e. the
        // publication is causally linked to request service, not to
        // another publisher event.
        assert!(f.track.tid >= 2000, "flow must finish on a shard track");
        assert_eq!(s.track.tid, 1, "flow must start on the publisher track");
    }
}

#[test]
fn chrome_export_is_valid_trace_event_json() {
    let trace = run_traced(&cosim_config(4, 7));
    let doc = json::parse(&trace.export_chrome_json()).expect("export must parse");
    assert_eq!(doc.req_str("displayTimeUnit").unwrap(), "ms");
    let events = doc.req_array("traceEvents").unwrap();
    assert!(!events.is_empty());

    // Nestable-async begin/end balance per (pid, cat, id), as Perfetto
    // matches them; flow finishes must carry the binding point.
    let mut open: BTreeMap<(f64, String, f64), i64> = BTreeMap::new();
    let mut flow_starts = 0u64;
    for e in events {
        let ph = e.req_str("ph").unwrap();
        assert!(
            ["X", "b", "e", "i", "s", "f", "M", "C"].contains(&ph),
            "unexpected phase {ph}"
        );
        if ph == "M" {
            let meta = e.req_str("name").unwrap();
            assert!(["process_name", "thread_name"].contains(&meta));
            continue;
        }
        assert!(e.req_f64("ts").unwrap() >= 0.0);
        assert!(e.req_f64("pid").is_ok() && e.req_f64("tid").is_ok());
        match ph {
            "X" => assert!(e.req_f64("dur").unwrap() >= 0.0),
            "b" | "e" => {
                let key = (
                    e.req_f64("pid").unwrap(),
                    e.req_str("cat").unwrap().to_string(),
                    e.req_f64("id").unwrap(),
                );
                *open.entry(key).or_default() += if ph == "b" { 1 } else { -1 };
            }
            "s" => flow_starts += 1,
            "f" => assert_eq!(e.req_str("bp").unwrap(), "e"),
            "C" => assert!(
                matches!(e.get("args"), Some(json::Value::Object(m)) if !m.is_empty()),
                "counter event must carry a non-empty args object"
            ),
            _ => {}
        }
    }
    assert!(open.values().all(|&n| n == 0), "unbalanced async events");
    assert!(flow_starts > 0);
}

/// Extract a counter sample's value for `key`, panicking on non-F64.
fn counter_value(e: &Event, key: &str) -> Option<f64> {
    e.args.iter().find(|(k, _)| *k == key).map(|(_, v)| match v {
        ArgValue::F64(x) => *x,
        other => panic!("counter series {key} must be F64, got {other:?}"),
    })
}

#[test]
fn counters_cover_all_three_planes_and_hold_invariants() {
    let trace = run_traced(&cosim_config(6, 7));
    let evs = trace.snapshot();
    let counters: Vec<&Event> = evs
        .iter()
        .filter(|e| e.kind == EventKind::Counter)
        .collect();
    assert!(!counters.is_empty(), "cosim must emit counter samples");

    // Coverage: every plane contributes at least one counter track.
    for prefix in ["serve/", "train/", "publish/"] {
        assert!(
            counters.iter().any(|e| e.name.starts_with(prefix)),
            "no counter track from the {prefix} plane"
        );
    }

    // Per-(pid, tid, name) timestamps are monotone non-decreasing — the
    // Perfetto counter-track contract.
    let mut last_ts: BTreeMap<(u32, u32, &str), f64> = BTreeMap::new();
    for e in &counters {
        let key = (e.track.pid, e.track.tid, e.name);
        if let Some(prev) = last_ts.get(&key) {
            assert!(
                e.ts_ms >= *prev,
                "counter {} ran backwards on pid={} tid={}",
                e.name,
                e.track.pid,
                e.track.tid
            );
        }
        last_ts.insert(key, e.ts_ms);
        assert_eq!(e.cat, "counter");
        assert!(!e.args.is_empty(), "counter {} has no series", e.name);
    }

    // Queue depth and in-flight work are never negative.
    for e in counters.iter().filter(|e| e.name == "serve/queue") {
        assert!(counter_value(e, "depth").unwrap() >= 0.0);
        assert!(counter_value(e, "in_flight").unwrap() >= 0.0);
    }

    // Egress occupancy: backlog never negative, bytes_sent non-decreasing
    // per publisher track.
    let mut last_bytes: BTreeMap<(u32, u32), f64> = BTreeMap::new();
    let mut egress_samples = 0u64;
    for e in counters.iter().filter(|e| e.name == "publish/egress") {
        egress_samples += 1;
        assert!(counter_value(e, "backlog_ms").unwrap() >= 0.0);
        let bytes = counter_value(e, "bytes_sent").unwrap();
        let key = (e.track.pid, e.track.tid);
        if let Some(prev) = last_bytes.get(&key) {
            assert!(bytes >= *prev, "egress bytes_sent must be cumulative");
        }
        last_bytes.insert(key, bytes);
    }
    assert!(egress_samples > 0, "publisher must sample egress occupancy");

    // Straggler/pending counters exist on the master track and are sane.
    for e in counters
        .iter()
        .filter(|e| e.name == "train/pending-gradients")
    {
        assert!(counter_value(e, "pending").unwrap() >= 0.0);
    }
}

#[test]
fn counter_exports_are_deterministic_across_equal_seed_runs() {
    // The byte-identity test above already covers this implicitly, but
    // pin it for counters specifically: equal seeds must produce the
    // exact same counter sample sequence.
    let cfg = cosim_config(4, 11);
    let a = run_traced(&cfg);
    let b = run_traced(&cfg);
    let series = |t: &TraceHandle| -> Vec<(u32, u32, String, String)> {
        t.snapshot()
            .iter()
            .filter(|e| e.kind == EventKind::Counter)
            .map(|e| {
                let args = e
                    .args
                    .iter()
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect::<Vec<_>>()
                    .join(";");
                (e.track.pid, e.track.tid, e.name.to_string(), args)
            })
            .collect()
    };
    let sa = series(&a);
    assert!(!sa.is_empty());
    assert_eq!(sa, series(&b));
}

#[test]
fn critical_path_covers_iteration_wall_time() {
    // ISSUE 8 acceptance: per-iteration critical-path lengths must sum to
    // within 1% of the traced iteration span's wall-time.
    let trace = run_traced(&cosim_config(6, 7));
    let analysis = TraceAnalysis::from_events(&trace.snapshot());
    assert!(
        !analysis.iterations.is_empty(),
        "analyzer must find training iterations"
    );
    for p in &analysis.iterations {
        let path = p.path_ms();
        if p.wall_ms <= 0.0 {
            assert!(path.abs() < 1e-9);
            continue;
        }
        let err = (path - p.wall_ms).abs() / p.wall_ms;
        assert!(
            err <= 0.01,
            "iteration {:?} path {:.3} ms vs wall {:.3} ms ({:.2}% off)",
            p.iteration,
            path,
            p.wall_ms,
            100.0 * err
        );
    }
    // The serving plane decomposes too, and the analyzer names verdicts
    // for every plane present in the trace.
    assert!(!analysis.requests.is_empty(), "request paths must decompose");
    assert!(!analysis.verdicts.is_empty());
    let scopes: Vec<&str> = analysis.verdicts.iter().map(|v| v.scope.as_str()).collect();
    assert!(scopes.iter().any(|s| s.starts_with("train")));
    assert!(scopes.iter().any(|s| s.starts_with("serve")));
}
