//! Fault-injection plane: seeded, virtual-clock-deterministic adversity.
//!
//! The paper's defining operating condition — browsers that join, leave,
//! stall, and misbehave mid-training (§3.2/§3.3b) — is injected here as a
//! pure function of `(profile, seed, worker, iteration, attempt)`.  A
//! [`FaultPlan`] keeps *no* mutable state: every decision builds a fresh
//! [`Pcg32`] on its own stream, so the plan is identical however many
//! times (or in whatever order) it is consulted — equal seeds produce
//! byte-identical fault schedules, and checkpoint/replay never drifts.
//!
//! Fault taxonomy (all optional, all composable):
//! * **disconnect storms** — correlated bursts: every `storm_every`
//!   iterations, a `storm_fraction` slice of the fleet drops for
//!   `storm_duration` iterations (the same workers stay down for the
//!   whole burst — decisions are keyed by storm epoch, not iteration).
//! * **stragglers** — a per-worker slowdown factor scaled by
//!   [`DeviceClass`] (phones stall harder than workstations).
//! * **upload drop / duplicate** — a submission vanishes in flight (the
//!   client retries with seeded-jitter backoff until its deadline) or
//!   arrives twice (the master must deduplicate).
//! * **hostile gradients** — an `adversary_fraction` slice of the fleet
//!   corrupts every upload: `NaN | Inf | scaled:<k> | sign-flip`
//!   ([`CorruptionMode`]).  Non-finite modes are caught by master-side
//!   quarantine; finite ones only by robust aggregation
//!   (`params::AggregationMode`).

use crate::client::DeviceClass;
use crate::rng::Pcg32;

const SALT_ADVERSARY: u64 = 0xFA01;
const SALT_STRAGGLER: u64 = 0xFA02;
const SALT_STORM: u64 = 0xFA03;
const SALT_DROP: u64 = 0xFA04;
const SALT_DUP: u64 = 0xFA05;
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// How a hostile client mangles its gradient before upload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CorruptionMode {
    /// Every coordinate becomes NaN (diverged or malicious worker).
    NaN,
    /// Every coordinate becomes +∞.
    Inf,
    /// Gradient multiplied by a constant (e.g. `scaled:-8` — a finite,
    /// quarantine-proof attack that only robust aggregation survives).
    Scaled(f32),
    /// Gradient negated: the classic sign-flip poisoning attack.
    SignFlip,
}

impl CorruptionMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        if s == "nan" {
            Ok(CorruptionMode::NaN)
        } else if s == "inf" {
            Ok(CorruptionMode::Inf)
        } else if s == "sign-flip" {
            Ok(CorruptionMode::SignFlip)
        } else if let Some(k) = s.strip_prefix("scaled:") {
            let k: f32 = k.parse().map_err(|_| format!("bad scale '{k}'"))?;
            if !k.is_finite() {
                return Err(format!("scale {k} must be finite"));
            }
            Ok(CorruptionMode::Scaled(k))
        } else {
            Err(format!(
                "unknown corruption '{s}' (nan|inf|scaled:<k>|sign-flip)"
            ))
        }
    }

    pub fn name(&self) -> String {
        match self {
            CorruptionMode::NaN => "nan".into(),
            CorruptionMode::Inf => "inf".into(),
            CorruptionMode::Scaled(k) => format!("scaled:{k}"),
            CorruptionMode::SignFlip => "sign-flip".into(),
        }
    }
}

/// Declarative fault configuration; compiled against a seed into a
/// [`FaultPlan`].  `FaultProfile::none()` (the default) injects nothing
/// and leaves every existing run bitwise-unchanged.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultProfile {
    /// Fraction of the fleet that uploads corrupted gradients.
    pub adversary_fraction: f64,
    /// What the adversaries upload.
    pub corruption: CorruptionMode,
    /// Per-attempt probability that an upload is lost in flight.
    pub drop_prob: f64,
    /// Probability that a delivered upload arrives twice.
    pub duplicate_prob: f64,
    /// Disconnect-storm cadence in iterations (0 = no storms).
    pub storm_every: u64,
    /// Storm length in iterations.
    pub storm_duration: u64,
    /// Fraction of the fleet taken down by each storm.
    pub storm_fraction: f64,
    /// Fraction of the fleet that runs slow.
    pub straggler_fraction: f64,
    /// Base compute-slowdown factor for stragglers (scaled per device
    /// class — see [`FaultPlan::slowdown_for`]).
    pub slowdown: f64,
    /// The spec string this profile was parsed from (for display).
    spec: String,
}

impl Default for FaultProfile {
    fn default() -> Self {
        FaultProfile::none()
    }
}

impl FaultProfile {
    /// The inert profile: nothing is injected, every decision is `false`.
    pub fn none() -> Self {
        FaultProfile {
            adversary_fraction: 0.0,
            corruption: CorruptionMode::SignFlip,
            drop_prob: 0.0,
            duplicate_prob: 0.0,
            storm_every: 0,
            storm_duration: 0,
            storm_fraction: 0.0,
            straggler_fraction: 0.0,
            slowdown: 1.0,
            spec: "none".into(),
        }
    }

    /// Parse a profile spec:
    /// * `none` — inert
    /// * `flaky` — drops, duplicates, stragglers (an unreliable but
    ///   honest volunteer fleet)
    /// * `storm` — flaky plus correlated disconnect storms
    /// * `hostile:<frac>[:<mode>]` — an adversary fraction uploading
    ///   corrupted gradients (mode defaults to `sign-flip`; `scaled:-8`
    ///   style modes keep their own `:`)
    /// * `mixed:<frac>` — storms + flakiness + hostile fraction
    pub fn parse(s: &str) -> Result<Self, String> {
        let flaky = || FaultProfile {
            drop_prob: 0.15,
            duplicate_prob: 0.05,
            straggler_fraction: 0.2,
            slowdown: 3.0,
            spec: s.to_string(),
            ..FaultProfile::none()
        };
        if s == "none" {
            Ok(FaultProfile::none())
        } else if s == "flaky" {
            Ok(flaky())
        } else if s == "storm" {
            Ok(FaultProfile {
                storm_every: 8,
                storm_duration: 2,
                storm_fraction: 0.5,
                ..flaky()
            })
        } else if let Some(rest) = s.strip_prefix("hostile:") {
            let (frac, mode) = match rest.split_once(':') {
                Some((f, m)) => (f, CorruptionMode::parse(m)?),
                None => (rest, CorruptionMode::SignFlip),
            };
            Ok(FaultProfile {
                adversary_fraction: parse_fraction(frac)?,
                corruption: mode,
                spec: s.to_string(),
                ..FaultProfile::none()
            })
        } else if let Some(frac) = s.strip_prefix("mixed:") {
            Ok(FaultProfile {
                adversary_fraction: parse_fraction(frac)?,
                storm_every: 8,
                storm_duration: 2,
                storm_fraction: 0.5,
                ..flaky()
            })
        } else {
            Err(format!(
                "unknown fault profile '{s}' \
                 (none|flaky|storm|hostile:<f>[:<mode>]|mixed:<f>)"
            ))
        }
    }

    /// The spec string this profile was parsed from.
    pub fn name(&self) -> &str {
        &self.spec
    }

    /// True when any fault class can fire.
    pub fn is_active(&self) -> bool {
        self.adversary_fraction > 0.0
            || self.drop_prob > 0.0
            || self.duplicate_prob > 0.0
            || self.storm_every > 0
            || self.straggler_fraction > 0.0
    }
}

fn parse_fraction(s: &str) -> Result<f64, String> {
    let f: f64 = s.parse().map_err(|_| format!("bad fraction '{s}'"))?;
    if !(0.0..=1.0).contains(&f) {
        return Err(format!("fraction {f} out of [0, 1]"));
    }
    Ok(f)
}

/// A profile compiled against a seed: the complete, stateless fault
/// schedule.  Every decision derives a fresh generator from
/// `(seed, salt, worker, key)` — consulting the plan never mutates it,
/// so injection sites can be added or reordered without shifting any
/// other decision (the property the equal-seed digest test pins).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    profile: FaultProfile,
    seed: u64,
}

impl FaultPlan {
    pub fn new(profile: FaultProfile, seed: u64) -> Self {
        FaultPlan { profile, seed }
    }

    pub fn profile(&self) -> &FaultProfile {
        &self.profile
    }

    pub fn is_active(&self) -> bool {
        self.profile.is_active()
    }

    /// One decision generator, keyed by salt (fault class) and `(a, b)`
    /// (worker / epoch / attempt).  One `gen_bool` per decision.
    fn decision(&self, salt: u64, a: u64, b: u64) -> Pcg32 {
        Pcg32::with_stream(self.seed ^ salt, a.wrapping_mul(GOLDEN) ^ b)
    }

    /// Is this worker hostile for the whole run?
    pub fn is_adversary(&self, worker: u64) -> bool {
        self.profile.adversary_fraction > 0.0
            && self
                .decision(SALT_ADVERSARY, worker, 0)
                .gen_bool(self.profile.adversary_fraction)
    }

    /// Is this worker a straggler for the whole run?
    pub fn is_straggler(&self, worker: u64) -> bool {
        self.profile.straggler_fraction > 0.0
            && self
                .decision(SALT_STRAGGLER, worker, 0)
                .gen_bool(self.profile.straggler_fraction)
    }

    /// Compute-slowdown factor for a straggler of this device class
    /// (1.0 for non-stragglers).  Weaker devices stall harder: a phone
    /// in a background tab degrades worse than a workstation.
    pub fn slowdown_for(&self, class: DeviceClass, worker: u64) -> f64 {
        if !self.is_straggler(worker) {
            return 1.0;
        }
        let class_factor = match class {
            DeviceClass::Workstation => 1.0,
            DeviceClass::Desktop => 1.2,
            DeviceClass::Laptop => 1.5,
            DeviceClass::Mobile => 2.5,
        };
        (self.profile.slowdown * class_factor).max(1.0)
    }

    /// Is a disconnect storm in progress at this iteration?  The first
    /// epoch (iterations `0..storm_every`) is always clean so runs start
    /// from a healthy fleet.
    pub fn storm_active(&self, iteration: u64) -> bool {
        let every = self.profile.storm_every;
        every > 0 && iteration >= every && iteration % every < self.profile.storm_duration
    }

    /// Is this worker disconnected at this iteration?  Keyed by storm
    /// *epoch*, not iteration: the same workers stay down for the whole
    /// burst — a correlated storm, not independent coin flips per tick.
    pub fn disconnected(&self, worker: u64, iteration: u64) -> bool {
        self.storm_active(iteration)
            && self
                .decision(SALT_STORM, worker, iteration / self.profile.storm_every)
                .gen_bool(self.profile.storm_fraction)
    }

    /// Is this upload attempt lost in flight?
    pub fn upload_dropped(&self, worker: u64, iteration: u64, attempt: u32) -> bool {
        self.profile.drop_prob > 0.0
            && self
                .decision(
                    SALT_DROP,
                    worker,
                    iteration.wrapping_mul(GOLDEN) ^ attempt as u64,
                )
                .gen_bool(self.profile.drop_prob)
    }

    /// Does this delivered upload arrive twice?
    pub fn duplicated(&self, worker: u64, iteration: u64) -> bool {
        self.profile.duplicate_prob > 0.0
            && self
                .decision(SALT_DUP, worker, iteration)
                .gen_bool(self.profile.duplicate_prob)
    }

    /// Corrupt a gradient in place if this worker is an adversary.
    /// Returns whether corruption was applied.
    pub fn corrupt(&self, grad: &mut [f32], worker: u64) -> bool {
        if !self.is_adversary(worker) {
            return false;
        }
        match self.profile.corruption {
            CorruptionMode::NaN => grad.fill(f32::NAN),
            CorruptionMode::Inf => grad.fill(f32::INFINITY),
            CorruptionMode::Scaled(k) => grad.iter_mut().for_each(|g| *g *= k),
            CorruptionMode::SignFlip => grad.iter_mut().for_each(|g| *g = -*g),
        }
        true
    }

    /// FNV-1a digest over every decision the plan would make for
    /// `workers × iterations` — the equal-seed determinism witness
    /// (equal seeds ⇒ equal digests; the plan itself is the schedule).
    pub fn digest(&self, workers: &[u64], iterations: u64) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut mix = |bit: bool| {
            h = (h ^ bit as u64).wrapping_mul(0x0000_0100_0000_01b3);
        };
        for &w in workers {
            mix(self.is_adversary(w));
            mix(self.is_straggler(w));
            for it in 0..iterations {
                mix(self.disconnected(w, it));
                mix(self.upload_dropped(w, it, 0));
                mix(self.duplicated(w, it));
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_parse_round_trips() {
        for spec in ["none", "flaky", "storm", "hostile:0.3", "mixed:0.2"] {
            let p = FaultProfile::parse(spec).unwrap();
            assert_eq!(p.name(), spec);
        }
        let p = FaultProfile::parse("hostile:0.3:scaled:-8").unwrap();
        assert_eq!(p.adversary_fraction, 0.3);
        assert_eq!(p.corruption, CorruptionMode::Scaled(-8.0));
        let p = FaultProfile::parse("hostile:0.5:nan").unwrap();
        assert_eq!(p.corruption, CorruptionMode::NaN);
        assert!(FaultProfile::parse("hostile:1.5").is_err());
        assert!(FaultProfile::parse("hostile:0.3:wat").is_err());
        assert!(FaultProfile::parse("wat").is_err());
    }

    #[test]
    fn none_profile_is_inert() {
        let plan = FaultPlan::new(FaultProfile::none(), 7);
        assert!(!plan.is_active());
        for w in 0..32 {
            assert!(!plan.is_adversary(w));
            assert!(!plan.is_straggler(w));
            assert_eq!(plan.slowdown_for(DeviceClass::Mobile, w), 1.0);
            for it in 0..16 {
                assert!(!plan.disconnected(w, it));
                assert!(!plan.upload_dropped(w, it, 0));
                assert!(!plan.duplicated(w, it));
            }
            let mut g = vec![1.0f32; 4];
            assert!(!plan.corrupt(&mut g, w));
            assert_eq!(g, vec![1.0; 4]);
        }
    }

    #[test]
    fn equal_seed_equal_plan_digest() {
        let workers: Vec<u64> = (1..=12).collect();
        let mk = |seed| FaultPlan::new(FaultProfile::parse("mixed:0.3").unwrap(), seed);
        assert_eq!(mk(5).digest(&workers, 40), mk(5).digest(&workers, 40));
        assert_ne!(mk(5).digest(&workers, 40), mk(6).digest(&workers, 40));
    }

    #[test]
    fn decisions_are_stateless_and_order_free() {
        let plan = FaultPlan::new(FaultProfile::parse("mixed:0.3").unwrap(), 11);
        let a = plan.upload_dropped(3, 9, 0);
        // Interleave unrelated queries; the original answer must not move.
        for w in 0..20 {
            plan.is_adversary(w);
            plan.duplicated(w, 5);
        }
        assert_eq!(plan.upload_dropped(3, 9, 0), a);
    }

    #[test]
    fn storms_are_correlated_bursts() {
        let plan = FaultPlan::new(FaultProfile::parse("storm").unwrap(), 3);
        // First epoch is clean.
        for it in 0..8 {
            assert!(!plan.storm_active(it), "iteration {it}");
        }
        // Inside one storm window a worker's fate is constant.
        for w in 0..16u64 {
            assert_eq!(plan.disconnected(w, 8), plan.disconnected(w, 9));
        }
        // Some worker is down in some storm (fraction 0.5, 16 workers).
        assert!((0..16u64).any(|w| plan.disconnected(w, 8) || plan.disconnected(w, 16)));
        // Storm windows end.
        assert!(!plan.storm_active(10));
    }

    #[test]
    fn adversary_fraction_selects_a_minority_not_everyone() {
        let plan = FaultPlan::new(FaultProfile::parse("hostile:0.3").unwrap(), 1);
        let adv: Vec<u64> = (1..=10).filter(|&w| plan.is_adversary(w)).collect();
        // Pinned for seed 1: the convergence-under-attack test (10
        // workstations, fraction 0.3) relies on exactly these three.
        assert_eq!(adv, vec![1, 6, 7]);
    }

    #[test]
    fn corruption_modes_apply() {
        let base = vec![1.0f32, -2.0, 0.5];
        let mut profile = FaultProfile::parse("hostile:1.0:nan").unwrap();
        let check = |profile: &FaultProfile, want: &dyn Fn(&[f32]) -> bool| {
            let plan = FaultPlan::new(profile.clone(), 2);
            let mut g = base.clone();
            assert!(plan.corrupt(&mut g, 4));
            assert!(want(&g), "{:?} -> {g:?}", profile.corruption);
        };
        check(&profile, &|g| g.iter().all(|x| x.is_nan()));
        profile.corruption = CorruptionMode::Inf;
        check(&profile, &|g| g.iter().all(|x| *x == f32::INFINITY));
        profile.corruption = CorruptionMode::Scaled(-8.0);
        check(&profile, &|g| g == [-8.0, 16.0, -4.0]);
        profile.corruption = CorruptionMode::SignFlip;
        check(&profile, &|g| g == [-1.0, 2.0, -0.5]);
    }
}
