//! Per-client link models and the master's ingestion model.
//!
//! Calibration targets come straight from the paper:
//! * §3.7: "we found that 1MB/sec bandwidth was achievable on a local
//!   network" — LAN bandwidth default.
//! * §3.7: gradients are "at least > 1MB for small neural networks" in
//!   their JS encoding; we compute message bytes from the actual parameter
//!   count (f32) plus protocol overhead.
//! * §3.5: the knee at 64 nodes is "a single server reaching the limit of
//!   its capacity to process incoming gradients synchronously" — modeled
//!   as serial service of gradient messages at the master.

use crate::rng::{LogNormal, Pcg32, Uniform};

/// Connection class of a simulated client (paper: hardwired grid machines
/// vs. wifi laptops vs. cellular mobiles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkProfile {
    Lan,
    Wifi,
    Cellular,
}

impl LinkProfile {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "lan" => Ok(Self::Lan),
            "wifi" => Ok(Self::Wifi),
            "cellular" => Ok(Self::Cellular),
            _ => Err(format!("unknown link profile '{s}' (lan|wifi|cellular)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Lan => "lan",
            Self::Wifi => "wifi",
            Self::Cellular => "cellular",
        }
    }

    /// (median one-way latency ms, lognormal sigma, bandwidth bytes/ms)
    fn constants(self) -> (f64, f64, f64) {
        match self {
            // 1 MB/s per the paper's LAN measurement → 1048.6 bytes/ms.
            LinkProfile::Lan => (4.0, 0.25, 1_048.6),
            LinkProfile::Wifi => (12.0, 0.45, 700.0),
            LinkProfile::Cellular => (80.0, 0.8, 125.0),
        }
    }
}

/// A client's link: fixed base latency (drawn once per client — device
/// placement) plus per-message heavy-tailed jitter.
#[derive(Debug, Clone)]
pub struct LinkModel {
    pub profile: LinkProfile,
    base_ms: f64,
    jitter: LogNormal,
    bandwidth_bytes_per_ms: f64,
}

impl LinkModel {
    pub fn new(profile: LinkProfile, rng: &mut Pcg32) -> Self {
        let (median, sigma, bw) = profile.constants();
        // Spread client bases ±30% around the profile median.
        let base = Uniform::new(median * 0.7, median * 1.3).sample(rng);
        Self {
            profile,
            base_ms: base,
            jitter: LogNormal::from_median(base, sigma),
            bandwidth_bytes_per_ms: bw,
        }
    }

    /// Rebuild a link from its persisted placement. The jitter
    /// distribution and bandwidth are pure functions of (profile,
    /// base_ms), so a checkpoint only stores those two values and this
    /// constructor yields a bitwise-identical model on restore.
    pub fn from_base(profile: LinkProfile, base_ms: f64) -> Self {
        let (_, sigma, bw) = profile.constants();
        Self {
            profile,
            base_ms,
            jitter: LogNormal::from_median(base_ms, sigma),
            bandwidth_bytes_per_ms: bw,
        }
    }

    /// One-way message latency sample (ms), excluding transmission time.
    pub fn sample_latency_ms(&self, rng: &mut Pcg32) -> f64 {
        self.jitter.sample(rng)
    }

    /// Transmission time for a payload (ms).
    pub fn transmit_ms(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bandwidth_bytes_per_ms
    }

    /// Base (median) latency — what the master's latency monitor estimates.
    pub fn base_ms(&self) -> f64 {
        self.base_ms
    }

    /// Backoff before retry `attempt + 1` after a dropped upload (ms):
    /// binary exponential in the link's base latency, capped at 2⁶, with
    /// seeded ±50% jitter so a correlated storm's retries desynchronize
    /// deterministically.
    pub fn retry_backoff_ms(&self, attempt: u32, rng: &mut Pcg32) -> f64 {
        let exp = (1u64 << attempt.min(6)) as f64;
        self.base_ms * exp * (0.5 + rng.gen_f64())
    }

    /// Link bandwidth (bytes/ms) — sizing the background-download budget.
    pub fn bandwidth_bytes_per_ms(&self) -> f64 {
        self.bandwidth_bytes_per_ms
    }
}

/// How the master parallelizes the reduce step (the paper's §5
/// "multiple reduce processes" mitigation, in two shapes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReduceMode {
    /// Message-parallel: whole gradient messages are load-balanced
    /// round-robin over `MasterModel::processes` queues (the original
    /// modeled mitigation).
    MessageParallel,
    /// Parameter-sharded: one reduce pipeline, but each message's merge
    /// is split over `shards` threads (`params::ShardedAccumulator`), so
    /// the per-message merge cost divides by S at the price of a
    /// per-shard fan-in barrier term.
    Sharded { shards: usize },
}

impl ReduceMode {
    /// Shard count the accumulator should use (1 for message-parallel).
    pub fn shards(&self) -> usize {
        match self {
            ReduceMode::MessageParallel => 1,
            ReduceMode::Sharded { shards } => (*shards).max(1),
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        if s == "message" {
            Ok(ReduceMode::MessageParallel)
        } else if s == "sharded" {
            Ok(ReduceMode::Sharded { shards: 4 })
        } else if let Some(n) = s.strip_prefix("sharded:") {
            let shards: usize = n
                .parse()
                .map_err(|_| format!("bad shard count '{n}'"))?;
            if shards == 0 {
                return Err("shard count must be >= 1".into());
            }
            Ok(ReduceMode::Sharded { shards })
        } else {
            Err(format!(
                "unknown reduce mode '{s}' (message|sharded|sharded:<S>)"
            ))
        }
    }

    pub fn name(&self) -> String {
        match self {
            ReduceMode::MessageParallel => "message".into(),
            ReduceMode::Sharded { shards } => format!("sharded:{shards}"),
        }
    }
}

/// The master's capacity to ingest gradient messages at the sync point.
///
/// All trainers respond near-simultaneously at the end of an iteration
/// (§3.5); the master serves messages serially per process: receive
/// (bytes / ingest bandwidth) then merge (params × per-param cost).  With
/// `processes > 1` (the paper's mitigation #1), messages are load-balanced
/// round-robin across processes; with [`ReduceMode::Sharded`] the merge
/// itself is split across shard threads instead.
#[derive(Debug, Clone)]
pub struct MasterModel {
    /// Master ingress bandwidth (bytes/ms): the shared switch/NIC all
    /// gradient flows converge on at the sync point.
    pub ingest_bandwidth_bytes_per_ms: f64,
    /// Fixed per-message handling overhead (ms): websocket framing, JSON
    /// envelope, event dispatch in the single Node.js loop.
    pub per_msg_overhead_ms: f64,
    /// Gradient-merge cost per parameter (ns).  Calibrate from the
    /// measured kernel: `cargo bench --bench micro -- --reduce-only`
    /// prints this value (and `BENCH_reduce.json` records it); inject it
    /// via `--merge-ns` on the CLI sweeps.  The default stays at the
    /// paper-era 1 ns/param (a JS-engine merge loop) so the §3.5 knee
    /// calibration below is unchanged.
    pub merge_ns_per_param: f64,
    /// Number of master reduce processes (paper mitigation: >1).  Only
    /// meaningful under [`ReduceMode::MessageParallel`].
    pub processes: usize,
    /// How the reduce parallelizes (message-parallel vs param-sharded).
    pub reduce_mode: ReduceMode,
    /// Fan-in barrier cost per shard per message (ns) under
    /// [`ReduceMode::Sharded`]: the scoped-thread wake/join overhead,
    /// amortized over the burst.  Sets the knee where more shards stop
    /// paying off.
    pub fanin_ns_per_shard: f64,
    /// Saturation threshold: once the bytes arriving in one sync burst
    /// exceed this, per-message service degrades quadratically — the
    /// Node.js heap/GC pressure behind the paper's observation that "a
    /// single server reach[es] the limit of its capacity to process
    /// incoming gradients synchronously" (§3.5).
    pub congestion_bytes: u64,
}

impl Default for MasterModel {
    fn default() -> Self {
        Self {
            // 100 Mbit/s switch uplink at the master (the paper's single
            // router, §3.5), minus protocol overhead.
            ingest_bandwidth_bytes_per_ms: 12_000.0,
            per_msg_overhead_ms: 3.0,
            merge_ns_per_param: 1.0,
            processes: 1,
            reduce_mode: ReduceMode::MessageParallel,
            fanin_ns_per_shard: 2_000.0,
            // Calibrated just above 64 × ~94 KB (the mnist_conv gradient
            // burst): the knee lands at the paper's 64 nodes.
            congestion_bytes: 6_500_000,
        }
    }
}

impl MasterModel {
    /// Service time for one gradient message of `bytes` covering `params`
    /// parameters (ms), excluding queueing and congestion.  Under
    /// [`ReduceMode::Sharded`] the merge component divides by the shard
    /// count and pays the per-shard fan-in barrier.
    pub fn service_ms(&self, bytes: u64, params: usize) -> f64 {
        let (overhead, ingest, merge) = self.service_breakdown(bytes, params);
        overhead + ingest + merge
    }

    /// The three components of [`service_ms`](Self::service_ms), in ms:
    /// `(per-message overhead, ingest transfer, merge)`.  The trace plane
    /// attaches these to ingest spans so a timeline shows *where* a
    /// gradient's drain time went (framing vs wire vs reduce).
    pub fn service_breakdown(&self, bytes: u64, params: usize) -> (f64, f64, f64) {
        let merge_ns = match self.reduce_mode {
            ReduceMode::MessageParallel => params as f64 * self.merge_ns_per_param,
            ReduceMode::Sharded { shards } => {
                let s = shards.max(1) as f64;
                params as f64 * self.merge_ns_per_param / s + s * self.fanin_ns_per_shard
            }
        };
        (
            self.per_msg_overhead_ms,
            bytes as f64 / self.ingest_bandwidth_bytes_per_ms,
            merge_ns / 1.0e6,
        )
    }

    /// Service degradation multiplier for a sync burst totaling
    /// `total_bytes`: 1 below the congestion threshold, growing
    /// quadratically beyond it (GC/buffer pressure).
    pub fn congestion_factor(&self, total_bytes: u64) -> f64 {
        let x = total_bytes as f64 / self.congestion_bytes as f64;
        if x <= 1.0 {
            1.0
        } else {
            x * x
        }
    }

    /// Completion delay (ms past the sync point) for each arriving message.
    ///
    /// `arrivals[i] = (arrival offset ms, bytes, params)`.  Messages are
    /// dispatched round-robin over `processes` queues in arrival order and
    /// served FIFO per queue; service times carry the burst's congestion
    /// factor.  Returns per-message completion times in the original
    /// order — the "asynchronous reduction callback delay" each client
    /// experiences.
    pub fn drain_delays(&self, arrivals: &[(f64, u64, usize)]) -> Vec<f64> {
        let total_bytes: u64 = arrivals.iter().map(|a| a.1).sum();
        // Message-parallel: round-robin over `processes` queues, each
        // seeing 1/processes of the burst (paper mitigation #1 splits the
        // heap pressure as well as the queue).  Sharded: one reduce
        // pipeline — service is faster per message, but the full burst's
        // congestion lands on it.
        let queues = match self.reduce_mode {
            ReduceMode::MessageParallel => self.processes.max(1),
            ReduceMode::Sharded { .. } => 1,
        };
        let factor = self.congestion_factor(total_bytes / queues as u64);
        let mut order: Vec<usize> = (0..arrivals.len()).collect();
        // total_cmp: a NaN offset (corrupt clock math upstream) must not
        // panic the master's drain — it sorts deterministically instead.
        order.sort_by(|&a, &b| arrivals[a].0.total_cmp(&arrivals[b].0));
        let mut free_at = vec![0.0f64; queues];
        let mut completion = vec![0.0f64; arrivals.len()];
        for (k, &i) in order.iter().enumerate() {
            let (arrival, bytes, params) = arrivals[i];
            let q = k % free_at.len();
            let start = free_at[q].max(arrival);
            let done = start + self.service_ms(bytes, params) * factor;
            free_at[q] = done;
            completion[i] = done;
        }
        completion
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn profile_parse_roundtrip() {
        for p in [LinkProfile::Lan, LinkProfile::Wifi, LinkProfile::Cellular] {
            assert_eq!(LinkProfile::parse(p.name()).unwrap(), p);
        }
        assert!(LinkProfile::parse("carrier-pigeon").is_err());
    }

    #[test]
    fn transmit_time_scales_with_bytes() {
        let mut rng = Pcg32::new(1);
        let link = LinkModel::new(LinkProfile::Lan, &mut rng);
        let t1 = link.transmit_ms(1_048_600); // ~1 MB at 1 MB/s ≈ 1000 ms
        assert!((t1 - 1000.0).abs() < 50.0, "{t1}");
        assert_eq!(link.transmit_ms(0), 0.0);
    }

    #[test]
    fn retry_backoff_grows_exponentially_then_caps() {
        let link = LinkModel::from_base(LinkProfile::Wifi, 10.0);
        let mut rng = Pcg32::new(9);
        for attempt in 0..12 {
            let b = link.retry_backoff_ms(attempt, &mut rng);
            let exp = (1u64 << attempt.min(6)) as f64;
            assert!(
                b >= 10.0 * exp * 0.5 && b < 10.0 * exp * 1.5,
                "attempt {attempt}: {b}"
            );
        }
        // Deterministic given equal rng state.
        let mut a = Pcg32::new(3);
        let mut b = Pcg32::new(3);
        assert_eq!(
            link.retry_backoff_ms(2, &mut a).to_bits(),
            link.retry_backoff_ms(2, &mut b).to_bits()
        );
    }

    #[test]
    fn service_time_components() {
        let m = MasterModel::default();
        let s = m.service_ms(104_860, 23_466);
        // 3 + 104860/12000 + 0.023 ms
        assert!((s - 11.76).abs() < 0.2, "{s}");
        // The breakdown sums exactly to the total and splits as modeled.
        let (overhead, ingest, merge) = m.service_breakdown(104_860, 23_466);
        assert_eq!(overhead + ingest + merge, s);
        assert_eq!(overhead, 3.0);
        assert!((ingest - 104_860.0 / 12_000.0).abs() < 1e-12);
        assert!((merge - 0.023_466).abs() < 1e-9);
    }

    #[test]
    fn knee_position_matches_paper() {
        // The default calibration must keep the master uncongested through
        // the paper's 64-node linear regime and congested beyond it
        // (Fig 4: linear to 64, latency jump after).
        let m = MasterModel::default();
        let msg = (23_466 * 4 + 96) as u64; // mnist_conv gradient message
        assert_eq!(m.congestion_factor(64 * msg), 1.0);
        assert!(m.congestion_factor(96 * msg) > 1.5);
        // and the queueing delay visibly jumps 64 -> 96
        let drain = |n: usize| -> f64 {
            let arrivals = vec![(0.0, msg, 23_466); n];
            m.drain_delays(&arrivals).into_iter().fold(0.0, f64::max)
        };
        assert!(drain(96) > 2.0 * drain(64), "64: {} 96: {}", drain(64), drain(96));
    }

    #[test]
    fn serial_drain_queues_up() {
        let m = MasterModel::default();
        // 4 identical messages arriving together: completions stack.
        let arrivals = vec![(0.0, 10_486, 1000); 4];
        let d = m.drain_delays(&arrivals);
        let svc = m.service_ms(10_486, 1000);
        let mut sorted = d.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for (k, v) in sorted.iter().enumerate() {
            assert!((v - svc * (k + 1) as f64).abs() < 1e-9, "{d:?}");
        }
    }

    #[test]
    fn multiple_processes_divide_queue() {
        let one = MasterModel {
            processes: 1,
            ..Default::default()
        };
        let four = MasterModel {
            processes: 4,
            ..Default::default()
        };
        let arrivals = vec![(0.0, 10_486, 1000); 8];
        let worst1 = one
            .drain_delays(&arrivals)
            .into_iter()
            .fold(0.0f64, f64::max);
        let worst4 = four
            .drain_delays(&arrivals)
            .into_iter()
            .fold(0.0f64, f64::max);
        assert!(
            (worst1 / worst4 - 4.0).abs() < 0.1,
            "1p {worst1} vs 4p {worst4}"
        );
    }

    #[test]
    fn late_arrival_not_queued_behind_early_ones() {
        let m = MasterModel::default();
        let svc = m.service_ms(1000, 10);
        // One early message; one arriving long after the first finished.
        let d = m.drain_delays(&[(0.0, 1000, 10), (1000.0, 1000, 10)]);
        assert!((d[0] - svc).abs() < 1e-9);
        assert!((d[1] - (1000.0 + svc)).abs() < 1e-9);
    }

    #[test]
    fn reduce_mode_parse_roundtrip() {
        for m in [
            ReduceMode::MessageParallel,
            ReduceMode::Sharded { shards: 4 },
            ReduceMode::Sharded { shards: 7 },
        ] {
            assert_eq!(ReduceMode::parse(&m.name()).unwrap(), m);
        }
        assert_eq!(
            ReduceMode::parse("sharded").unwrap(),
            ReduceMode::Sharded { shards: 4 }
        );
        assert!(ReduceMode::parse("sharded:0").is_err());
        assert!(ReduceMode::parse("threads").is_err());
        assert_eq!(ReduceMode::MessageParallel.shards(), 1);
        assert_eq!(ReduceMode::Sharded { shards: 6 }.shards(), 6);
    }

    #[test]
    fn sharded_mode_divides_merge_cost() {
        let base = MasterModel::default();
        let sharded = MasterModel {
            reduce_mode: ReduceMode::Sharded { shards: 4 },
            ..Default::default()
        };
        // Big message so the merge term dominates the comparison.
        let params = 1_000_000;
        let s1 = base.service_ms(0, params) - base.per_msg_overhead_ms;
        let s4 = sharded.service_ms(0, params) - sharded.per_msg_overhead_ms;
        let expected = s1 / 4.0 + 4.0 * sharded.fanin_ns_per_shard / 1.0e6;
        assert!((s4 - expected).abs() < 1e-9, "{s4} vs {expected}");
        assert!(s4 < s1);
    }

    #[test]
    fn sharded_fanin_barrier_has_a_knee() {
        // More shards than the merge can amortize must cost more, not
        // less: the fan-in term caps useful S.
        let svc = |shards| {
            MasterModel {
                reduce_mode: ReduceMode::Sharded { shards },
                ..Default::default()
            }
            .service_ms(0, 1_000)
        };
        assert!(svc(4) < svc(1024), "barrier term must dominate eventually");
    }

    #[test]
    fn sharded_mode_single_queue_beats_serial_on_merge_bound_bursts() {
        // A burst of merge-heavy messages: the sharded pipeline drains
        // close to S× faster than the single-process message queue.
        let serial = MasterModel {
            per_msg_overhead_ms: 0.0,
            ..Default::default()
        };
        let sharded = MasterModel {
            per_msg_overhead_ms: 0.0,
            reduce_mode: ReduceMode::Sharded { shards: 4 },
            ..Default::default()
        };
        let arrivals = vec![(0.0, 0, 1_000_000); 8];
        let worst = |m: &MasterModel| {
            m.drain_delays(&arrivals)
                .into_iter()
                .fold(0.0f64, f64::max)
        };
        let speedup = worst(&serial) / worst(&sharded);
        assert!(speedup > 3.5 && speedup < 4.0, "speedup {speedup}");
    }

    #[test]
    fn nan_arrival_offset_does_not_panic_drain() {
        let m = MasterModel::default();
        let d = m.drain_delays(&[
            (0.0, 1000, 10),
            (f64::NAN, 1000, 10),
            (5.0, 1000, 10),
        ]);
        assert_eq!(d.len(), 3);
        // The well-formed messages still complete at finite times.
        assert!(d[0].is_finite() && d[2].is_finite());
    }

    #[test]
    fn order_is_preserved_in_output() {
        let m = MasterModel::default();
        // Reverse arrival order: output must stay input-indexed.
        let d = m.drain_delays(&[(5.0, 100, 10), (0.0, 100, 10)]);
        assert!(d[1] < d[0]);
    }
}
