//! Virtual wall-clock for discrete-event simulation.
//!
//! The master event loop runs against this clock: iterations advance it by
//! max(T, slowest-response time), exactly the paper's "asynchronous
//! reduction callback delay" — the reduce runs only after the slowest
//! slave has returned (§3.3d).

/// Monotonic virtual time in milliseconds.
#[derive(Debug, Clone)]
pub struct VirtualClock {
    now_ms: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self { now_ms: 0.0 }
    }

    pub fn now_ms(&self) -> f64 {
        self.now_ms
    }

    pub fn now_secs(&self) -> f64 {
        self.now_ms / 1000.0
    }

    /// Advance by `dt_ms` (must be non-negative).
    pub fn advance(&mut self, dt_ms: f64) {
        assert!(dt_ms >= 0.0 && dt_ms.is_finite(), "bad dt {dt_ms}");
        self.now_ms += dt_ms;
    }

    /// Advance to an absolute timestamp (no-op if already past it).
    pub fn advance_to(&mut self, t_ms: f64) {
        if t_ms > self.now_ms {
            self.now_ms = t_ms;
        }
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = VirtualClock::new();
        c.advance(100.0);
        c.advance(0.0);
        assert_eq!(c.now_ms(), 100.0);
        c.advance_to(50.0); // in the past: no-op
        assert_eq!(c.now_ms(), 100.0);
        c.advance_to(250.0);
        assert_eq!(c.now_secs(), 0.25);
    }

    #[test]
    #[should_panic(expected = "bad dt")]
    fn rejects_negative_dt() {
        VirtualClock::new().advance(-1.0);
    }
}
