//! Network simulator: virtual clock, per-link latency models, bandwidth
//! accounting.
//!
//! The paper's testbed is a LAN of workstations plus (conceptually)
//! cellular-connected mobile devices; "devices with a cellular network
//! connection communicate with longer delays than hardwired machines"
//! (§3.3d), and the Fig 4 latency knee comes from "all clients
//! simultaneously sending gradients to the server at the end of each
//! iteration" (§3.5) saturating a single master.  This module provides the
//! virtual time base and the latency/bandwidth models that let the
//! simulated fleet reproduce those effects deterministically.

mod clock;
mod link;

pub use clock::VirtualClock;
pub use link::{LinkModel, LinkProfile, MasterModel, ReduceMode};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn hardwired_is_faster_than_cellular() {
        let mut rng = Pcg32::new(1);
        let lan = LinkModel::new(LinkProfile::Lan, &mut rng);
        let cell = LinkModel::new(LinkProfile::Cellular, &mut rng);
        let mut rng2 = Pcg32::new(2);
        let n = 200;
        let lan_mean: f64 =
            (0..n).map(|_| lan.sample_latency_ms(&mut rng2)).sum::<f64>() / n as f64;
        let cell_mean: f64 =
            (0..n).map(|_| cell.sample_latency_ms(&mut rng2)).sum::<f64>() / n as f64;
        assert!(
            cell_mean > 3.0 * lan_mean,
            "cellular {cell_mean} vs lan {lan_mean}"
        );
    }
}
