//! Open-loop request-load generation.
//!
//! The serving fleet mirrors the training fleet's heterogeneity (§3.3d):
//! groups of simulated clients on Lan/Wifi/Cellular [`LinkProfile`]s each
//! fire prediction requests as an independent Poisson process at a
//! configured per-client rate — open-loop, so offered load does not slow
//! down when the server queues (the regime where admission control and
//! micro-batching earn their keep).  Inputs are drawn from a shared pool
//! of synthetic samples; pool size dials the repeat rate the prediction
//! cache sees.

use std::sync::Arc;

use crate::data::{SynthSpec, Synthesizer};
use crate::model::ModelSpec;
use crate::netsim::{LinkModel, LinkProfile};
use crate::rng::{Exp, Pcg32};

use super::control::ProjectId;

/// A homogeneous group of simulated request clients.
#[derive(Debug, Clone, Copy)]
pub struct ClientSpec {
    pub link: LinkProfile,
    /// Open-loop arrival rate per client (requests/second).
    pub rate_rps: f64,
    /// Clients in the group.
    pub count: usize,
}

/// The whole request fleet for one serving run.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub groups: Vec<ClientSpec>,
    /// Emission horizon (virtual seconds): requests are *sent* within
    /// [0, duration); responses may complete after it.
    pub duration_s: f64,
    /// Distinct inputs the fleet draws from (smaller pool ⇒ more repeats
    /// ⇒ higher cache hit rate).
    pub input_pool: usize,
    pub seed: u64,
}

/// One request on the wire; the uplink (client → server) is resolved at
/// generation time, the downlink at response time.  Requests carry their
/// [`ProjectId`]: the multi-tenant tier routes, batches and answers them
/// against that project's model only.
#[derive(Debug, Clone)]
pub struct RequestEvent {
    pub id: u64,
    pub client: u32,
    /// The hosted project this request queries.
    pub project: ProjectId,
    /// When the client sent it (virtual ms).
    pub sent_ms: f64,
    /// When it reaches the server: sent + uplink latency + transmission.
    pub arrival_ms: f64,
    pub input: Arc<Vec<f32>>,
}

/// Generated fleet: per-client links (for response timing) plus the
/// time-ordered server-arrival schedule.
#[derive(Debug, Clone)]
pub struct RequestFleet {
    pub links: Vec<LinkModel>,
    pub events: Vec<RequestEvent>,
    /// Modeled request payload (f32 pixels + envelope).
    pub input_bytes: u64,
}

impl RequestFleet {
    /// Build one project's fleet and its full arrival schedule,
    /// deterministically from `cfg.seed`.  Ids and client indices are
    /// fleet-local; [`RequestFleet::merge`] re-bases them when several
    /// projects share a serving tier.
    pub fn generate(project: ProjectId, cfg: &FleetConfig, spec: &ModelSpec) -> Self {
        let mut rng = Pcg32::new(cfg.seed ^ 0x5E47E);
        let pool = input_pool(cfg, spec, &mut rng);
        let input_bytes = (spec.input_len() * 4 + 64) as u64;
        let horizon_ms = cfg.duration_s * 1000.0;

        let mut links = Vec::new();
        let mut events = Vec::new();
        let mut id = 0u64;
        let mut client = 0u32;
        for group in &cfg.groups {
            for _ in 0..group.count {
                let mut crng = rng.fork(client as u64 + 1);
                let link = LinkModel::new(group.link, &mut crng);
                if group.rate_rps > 0.0 {
                    let gap = Exp::new(group.rate_rps / 1000.0); // per-ms rate
                    let mut t = gap.sample(&mut crng);
                    while t < horizon_ms {
                        let input = Arc::clone(&pool[crng.gen_range_usize(pool.len())]);
                        let uplink =
                            link.sample_latency_ms(&mut crng) + link.transmit_ms(input_bytes);
                        events.push(RequestEvent {
                            id,
                            client,
                            project,
                            sent_ms: t,
                            arrival_ms: t + uplink,
                            input,
                        });
                        id += 1;
                        t += gap.sample(&mut crng);
                    }
                }
                links.push(link);
                client += 1;
            }
        }
        events.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms).then(a.id.cmp(&b.id)));
        Self {
            links,
            events,
            input_bytes,
        }
    }

    /// Interleave several projects' fleets into one time-ordered arrival
    /// schedule for the shared tier.  Request ids and client indices are
    /// offset per fleet so both stay globally unique (links concatenate in
    /// fleet order; response timing indexes the merged table).
    pub fn merge(fleets: Vec<RequestFleet>) -> Self {
        let mut links = Vec::new();
        let mut events: Vec<RequestEvent> = Vec::new();
        let mut id_base = 0u64;
        let mut input_bytes = 0u64;
        for fleet in fleets {
            let client_base = links.len() as u32;
            let count = fleet.events.len() as u64;
            for mut e in fleet.events {
                e.id += id_base;
                e.client += client_base;
                events.push(e);
            }
            id_base += count;
            links.extend(fleet.links);
            input_bytes = input_bytes.max(fleet.input_bytes);
        }
        events.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms).then(a.id.cmp(&b.id)));
        Self {
            links,
            events,
            input_bytes,
        }
    }

    /// Total requests offered to the server.
    pub fn offered(&self) -> u64 {
        self.events.len() as u64
    }
}

/// Shared input pool: synthetic corpus samples when the model's input
/// shape matches a known corpus, uniform noise tensors otherwise (toy
/// specs in tests).
fn input_pool(cfg: &FleetConfig, spec: &ModelSpec, rng: &mut Pcg32) -> Vec<Arc<Vec<f32>>> {
    let n = cfg.input_pool.max(1);
    let synth_spec = match spec.input.as_slice() {
        [32, 32, 3] => SynthSpec::cifar(cfg.seed ^ 0xD00D),
        _ => SynthSpec::mnist(cfg.seed ^ 0xD00D),
    };
    if synth_spec.pixels() == spec.input_len() {
        let synth = Synthesizer::new(synth_spec);
        (0..n)
            .map(|i| {
                Arc::new(
                    synth
                        .sample((i % synth_spec.classes as usize) as u8, i as u64)
                        .pixels,
                )
            })
            .collect()
    } else {
        (0..n)
            .map(|_| Arc::new((0..spec.input_len()).map(|_| rng.gen_f32()).collect()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 6,
            batch_size: 4,
            micro_batches: vec![4, 1],
            input: vec![3, 2, 1],
            classes: 2,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![6],
                offset: 0,
                size: 6,
                fan_in: 3,
            }],
            artifacts: Default::default(),
        }
    }

    fn cfg(rate: f64, clients: usize, duration_s: f64) -> FleetConfig {
        FleetConfig {
            groups: vec![ClientSpec {
                link: LinkProfile::Lan,
                rate_rps: rate,
                count: clients,
            }],
            duration_s,
            input_pool: 8,
            seed: 3,
        }
    }

    fn gen(cfg: &FleetConfig) -> RequestFleet {
        RequestFleet::generate(ProjectId::new(0), cfg, &spec())
    }

    #[test]
    fn event_count_tracks_offered_load() {
        let fleet_lo = gen(&cfg(2.0, 4, 10.0));
        let fleet_hi = gen(&cfg(20.0, 4, 10.0));
        // Poisson: expect ~80 vs ~800; allow wide slack.
        assert!(fleet_lo.offered() > 30 && fleet_lo.offered() < 200, "{}", fleet_lo.offered());
        assert!(
            fleet_hi.offered() > 5 * fleet_lo.offered(),
            "hi {} lo {}",
            fleet_hi.offered(),
            fleet_lo.offered()
        );
        assert_eq!(fleet_hi.links.len(), 4);
    }

    #[test]
    fn events_sorted_by_arrival_and_after_send() {
        let fleet = gen(&cfg(10.0, 3, 5.0));
        for w in fleet.events.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
        for e in &fleet.events {
            assert!(e.arrival_ms > e.sent_ms, "uplink takes time");
            assert!(e.sent_ms < 5_000.0, "sent within the horizon");
            assert_eq!(e.input.len(), 6);
            assert_eq!(e.project, ProjectId::new(0));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gen(&cfg(5.0, 2, 5.0));
        let b = gen(&cfg(5.0, 2, 5.0));
        assert_eq!(a.offered(), b.offered());
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival_ms, y.arrival_ms);
        }
        let mut other = cfg(5.0, 2, 5.0);
        other.seed = 4;
        let c = gen(&other);
        assert!(
            a.events.len() != c.events.len()
                || a.events
                    .iter()
                    .zip(&c.events)
                    .any(|(x, y)| x.arrival_ms != y.arrival_ms)
        );
    }

    #[test]
    fn cellular_uplinks_are_slower_than_lan() {
        let mut lan_cfg = cfg(10.0, 4, 10.0);
        let mut cell_cfg = cfg(10.0, 4, 10.0);
        cell_cfg.groups[0].link = LinkProfile::Cellular;
        lan_cfg.seed = 9;
        cell_cfg.seed = 9;
        let mean_uplink = |fleet: &RequestFleet| {
            fleet
                .events
                .iter()
                .map(|e| e.arrival_ms - e.sent_ms)
                .sum::<f64>()
                / fleet.events.len() as f64
        };
        let lan = mean_uplink(&gen(&lan_cfg));
        let cell = mean_uplink(&gen(&cell_cfg));
        assert!(cell > 3.0 * lan, "cellular {cell} vs lan {lan}");
    }

    #[test]
    fn zero_rate_or_zero_clients_offer_nothing() {
        let none = gen(&cfg(0.0, 4, 10.0));
        assert_eq!(none.offered(), 0);
        assert_eq!(none.links.len(), 4);
        let empty = gen(&cfg(5.0, 0, 10.0));
        assert_eq!(empty.offered(), 0);
        assert!(empty.links.is_empty());
    }

    #[test]
    fn merge_interleaves_and_rebases_ids() {
        // Two projects with their own fleets: the merged schedule stays
        // time-ordered, ids and client indices are globally unique, and
        // every event keeps its project tag.
        let a = RequestFleet::generate(ProjectId::new(0), &cfg(10.0, 2, 5.0), &spec());
        let mut bc = cfg(6.0, 3, 5.0);
        bc.seed = 5;
        let b = RequestFleet::generate(ProjectId::new(1), &bc, &spec());
        let (na, nb) = (a.offered(), b.offered());
        assert!(na > 0 && nb > 0);
        let merged = RequestFleet::merge(vec![a, b]);
        assert_eq!(merged.offered(), na + nb);
        assert_eq!(merged.links.len(), 5);
        for w in merged.events.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
        let mut ids: Vec<u64> = merged.events.iter().map(|e| e.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len() as u64, na + nb, "ids stay unique after merge");
        for e in &merged.events {
            if e.project == ProjectId::new(0) {
                assert!(e.id < na && e.client < 2);
            } else {
                assert!(e.id >= na && (2u32..5).contains(&e.client));
            }
        }
    }

    #[test]
    fn merge_with_nan_arrival_does_not_panic() {
        // A hand-corrupted arrival must not panic the merge sort
        // (total_cmp, not partial_cmp().unwrap()): NaN sorts last and
        // every event survives.
        let mut a = RequestFleet::generate(ProjectId::new(0), &cfg(10.0, 2, 2.0), &spec());
        let n = a.offered();
        assert!(n > 0);
        a.events[0].arrival_ms = f64::NAN;
        let merged = RequestFleet::merge(vec![a]);
        assert_eq!(merged.offered(), n);
        assert!(
            merged.events.last().unwrap().arrival_ms.is_nan(),
            "NaN arrival sorts after every finite arrival"
        );
    }

    #[test]
    fn pool_inputs_repeat_across_requests() {
        let mut c = cfg(50.0, 2, 10.0);
        c.input_pool = 2;
        let fleet = gen(&c);
        let first = &fleet.events[0].input;
        assert!(
            fleet.events[1..].iter().any(|e| Arc::ptr_eq(&e.input, first)),
            "a 2-entry pool must produce repeats"
        );
    }
}
