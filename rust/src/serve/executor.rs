//! Micro-batch prediction executor.
//!
//! A flushed batch of admitted requests is padded up to the smallest
//! compiled micro-batch variant (the same `_b{n}` artifact family training
//! uses, §3.3d) and executed once through [`Compute::predict_batch`];
//! per-request rows are then sliced back out.  Padding rows repeat the
//! first input — a valid example whose output is discarded — so the
//! executable always sees its compiled shape.
//!
//! Invariant (the serving correctness criterion): prediction is
//! per-example pure, so executing a request in a batch of 32 yields
//! bit-identical probabilities to executing it alone.  `tests` pin this.

use anyhow::{bail, Result};

use crate::model::ModelSpec;
use crate::runtime::Compute;

/// The served answer for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    /// Argmax class (first index on exact ties — deterministic).
    pub class: usize,
    /// Probability of the argmax class.
    pub confidence: f32,
    /// Full class-probability row.
    pub probs: Vec<f32>,
}

impl Prediction {
    /// Build from one probability row (must be non-empty).
    pub fn from_row(row: &[f32]) -> Self {
        let mut class = 0;
        for (i, &p) in row.iter().enumerate() {
            if p > row[class] {
                class = i;
            }
        }
        Self {
            class,
            confidence: row[class],
            probs: row.to_vec(),
        }
    }
}

/// Server-side hardware model for service-time accounting: the endpoint
/// runs on the master's machine, not a volunteer browser.
#[derive(Debug, Clone, Copy)]
pub struct ServerProfile {
    /// Forward-pass rate (data vectors per second) at full batch.
    pub power_vps: f64,
    /// Fixed per-batch dispatch cost (ms): request framing, buffer
    /// assembly, executable invocation — the part micro-batching
    /// amortizes across requests.
    pub per_batch_overhead_ms: f64,
    /// Service time of a prediction-cache hit (hash + map lookup, ms).
    pub cache_lookup_ms: f64,
    /// Service-time spread: each executed batch takes
    /// `base × (1 + jitter × Exp(1))` — straggler batches from GC pauses,
    /// contention, thermal throttling.  0 (the default) is the idealized
    /// deterministic server; realistic endpoints are ~0.3–0.5, and the
    /// spread is what makes backlog-aware routing (JSQ) beat oblivious
    /// round-robin on tail latency.  Applied by `ServeSim`, not here —
    /// the executor's own accounting stays deterministic.
    pub jitter: f64,
}

impl Default for ServerProfile {
    fn default() -> Self {
        Self {
            // A workstation-class server runs the forward pass roughly an
            // order of magnitude faster than the §3.5 grad+backprop rate.
            power_vps: 4_000.0,
            per_batch_overhead_ms: 2.5,
            cache_lookup_ms: 0.05,
            jitter: 0.0,
        }
    }
}

/// Stateful executor: one served model, cumulative batch statistics.
#[derive(Debug, Clone)]
pub struct BatchExecutor {
    spec: ModelSpec,
    profile: ServerProfile,
    batches: u64,
    examples: u64,
    padded: u64,
    /// Padding rows of the most recent `execute` call only — the trace
    /// plane stamps each batch span with its own padding, not the
    /// cumulative total.
    last_padded: u64,
    /// Flush-assembly buffer, reused across flushes: once grown to the
    /// largest compiled batch it never reallocates (ROADMAP perf item —
    /// this used to be a fresh `Vec` per flush on the serving hot path).
    scratch: Vec<f32>,
}

impl BatchExecutor {
    pub fn new(spec: ModelSpec, profile: ServerProfile) -> Self {
        Self {
            spec,
            profile,
            batches: 0,
            examples: 0,
            padded: 0,
            last_padded: 0,
            scratch: Vec::new(),
        }
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn profile(&self) -> &ServerProfile {
        &self.profile
    }

    /// Batches executed so far.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Real (non-padding) examples executed so far.
    pub fn examples(&self) -> u64 {
        self.examples
    }

    /// Padding examples executed so far.
    pub fn padded(&self) -> u64 {
        self.padded
    }

    /// Padding rows of the most recent `execute` call.
    pub fn last_padded(&self) -> u64 {
        self.last_padded
    }

    /// Fraction of executed rows that were real requests (1.0 = perfectly
    /// full batches).
    pub fn occupancy(&self) -> f64 {
        let total = self.examples + self.padded;
        if total == 0 {
            return 1.0;
        }
        self.examples as f64 / total as f64
    }

    /// Current capacity of the flush-assembly scratch buffer (test hook:
    /// pins the no-per-flush-allocation-growth invariant).
    pub fn scratch_capacity(&self) -> usize {
        self.scratch.capacity()
    }

    /// Largest compiled micro-batch (order-independent; the manifest
    /// normally sorts descending, hand-built specs may not).
    fn largest_batch(&self) -> usize {
        self.spec
            .micro_batches
            .iter()
            .copied()
            .max()
            .unwrap_or(self.spec.batch_size)
    }

    /// Smallest compiled micro-batch that fits `n` requests; oversized
    /// `n` falls back to the largest variant (callers then chunk).
    /// Order-independent over `micro_batches`.
    fn pick_batch(&self, n: usize) -> usize {
        let mut best: Option<usize> = None;
        for &b in &self.spec.micro_batches {
            if b >= n {
                best = Some(match best {
                    Some(cur) => cur.min(b),
                    None => b,
                });
            }
        }
        best.unwrap_or_else(|| self.largest_batch())
    }

    /// Execute one flushed batch of request inputs against a parameter
    /// snapshot.  Returns per-request predictions (input order) and the
    /// modeled service time (ms).  Inputs beyond the largest compiled
    /// variant are chunked into consecutive executions.
    pub fn execute(
        &mut self,
        compute: &mut dyn Compute,
        params: &[f32],
        inputs: &[&[f32]],
    ) -> Result<(Vec<Prediction>, f64)> {
        if inputs.is_empty() {
            return Ok((Vec::new(), 0.0));
        }
        let input_len = self.spec.input_len();
        let classes = self.spec.classes;
        if classes == 0 {
            bail!("model '{}' declares zero classes", self.spec.name);
        }
        for (i, x) in inputs.iter().enumerate() {
            if x.len() != input_len {
                bail!(
                    "request {i}: input has {} values, model '{}' expects {input_len}",
                    x.len(),
                    self.spec.name
                );
            }
        }
        let largest = self.largest_batch().max(1);
        let mut preds = Vec::with_capacity(inputs.len());
        let mut service_ms = 0.0;
        self.last_padded = 0;
        for chunk in inputs.chunks(largest) {
            let b = self.pick_batch(chunk.len());
            self.scratch.clear();
            self.scratch.reserve(b * input_len);
            for x in chunk {
                self.scratch.extend_from_slice(x);
            }
            for _ in chunk.len()..b {
                self.scratch.extend_from_slice(chunk[0]);
            }
            let probs =
                compute.predict_batch(&self.spec.name, b, params, &self.scratch, classes)?;
            if probs.len() != b * classes {
                bail!(
                    "predict returned {} values, expected {} (batch {b} × {classes} classes)",
                    probs.len(),
                    b * classes
                );
            }
            for row in probs.chunks(classes).take(chunk.len()) {
                preds.push(Prediction::from_row(row));
            }
            self.batches += 1;
            self.examples += chunk.len() as u64;
            self.padded += (b - chunk.len()) as u64;
            self.last_padded += (b - chunk.len()) as u64;
            service_ms +=
                self.profile.per_batch_overhead_ms + b as f64 / self.profile.power_vps * 1000.0;
        }
        Ok((preds, service_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;
    use crate::runtime::ModeledCompute;

    fn spec(micro_batches: Vec<usize>) -> ModelSpec {
        let batch_size = micro_batches[0];
        ModelSpec {
            name: "toy".into(),
            param_count: 12,
            batch_size,
            micro_batches,
            input: vec![3, 1, 1],
            classes: 4,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![12],
                offset: 0,
                size: 12,
                fan_in: 3,
            }],
            artifacts: Default::default(),
        }
    }

    fn params() -> Vec<f32> {
        (0..12).map(|i| (i as f32 - 6.0) * 0.2).collect()
    }

    fn inputs(n: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|i| (0..3).map(|j| ((i * 3 + j) as f32 * 0.37).sin().abs()).collect())
            .collect()
    }

    #[test]
    fn batched_equals_unbatched() {
        let mut compute = ModeledCompute { param_count: 12 };
        let xs = inputs(5);
        let refs: Vec<&[f32]> = xs.iter().map(Vec::as_slice).collect();
        let mut batched = BatchExecutor::new(spec(vec![8, 4, 1]), ServerProfile::default());
        let (together, _) = batched.execute(&mut compute, &params(), &refs).unwrap();
        let mut single = BatchExecutor::new(spec(vec![8, 4, 1]), ServerProfile::default());
        for (x, expect) in refs.iter().zip(&together) {
            let (alone, _) = single.execute(&mut compute, &params(), &[x]).unwrap();
            assert_eq!(&alone[0], expect, "batching changed a prediction");
        }
    }

    #[test]
    fn pads_to_smallest_compiled_variant() {
        let mut compute = ModeledCompute { param_count: 12 };
        let xs = inputs(5);
        let refs: Vec<&[f32]> = xs.iter().map(Vec::as_slice).collect();
        let mut ex = BatchExecutor::new(spec(vec![8, 4, 1]), ServerProfile::default());
        ex.execute(&mut compute, &params(), &refs).unwrap();
        // 5 requests → compiled batch 8: 3 padding rows.
        assert_eq!(ex.batches(), 1);
        assert_eq!(ex.examples(), 5);
        assert_eq!(ex.padded(), 3);
        assert_eq!(ex.last_padded(), 3);
        assert!((ex.occupancy() - 5.0 / 8.0).abs() < 1e-12);
        // A second, full flush resets the per-flush padding readout.
        let xs8 = inputs(8);
        let full8: Vec<&[f32]> = xs8.iter().map(Vec::as_slice).collect();
        ex.execute(&mut compute, &params(), &full8).unwrap();
        assert_eq!(ex.last_padded(), 0);
        assert_eq!(ex.padded(), 3);
    }

    #[test]
    fn oversized_batches_chunk() {
        let mut compute = ModeledCompute { param_count: 12 };
        let xs = inputs(9);
        let refs: Vec<&[f32]> = xs.iter().map(Vec::as_slice).collect();
        let mut ex = BatchExecutor::new(spec(vec![4, 1]), ServerProfile::default());
        let (preds, ms) = ex.execute(&mut compute, &params(), &refs).unwrap();
        assert_eq!(preds.len(), 9);
        // 4 + 4 + 1 → three executions, the last on the b=1 variant.
        assert_eq!(ex.batches(), 3);
        assert_eq!(ex.padded(), 0);
        assert!(ms > 0.0);
    }

    #[test]
    fn per_batch_overhead_amortizes() {
        let mut compute = ModeledCompute { param_count: 12 };
        let xs = inputs(8);
        let refs: Vec<&[f32]> = xs.iter().map(Vec::as_slice).collect();
        let mut ex = BatchExecutor::new(spec(vec![8, 1]), ServerProfile::default());
        let (_, one_batch_ms) = ex.execute(&mut compute, &params(), &refs).unwrap();
        let mut singles_ms = 0.0;
        for x in &refs {
            let (_, ms) = ex.execute(&mut compute, &params(), &[x]).unwrap();
            singles_ms += ms;
        }
        assert!(
            one_batch_ms < singles_ms / 2.0,
            "batched {one_batch_ms} ms vs serial {singles_ms} ms"
        );
    }

    #[test]
    fn unsorted_micro_batches_still_pick_smallest_fit() {
        // A hand-built (or ascending) variant list must not inflate the
        // padded batch: 3 requests over [4, 8, 1] pick 4, not 8.
        let mut compute = ModeledCompute { param_count: 12 };
        let xs = inputs(3);
        let refs: Vec<&[f32]> = xs.iter().map(Vec::as_slice).collect();
        let mut ex = BatchExecutor::new(spec(vec![4, 8, 1]), ServerProfile::default());
        ex.execute(&mut compute, &params(), &refs).unwrap();
        assert_eq!(ex.batches(), 1);
        assert_eq!(ex.padded(), 1, "3 → b=4 pads one row, not five");
    }

    #[test]
    fn scratch_buffer_does_not_grow_per_flush() {
        // ROADMAP perf item: flush assembly must reuse one buffer, not
        // allocate per flush.  Warm up at the largest compiled variant,
        // then hammer mixed sizes and assert zero capacity growth.
        let mut compute = ModeledCompute { param_count: 12 };
        let mut ex = BatchExecutor::new(spec(vec![8, 4, 1]), ServerProfile::default());
        let xs = inputs(8);
        let refs: Vec<&[f32]> = xs.iter().map(Vec::as_slice).collect();
        ex.execute(&mut compute, &params(), &refs).unwrap();
        let warm = ex.scratch_capacity();
        assert!(warm >= 8 * 3, "warmed to the largest compiled batch");
        for n in [1usize, 3, 5, 8, 2, 8, 7] {
            for _ in 0..20 {
                ex.execute(&mut compute, &params(), &refs[..n]).unwrap();
            }
        }
        assert_eq!(
            ex.scratch_capacity(),
            warm,
            "per-flush allocation growth on the serving hot path"
        );
    }

    #[test]
    fn rejects_wrong_input_len() {
        let mut compute = ModeledCompute { param_count: 12 };
        let mut ex = BatchExecutor::new(spec(vec![4]), ServerProfile::default());
        let bad = vec![0.0f32; 2];
        assert!(ex.execute(&mut compute, &params(), &[&bad]).is_err());
    }

    #[test]
    fn empty_batch_is_noop() {
        let mut compute = ModeledCompute { param_count: 12 };
        let mut ex = BatchExecutor::new(spec(vec![4]), ServerProfile::default());
        let (preds, ms) = ex.execute(&mut compute, &params(), &[]).unwrap();
        assert!(preds.is_empty());
        assert_eq!(ms, 0.0);
        assert_eq!(ex.batches(), 0);
        assert_eq!(ex.occupancy(), 1.0);
    }

    #[test]
    fn prediction_from_row_ties_break_low() {
        let p = Prediction::from_row(&[0.2, 0.4, 0.4]);
        assert_eq!(p.class, 1);
        assert_eq!(p.confidence, 0.4);
    }
}
