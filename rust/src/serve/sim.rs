//! Discrete-event serving: route → admission → cache → coalesce →
//! micro-batch → execute → respond, over the multi-project
//! [`ControlPlane`] and a simulated request fleet.
//!
//! The core is [`ServeEngine`], an *incrementally pumpable* event loop:
//! `pump(horizon)` processes every arrival and batch flush up to a
//! virtual-time horizon and then returns, leaving queued work pending.
//! That is what the serve × train co-simulation ([`crate::cosim`]) needs
//! — the training masters advance the shared clock one iteration at a
//! time and the serving tier fills in the window between boundaries,
//! with snapshot publications (hot swaps) landing at the boundaries.
//! [`ServeSim`] is the closed-loop wrapper the serving-only paths use:
//! one `pump(None)` to drain the whole schedule.
//!
//! Multi-tenancy: every request carries its [`ProjectId`]; the engine
//! stamps it with the typed `ModelVersion` active for that project at
//! arrival.  Batches are version-pure (and therefore project-pure — the
//! handle names both), cache keys are project-scoped, each shard runs one
//! executor per project, and admission is weighted fair-share: a hot
//! project saturating the tier is shed at its own cap while the cold
//! project's reserved slice stays admittable.
//!
//! Version consistency under hot swap: each request is stamped with the
//! version active at its arrival, carries it through admission, and is
//! computed entirely against that version — the queue cuts batches at
//! version boundaries and the registry holds a reader pin per admitted
//! request so traffic-driven GC cannot evict a version with in-flight
//! work.  Cache keys include the version, so a swap invalidates the cache
//! by construction (and a rollback revalidates the old entries).
//!
//! Failover: when the routed shard refuses admission (queue full, project
//! cap reached, or drained via `queue_depth: 0`), the arrival is
//! re-offered to the other shards in least-outstanding-work order; it is
//! shed only when every endpoint refuses.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::metrics::{Histogram, RejectionRecord, RequestLog, RequestRecord};
use crate::netsim::LinkModel;
use crate::rng::{Exp, Pcg32};
use crate::runtime::Compute;
use crate::trace::{ArgValue, TraceHandle, Track};

use super::cache::input_key;
use super::control::{ControlPlane, ProjectId, ProjectStats};
use super::executor::{Prediction, ServerProfile};
use super::loadgen::{FleetConfig, RequestEvent, RequestFleet};
use super::queue::{BatchPolicy, PredictRequest};
use super::registry::SnapshotMeta;
use super::router::{
    failover_order, Join, Router, RouterConfig, RoutingPolicy, Shard, ShardStats, Waiter,
};

/// Everything one serving run needs besides the control plane and
/// compute.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// One request fleet per registered project (index = project id).
    pub fleets: Vec<FleetConfig>,
    pub policy: BatchPolicy,
    pub server: ServerProfile,
    /// Fleet shape: shard count, routing policy, coalescing, autotune,
    /// fair share.
    pub router: RouterConfig,
    /// Heterogeneous fleet: profile overrides per shard index (shorter
    /// than the shard count → remaining shards use `server`).
    pub shard_profiles: Vec<ServerProfile>,
    /// Shards whose admission queue starts closed (`queue_depth: 0`) —
    /// drained endpoints the router fails over around.
    pub drained_shards: Vec<usize>,
    /// Per-shard prediction-cache capacity in entries (0 disables).
    pub cache_capacity: usize,
    /// Response payload on the downlink (class + confidence + envelope).
    pub response_bytes: u64,
    /// Retain the full per-request [`RequestLog`]?  Percentiles come from
    /// the constant-memory [`Histogram`] either way; the log exists for
    /// explicit CSV export and per-record assertions, and at 10⁵+
    /// requests it is the report's only unbounded allocation — turn it
    /// off when only aggregates are consumed.
    pub keep_log: bool,
}

/// Outcome of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Per-request records — empty when `ServeConfig::keep_log` is off.
    pub log: RequestLog,
    /// End-to-end latency distribution of every completed request,
    /// accumulated online (constant memory, independent of `keep_log`).
    pub latency_hist: Histogram,
    /// Per-project latency distributions (index = project id).
    pub latency_by_project: Vec<Histogram>,
    pub offered: u64,
    pub completed: u64,
    pub rejected: u64,
    pub cache_hits: u64,
    /// Requests answered by piggybacking on an in-flight duplicate.
    pub coalesced: u64,
    /// Requests the routed shard refused that another shard served.
    pub failovers: u64,
    pub batches: u64,
    /// Real requests executed in batches (excludes cache hits, coalesced
    /// waiters and padding).
    pub batch_examples: u64,
    pub padded_examples: u64,
    /// The fleet shape the run used.
    pub router: RouterConfig,
    /// Per-shard counters (one entry per endpoint, index order).
    pub per_shard: Vec<ShardStats>,
    /// Per-project counters (one entry per registered project, id order).
    pub per_project: Vec<ProjectStats>,
    /// Emission horizon (s) — offered-load normalizer.
    pub duration_s: f64,
    /// Virtual time of the last response (s).
    pub span_s: f64,
}

impl ServeReport {
    /// Completed requests per second of emission horizon.  Counter-based,
    /// not log-based — correct with `keep_log` off.
    pub fn throughput_rps(&self) -> f64 {
        let horizon = self.duration_s.max(self.span_s);
        if horizon <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / horizon
    }

    /// End-to-end latency distribution (p50/p95/p99/p999, min/max, mean).
    pub fn latency(&self) -> &Histogram {
        &self.latency_hist
    }

    pub fn hit_rate(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / self.completed as f64
    }

    /// Fraction of offered requests shed at admission.
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.offered as f64
    }

    /// Mean executed-batch size (real requests per flush).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batch_examples as f64 / self.batches as f64
    }

    /// One project's counters.
    pub fn project(&self, project: ProjectId) -> &ProjectStats {
        &self.per_project[project.index()]
    }

    /// One-line human summary.  Percentiles print as `-` when nothing
    /// completed (a closed endpoint sheds everything).
    pub fn summary(&self) -> String {
        let lat = self.latency();
        let ms = |v: f64| {
            if v.is_finite() {
                format!("{v:.1}")
            } else {
                "-".into()
            }
        };
        format!(
            "projects={} shards={} router={} offered={} completed={} rejected={} \
             coalesced={} failover={} hit_rate={:.2} mean_batch={:.1} p50={}ms p95={}ms \
             p99={}ms throughput={:.1} rps",
            self.per_project.len(),
            self.per_shard.len(),
            self.router.policy.name(),
            self.offered,
            self.completed,
            self.rejected,
            self.coalesced,
            self.failovers,
            self.hit_rate(),
            self.mean_batch(),
            ms(lat.median()),
            ms(lat.p95()),
            ms(lat.quantile(0.99)),
            self.throughput_rps(),
        )
    }
}

/// Hook invoked for every served response, with the snapshot that
/// answered it and compute access (the co-simulation's staleness probe
/// re-predicts against the live master parameters here).  The record has
/// not yet been pushed to the log when the hook runs.
pub trait ServeObserver {
    fn on_response(
        &mut self,
        record: &RequestRecord,
        input: &Arc<Vec<f32>>,
        served: &Prediction,
        snapshot: SnapshotMeta,
        compute: &mut dyn Compute,
    ) -> Result<()>;
}

/// Observer that records nothing (plain serving runs).
pub struct NoopObserver;

impl ServeObserver for NoopObserver {
    fn on_response(
        &mut self,
        _record: &RequestRecord,
        _input: &Arc<Vec<f32>>,
        _served: &Prediction,
        _snapshot: SnapshotMeta,
        _compute: &mut dyn Compute,
    ) -> Result<()> {
        Ok(())
    }
}

/// Did a shard handle the arrival, or refuse it for lack of queue space?
enum ArrivalOutcome {
    Handled,
    Refused,
}

/// The incrementally pumpable serving event loop: shards + router +
/// request schedule on one virtual clock.  See the module docs.
pub struct ServeEngine {
    router_cfg: RouterConfig,
    coalesce: bool,
    caching: bool,
    need_key: bool,
    response_bytes: u64,
    duration_s: f64,
    shards: Vec<Shard>,
    router: Router,
    fleet: RequestFleet,
    /// Requests each project's fleet offered (index = project id).
    offered_by_project: Vec<u64>,
    /// Arrival cursor into `fleet.events`.
    next: usize,
    now: f64,
    log: RequestLog,
    /// Downlink + service jitter draws; separate stream from the load
    /// generator so admission decisions cannot perturb arrivals.
    rng: Pcg32,
    /// Straggler spread for executed batches (GC pauses, contention);
    /// standard exponential scaled by each shard's `ServerProfile::jitter`.
    straggler: Exp,
    failovers: u64,
    keep_log: bool,
    /// Counter/histogram accounting mirrors what the log used to derive,
    /// so reports stay exact with the log off.
    completed: u64,
    completed_by: Vec<u64>,
    rejected_by: Vec<u64>,
    hist: Histogram,
    hist_by_project: Vec<Histogram>,
    /// Latest response time seen (ms) — the report's span.
    last_done_ms: f64,
    trace: TraceHandle,
}

impl ServeEngine {
    /// Build shards, router and the merged multi-project arrival
    /// schedule.  `plane` supplies the served specs and the fair-share
    /// weights; `cfg.fleets` must carry one fleet per registered project.
    pub fn new(cfg: &ServeConfig, plane: &ControlPlane) -> Result<Self> {
        let specs = plane.specs();
        if specs.is_empty() {
            bail!("control plane has no registered projects");
        }
        if cfg.fleets.len() != specs.len() {
            bail!(
                "{} fleet config(s) for {} registered project(s)",
                cfg.fleets.len(),
                specs.len()
            );
        }
        let fleets: Vec<RequestFleet> = cfg
            .fleets
            .iter()
            .zip(&specs)
            .enumerate()
            .map(|(i, (fleet, spec))| {
                RequestFleet::generate(ProjectId::new(i as u32), fleet, spec)
            })
            .collect();
        let offered_by_project: Vec<u64> = fleets.iter().map(RequestFleet::offered).collect();
        let fleet = RequestFleet::merge(fleets);

        // Clamp the flush size to the largest compiled micro-batch across
        // the hosted specs so every flushed batch is exactly one
        // execution — `batch_size` in the log then always names a real
        // executed batch.  (Batches are project-pure, so a project with
        // smaller variants simply chunks below the clamp.)
        let largest = specs
            .iter()
            .flat_map(|s| s.micro_batches.iter().copied())
            .max()
            .unwrap_or_else(|| specs.iter().map(|s| s.batch_size).max().unwrap_or(1))
            .max(1);
        let mut policy = cfg.policy;
        policy.max_batch = policy.max_batch.clamp(1, largest);

        let router_cfg = cfg.router;
        let coalesce = router_cfg.coalesce;
        let caching = cfg.cache_capacity > 0;
        let affinity = router_cfg.policy == RoutingPolicy::InputAffinity;
        // Hashing ~KB of pixels per request only pays off when something
        // consumes the key: a cache, the in-flight table, or the
        // affinity router.
        let need_key = caching || coalesce || affinity;
        // Weighted fair-share admission caps, enforced per shard queue.
        let caps = if router_cfg.fair_share {
            plane.queue_caps(policy.queue_depth)
        } else {
            Vec::new()
        };
        let mut shards: Vec<Shard> = (0..router_cfg.shards.max(1))
            .map(|i| {
                let profile = cfg.shard_profiles.get(i).copied().unwrap_or(cfg.server);
                let mut shard =
                    Shard::new(i as u32, policy, cfg.cache_capacity, &specs, profile, &router_cfg);
                shard.queue.set_project_caps(caps.clone());
                shard
            })
            .collect();
        for &i in &cfg.drained_shards {
            if let Some(s) = shards.get_mut(i) {
                s.drain();
            }
        }
        // Mixing fold (not a plain XOR): two fleets sharing a seed must
        // not cancel out of the engine's jitter stream.
        let seed = cfg.fleets.iter().fold(0x5E12Eu64, |acc, f| {
            acc.rotate_left(17) ^ f.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        });
        let duration_s = cfg
            .fleets
            .iter()
            .map(|f| f.duration_s)
            .fold(0.0, f64::max);
        let projects = offered_by_project.len();
        Ok(Self {
            router_cfg,
            coalesce,
            caching,
            need_key,
            response_bytes: cfg.response_bytes,
            duration_s,
            router: Router::new(router_cfg.policy),
            rng: Pcg32::new(seed),
            straggler: Exp::new(1.0),
            shards,
            fleet,
            offered_by_project,
            next: 0,
            now: 0.0,
            log: RequestLog::new(),
            failovers: 0,
            keep_log: cfg.keep_log,
            completed: 0,
            completed_by: vec![0; projects],
            rejected_by: vec![0; projects],
            hist: Histogram::new(),
            hist_by_project: vec![Histogram::new(); projects],
            last_done_ms: 0.0,
            trace: TraceHandle::off(),
        })
    }

    /// Attach a trace handle (share one across planes for a unified
    /// timeline).  The engine emits per-request lifecycle spans, batch
    /// execution spans, and the publication→first-serve flow edges.
    pub fn set_trace(&mut self, trace: TraceHandle) {
        self.trace = trace;
        // Baseline gauges at t=0: each shard's installed fair-share caps
        // (empty when fair share is off), one counter track per
        // (project, shard) so cap vs observed depth read side by side.
        for (si, shard) in self.shards.iter().enumerate() {
            for (pi, &cap) in shard.queue.project_caps().iter().enumerate() {
                self.trace.counter(
                    Track::shard(pi as u32, si as u32),
                    "serve/fair-share-cap",
                    0.0,
                    &[("cap", cap as f64)],
                );
            }
        }
    }

    /// One completed response, whatever the path (executed, cache hit,
    /// coalesced waiter): counters, histograms, the request span's end,
    /// and — when retained — the log record.
    fn finish_request(&mut self, rec: RequestRecord) {
        self.completed += 1;
        let pi = rec.version.project.index();
        self.completed_by[pi] += 1;
        self.hist.observe(rec.latency_ms);
        self.hist_by_project[pi].observe(rec.latency_ms);
        if rec.done_ms > self.last_done_ms {
            self.last_done_ms = rec.done_ms;
        }
        let outcome = if rec.coalesced { "coalesced" } else { "served" };
        self.trace.async_end(
            Track::shard(rec.version.project.as_u32(), rec.shard),
            "serve",
            "request",
            rec.id,
            rec.done_ms,
            &[
                ("outcome", ArgValue::Str(outcome)),
                ("cache_hit", ArgValue::U64(rec.cache_hit as u64)),
                ("latency_ms", ArgValue::F64(rec.latency_ms)),
                ("version", ArgValue::U64(rec.version.version)),
            ],
        );
        if self.keep_log {
            self.log.push(rec);
        }
    }

    /// The per-request log so far.
    pub fn log(&self) -> &RequestLog {
        &self.log
    }

    /// Arrivals not yet processed (those after the last pump horizon).
    pub fn remaining_arrivals(&self) -> usize {
        self.fleet.events.len() - self.next
    }

    /// Process every arrival and flush with event time ≤ `horizon`
    /// (`None` = drain the whole schedule).  The control plane supplies
    /// each project's active version for new arrivals and holds reader
    /// pins for admitted ones; callers may publish / stage / activate /
    /// roll back / GC between pumps — never during one.
    pub fn pump(
        &mut self,
        horizon: Option<f64>,
        plane: &mut ControlPlane,
        compute: &mut dyn Compute,
        observer: &mut dyn ServeObserver,
    ) -> Result<()> {
        loop {
            let arrival = self
                .fleet
                .events
                .get(self.next)
                .map(|e| e.arrival_ms)
                .filter(|&t| horizon.is_none_or(|h| t <= h));
            let flush = next_flush(&self.shards, self.now)
                .filter(|&(t, _)| horizon.is_none_or(|h| t <= h));
            // Arrivals win ties so a request landing exactly at a flush
            // time still joins that batch.
            let take_arrival = match (arrival, flush) {
                (None, None) => return Ok(()),
                (Some(a), Some((f, _))) => a <= f,
                (Some(_), None) => true,
                (None, Some(_)) => false,
            };
            if take_arrival {
                let ev = self.fleet.events[self.next].clone();
                self.next += 1;
                self.now = ev.arrival_ms;
                let meta = plane
                    .active(ev.project)
                    .ok_or_else(|| {
                        anyhow!("project {} has no active snapshot", ev.project)
                    })?
                    .meta();
                let key = if self.need_key {
                    input_key(meta.version, &ev.input)
                } else {
                    0
                };
                let si = self.router.route(key, &self.shards, self.now);
                // One request-lifecycle span per arrival, opened on the
                // originally routed shard's track; exactly one matching
                // end (served / coalesced / shed) closes it.
                self.trace.async_begin(
                    Track::shard(ev.project.as_u32(), si as u32),
                    "serve",
                    "request",
                    ev.id,
                    self.now,
                    &[("client", ArgValue::U64(ev.client as u64))],
                );
                let mut outcome =
                    self.offer_to_shard(si, &ev, key, meta, plane, compute, observer)?;
                if matches!(outcome, ArrivalOutcome::Refused) && self.shards.len() > 1 {
                    // Router-level failover: re-offer to the other shards,
                    // least outstanding work first.
                    for j in failover_order(si, &self.shards, self.now) {
                        outcome =
                            self.offer_to_shard(j, &ev, key, meta, plane, compute, observer)?;
                        if matches!(outcome, ArrivalOutcome::Handled) {
                            self.failovers += 1;
                            break;
                        }
                    }
                }
                if matches!(outcome, ArrivalOutcome::Refused) {
                    // Every candidate refused: shed, attributed to the
                    // originally routed shard.
                    let shard = &mut self.shards[si];
                    shard.note_routed();
                    shard.queue.note_shed();
                    self.rejected_by[ev.project.index()] += 1;
                    self.trace.async_end(
                        Track::shard(ev.project.as_u32(), si as u32),
                        "serve",
                        "request",
                        ev.id,
                        self.now,
                        &[("outcome", ArgValue::Str("shed"))],
                    );
                    if self.keep_log {
                        self.log.push_rejection(RejectionRecord {
                            id: ev.id,
                            client: ev.client,
                            project: ev.project,
                            sent_ms: ev.sent_ms,
                            arrival_ms: self.now,
                            shard: si as u32,
                        });
                    }
                }
            } else if let Some((f, si)) = flush {
                self.now = f;
                self.shards[si].tick(f);
                let batch = self.shards[si].queue.take_batch();
                let Some(first) = batch.first() else { continue };
                // Answer consistency: a flushed batch carries exactly one
                // version — one project, one snapshot — (the queue cuts at
                // version boundaries) and is computed entirely against it.
                let vid = first.version;
                debug_assert!(
                    batch.iter().all(|r| r.version == vid),
                    "a flushed batch mixed model versions"
                );
                let snap = plane.get(vid).ok_or_else(|| {
                    anyhow!(
                        "snapshot {vid} evicted with {} in-flight request(s)",
                        batch.len()
                    )
                })?;
                let meta = snap.meta();
                let params = Arc::clone(&snap.params);
                let inputs: Vec<&[f32]> = batch.iter().map(|r| r.input.as_slice()).collect();
                let (preds, base_service_ms) = self.shards[si]
                    .executor_mut(vid.project)
                    .execute(compute, &params, &inputs)?;
                // Straggler batches: multiplicative spread on the modeled
                // service time, per this shard's own profile.  Zero jitter
                // draws nothing, so idealized runs keep exact timelines.
                let jitter = self.shards[si].profile.jitter;
                let service_ms = if jitter > 0.0 {
                    base_service_ms * (1.0 + jitter * self.straggler.sample(&mut self.rng))
                } else {
                    base_service_ms
                };
                let computed_at = self.now + service_ms;
                self.shards[si].free_at = computed_at;
                self.shards[si].executing = batch.len();
                let padded = self.shards[si].executor_mut(vid.project).last_padded();
                self.trace.span(
                    Track::shard(vid.project.as_u32(), si as u32),
                    "serve",
                    "batch",
                    self.now,
                    computed_at,
                    &[
                        ("size", ArgValue::U64(batch.len() as u64)),
                        ("padded", ArgValue::U64(padded)),
                        ("version", ArgValue::U64(vid.version)),
                        ("cut", ArgValue::Str(self.shards[si].queue.last_cut())),
                    ],
                );
                // Queue gauge at the cut: what stayed behind and what the
                // shard is now executing.
                self.trace.counter(
                    Track::shard(vid.project.as_u32(), si as u32),
                    "serve/queue",
                    self.now,
                    &[
                        ("depth", self.shards[si].queue.len() as f64),
                        ("in_flight", batch.len() as f64),
                    ],
                );
                // First batch executed on a freshly published version:
                // close that publication's flow edge here.  No-op unless
                // a publication opened the edge (plain serving runs emit
                // nothing), and only the first execution per version
                // binds the arrow.
                self.trace.flow_end(
                    Track::shard(vid.project.as_u32(), si as u32),
                    "publish",
                    "first-serve",
                    vid.flow_id(),
                    self.now,
                );
                for (req, pred) in batch.iter().zip(&preds) {
                    if self.coalesce {
                        // Fan the one computed answer out to every waiter
                        // that coalesced onto this leader.
                        let waiters = self.shards[si].resolve_inflight(req, computed_at, pred);
                        for w in waiters {
                            let done = computed_at
                                + respond_ms(
                                    &self.fleet.links,
                                    w.client,
                                    self.response_bytes,
                                    &mut self.rng,
                                );
                            let rec = RequestRecord {
                                id: w.id,
                                client: w.client,
                                sent_ms: w.sent_ms,
                                done_ms: done,
                                latency_ms: done - w.sent_ms,
                                shard: si as u32,
                                version: vid,
                                batch_size: 0,
                                cache_hit: false,
                                coalesced: true,
                                class: pred.class as u32,
                            };
                            observer.on_response(&rec, &req.input, pred, meta, compute)?;
                            self.finish_request(rec);
                        }
                    }
                    if self.caching {
                        // One fill per computation — waiters never insert.
                        // Visible once virtual time passes `computed_at`.
                        self.shards[si].schedule_insert(
                            computed_at,
                            req.key,
                            Arc::clone(&req.input),
                            pred.clone(),
                        );
                    }
                    let done = computed_at
                        + respond_ms(
                            &self.fleet.links,
                            req.client,
                            self.response_bytes,
                            &mut self.rng,
                        );
                    let rec = RequestRecord {
                        id: req.id,
                        client: req.client,
                        sent_ms: req.sent_ms,
                        done_ms: done,
                        latency_ms: done - req.sent_ms,
                        shard: si as u32,
                        version: vid,
                        batch_size: batch.len() as u32,
                        cache_hit: false,
                        coalesced: false,
                        class: pred.class as u32,
                    };
                    observer.on_response(&rec, &req.input, pred, meta, compute)?;
                    self.finish_request(rec);
                    // The computation ran: release the admission-time
                    // reader pin so GC can reclaim the version.
                    plane.unpin_reader(vid);
                }
                if self.caching {
                    // Cache gauge after the batch's fills were scheduled
                    // (`size` counts *visible* entries — fills mature at
                    // `computed_at`, so this samples the pre-fill state).
                    self.trace.counter(
                        Track::shard(vid.project.as_u32(), si as u32),
                        "serve/cache",
                        self.now,
                        &[
                            ("hit_rate", self.shards[si].cache.hit_rate()),
                            ("occupancy", self.shards[si].cache.occupancy()),
                            ("size", self.shards[si].cache.len() as f64),
                        ],
                    );
                }
            }
        }
    }

    /// Offer one arrival to one shard: cache hit, coalesce join, or
    /// admission (with a reader pin on the admitted version).  Returns
    /// `Refused` when the shard's queue — or the project's fair share of
    /// it — has no room; the caller then fails over or sheds.
    #[allow(clippy::too_many_arguments)]
    fn offer_to_shard(
        &mut self,
        si: usize,
        ev: &RequestEvent,
        key: u64,
        meta: SnapshotMeta,
        plane: &mut ControlPlane,
        compute: &mut dyn Compute,
        observer: &mut dyn ServeObserver,
    ) -> Result<ArrivalOutcome> {
        let now = self.now;
        self.shards[si].tick(now);
        if self.caching {
            let hit = self.shards[si].cache.get(key, &ev.input);
            if let Some(pred) = hit {
                let done = now
                    + self.shards[si].profile.cache_lookup_ms
                    + respond_ms(&self.fleet.links, ev.client, self.response_bytes, &mut self.rng);
                let rec = RequestRecord {
                    id: ev.id,
                    client: ev.client,
                    sent_ms: ev.sent_ms,
                    done_ms: done,
                    latency_ms: done - ev.sent_ms,
                    shard: si as u32,
                    version: meta.version,
                    batch_size: 0,
                    cache_hit: true,
                    coalesced: false,
                    class: pred.class as u32,
                };
                observer.on_response(&rec, &ev.input, &pred, meta, compute)?;
                self.finish_request(rec);
                self.shards[si].note_routed();
                self.trace.counter(
                    Track::shard(meta.version.project.as_u32(), si as u32),
                    "serve/cache",
                    now,
                    &[
                        ("hit_rate", self.shards[si].cache.hit_rate()),
                        ("occupancy", self.shards[si].cache.occupancy()),
                        ("size", self.shards[si].cache.len() as f64),
                    ],
                );
                return Ok(ArrivalOutcome::Handled);
            }
        }
        let waiter = Waiter {
            id: ev.id,
            client: ev.client,
            sent_ms: ev.sent_ms,
        };
        if self.coalesce {
            match self.shards[si].coalesce_join(key, &ev.input, waiter) {
                // The duplicate's computation already finished but is not
                // yet visible as a cache entry: share its answer.
                Join::Ready(computed_at, pred) => {
                    let done = computed_at
                        + respond_ms(&self.fleet.links, ev.client, self.response_bytes, &mut self.rng);
                    let rec = RequestRecord {
                        id: ev.id,
                        client: ev.client,
                        sent_ms: ev.sent_ms,
                        done_ms: done,
                        latency_ms: done - ev.sent_ms,
                        shard: si as u32,
                        version: meta.version,
                        batch_size: 0,
                        cache_hit: false,
                        coalesced: true,
                        class: pred.class as u32,
                    };
                    observer.on_response(&rec, &ev.input, &pred, meta, compute)?;
                    self.finish_request(rec);
                    self.shards[si].note_routed();
                    return Ok(ArrivalOutcome::Handled);
                }
                // Attached as a waiter; answered at the leader's
                // completion in the flush branch.
                Join::Queued => {
                    self.shards[si].note_routed();
                    return Ok(ArrivalOutcome::Handled);
                }
                Join::Admit => {}
            }
        }
        if !self.shards[si].queue.can_admit(ev.project) {
            return Ok(ArrivalOutcome::Refused);
        }
        let admitted = self.shards[si].admit(
            PredictRequest {
                id: ev.id,
                client: ev.client,
                sent_ms: ev.sent_ms,
                arrival_ms: now,
                input: Arc::clone(&ev.input),
                key,
                version: meta.version,
            },
            self.coalesce,
        );
        debug_assert!(admitted, "can_admit probe and offer disagree");
        // The admitted request will execute against this version: pin it
        // so traffic-driven GC cannot evict it first.
        plane.pin_reader(meta.version).map_err(|e| anyhow!(e))?;
        // Only arrivals that actually entered the queue drive the autotune
        // rate estimate — hits, waiters and sheds never fill a batch slot,
        // so counting them would mistune the deadline and flush size.
        self.shards[si].observe_admission(now);
        self.shards[si].note_routed();
        // Queue gauge after admission: the depth the next arrival sees.
        self.trace.counter(
            Track::shard(ev.project.as_u32(), si as u32),
            "serve/queue",
            now,
            &[
                ("depth", self.shards[si].queue.len() as f64),
                ("in_flight", self.shards[si].executing as f64),
            ],
        );
        Ok(ArrivalOutcome::Handled)
    }

    /// End-of-run accounting.  Everything here comes from online
    /// counters/histograms, never the log — identical reports with
    /// `keep_log` off.
    pub fn into_report(self) -> ServeReport {
        let span_s = self.last_done_ms / 1000.0;
        let per_shard: Vec<ShardStats> = self.shards.iter().map(Shard::stats).collect();
        let per_project: Vec<ProjectStats> = self
            .offered_by_project
            .iter()
            .enumerate()
            .map(|(i, &offered)| ProjectStats {
                project: ProjectId::new(i as u32),
                offered,
                completed: self.completed_by[i],
                rejected: self.rejected_by[i],
            })
            .collect();
        ServeReport {
            offered: self.fleet.offered(),
            completed: self.completed,
            rejected: per_shard.iter().map(|s| s.rejected).sum(),
            cache_hits: per_shard.iter().map(|s| s.cache_hits).sum(),
            coalesced: per_shard.iter().map(|s| s.coalesced).sum(),
            failovers: self.failovers,
            batches: per_shard.iter().map(|s| s.batches).sum(),
            batch_examples: per_shard.iter().map(|s| s.batch_examples).sum(),
            padded_examples: per_shard.iter().map(|s| s.padded_examples).sum(),
            router: self.router_cfg,
            per_shard,
            per_project,
            duration_s: self.duration_s,
            span_s,
            latency_hist: self.hist,
            latency_by_project: self.hist_by_project,
            log: self.log,
        }
    }
}

/// A configured serving run over one control plane + compute backend.
pub struct ServeSim<'c> {
    cfg: ServeConfig,
    plane: ControlPlane,
    compute: &'c mut dyn Compute,
}

impl<'c> ServeSim<'c> {
    pub fn new(cfg: ServeConfig, plane: ControlPlane, compute: &'c mut dyn Compute) -> Self {
        Self {
            cfg,
            plane,
            compute,
        }
    }

    pub fn plane(&self) -> &ControlPlane {
        &self.plane
    }

    /// Run the full request schedule to completion.
    pub fn run(&mut self) -> Result<ServeReport> {
        self.run_traced(TraceHandle::off())
    }

    /// Run with a trace handle attached — per-request lifecycle and batch
    /// spans land on the shared timeline.
    pub fn run_traced(&mut self, trace: TraceHandle) -> Result<ServeReport> {
        for p in self.plane.ids() {
            if self.plane.active(p).is_none() {
                return Err(anyhow!("project {p} has no active snapshot"));
            }
        }
        let mut engine = ServeEngine::new(&self.cfg, &self.plane)?;
        engine.set_trace(trace);
        engine.pump(None, &mut self.plane, &mut *self.compute, &mut NoopObserver)?;
        Ok(engine.into_report())
    }
}

/// Earliest pending flush across the fleet: `(time, shard)`, ties to the
/// lowest shard index.  `None` when every queue is empty.
fn next_flush(shards: &[Shard], now: f64) -> Option<(f64, usize)> {
    let mut best: Option<(f64, usize)> = None;
    for (i, s) in shards.iter().enumerate() {
        if let Some(t) = s.queue.next_flush_at(s.free_at) {
            let t = t.max(now);
            if best.is_none_or(|(bt, _)| t < bt) {
                best = Some((t, i));
            }
        }
    }
    best
}

/// Downlink time for a response to `client`: latency jitter + transmission.
fn respond_ms(links: &[LinkModel], client: u32, bytes: u64, rng: &mut Pcg32) -> f64 {
    let link = &links[client as usize];
    link.sample_latency_ms(rng) + link.transmit_ms(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelSpec, TensorSpec};
    use crate::netsim::LinkProfile;
    use crate::runtime::ModeledCompute;
    use crate::serve::loadgen::ClientSpec;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 24,
            batch_size: 8,
            micro_batches: vec![8, 4, 1],
            input: vec![4, 1, 1],
            classes: 3,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![24],
                offset: 0,
                size: 24,
                fan_in: 4,
            }],
            artifacts: Default::default(),
        }
    }

    fn config(rate: f64, clients: usize, cache: usize) -> ServeConfig {
        ServeConfig {
            fleets: vec![FleetConfig {
                groups: vec![ClientSpec {
                    link: LinkProfile::Lan,
                    rate_rps: rate,
                    count: clients,
                }],
                duration_s: 5.0,
                input_pool: 16,
                seed: 11,
            }],
            policy: BatchPolicy {
                max_batch: 8,
                max_wait_ms: 5.0,
                queue_depth: 64,
            },
            server: ServerProfile::default(),
            router: RouterConfig::single(),
            shard_profiles: Vec::new(),
            drained_shards: Vec::new(),
            cache_capacity: cache,
            response_bytes: 256,
            keep_log: true,
        }
    }

    fn test_params() -> Vec<f32> {
        (0..24).map(|i| ((i * 13 % 7) as f32 - 3.0) * 0.2).collect()
    }

    fn plane() -> ControlPlane {
        let mut plane = ControlPlane::single(spec());
        plane
            .registry_mut(ProjectId::new(0))
            .publish_params(test_params(), 5, "test".into(), 0.0)
            .unwrap();
        plane
    }

    fn run_cfg(cfg: ServeConfig) -> ServeReport {
        let mut compute = ModeledCompute { param_count: 24 };
        let mut sim = ServeSim::new(cfg, plane(), &mut compute);
        sim.run().unwrap()
    }

    /// Sorted (id, class) pairs — the answer-identity fingerprint.
    fn classes_by_id(report: &ServeReport) -> Vec<(u64, u32)> {
        let mut v: Vec<(u64, u32)> = report
            .log
            .records()
            .iter()
            .map(|r| (r.id, r.class))
            .collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn accounts_for_every_request() {
        let report = run_cfg(config(20.0, 4, 0));
        assert!(report.offered > 0);
        assert_eq!(report.completed + report.rejected, report.offered);
        assert_eq!(report.batch_examples, report.completed - report.cache_hits);
        for r in report.log.records() {
            assert!(r.latency_ms > 0.0, "{r:?}");
            assert!(r.done_ms > r.sent_ms);
            assert_eq!(r.version.version, 1, "single-version run");
            assert_eq!(r.version.project, ProjectId::new(0));
        }
        // Per-project accounting mirrors the global one on a single
        // project.
        assert_eq!(report.per_project.len(), 1);
        let p = report.project(ProjectId::new(0));
        assert_eq!(p.offered, report.offered);
        assert_eq!(p.completed, report.completed);
        assert_eq!(p.rejected, report.rejected);
        // The online histogram saw exactly the completions the log did,
        // and its percentiles track the exact (log-derived) ones.
        assert_eq!(report.latency().count(), report.completed);
        assert_eq!(report.latency_by_project[0].count(), report.completed);
        let exact = report.log.latency_summary();
        assert_eq!(report.latency().min(), exact.min());
        assert_eq!(report.latency().max(), exact.max());
        let rel = (report.latency().median() - exact.median()).abs() / exact.median();
        assert!(rel < 0.015, "histogram p50 drifted {rel} from exact");
    }

    #[test]
    fn no_snapshot_is_an_error() {
        let mut compute = ModeledCompute { param_count: 24 };
        let empty = ControlPlane::single(spec());
        let mut sim = ServeSim::new(config(5.0, 1, 0), empty, &mut compute);
        assert!(sim.run().is_err());
    }

    #[test]
    fn fleet_count_must_match_project_count() {
        let mut cfg = config(5.0, 1, 0);
        cfg.fleets.push(cfg.fleets[0].clone());
        let mut compute = ModeledCompute { param_count: 24 };
        let mut sim = ServeSim::new(cfg, plane(), &mut compute);
        assert!(sim.run().is_err(), "2 fleets for 1 project must refuse");
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed: u64| {
            let mut cfg = config(10.0, 3, 32);
            cfg.fleets[0].seed = seed;
            run_cfg(cfg).log.to_csv()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn small_input_pool_drives_cache_hits() {
        let mut cfg = config(40.0, 4, 256);
        cfg.fleets[0].input_pool = 4;
        let report = run_cfg(cfg);
        assert!(
            report.hit_rate() > 0.5,
            "4-input pool should mostly hit: {}",
            report.summary()
        );
        assert!(report.cache_hits > 0 && report.batch_examples > 0);
        // Cache hits skip the executor, so executed examples + hits must
        // still account for every completed request (coalescing off).
        assert_eq!(report.batch_examples + report.cache_hits, report.completed);
    }

    #[test]
    fn overload_sheds_and_stays_bounded() {
        let mut cfg = config(2_000.0, 8, 0);
        cfg.policy.queue_depth = 16;
        let report = run_cfg(cfg);
        assert!(report.rejected > 0, "{}", report.summary());
        assert_eq!(report.completed + report.rejected, report.offered);
        assert_eq!(report.failovers, 0, "one shard: nowhere to fail over");
        // Shedding is visible: one rejection record per shed request,
        // each attributed to a client, a project and a shard.
        assert_eq!(report.log.rejections().len() as u64, report.rejected);
        let by_client: u64 = report.log.rejections_by_client().values().sum();
        assert_eq!(by_client, report.rejected);
        for r in report.log.rejections() {
            assert!(r.client < 8);
            assert_eq!(r.shard, 0);
            assert_eq!(r.project, ProjectId::new(0));
            assert!(r.arrival_ms > r.sent_ms);
        }
    }

    #[test]
    fn zero_depth_policy_sheds_every_request() {
        // Regression for the `.max(1)` rounding: a closed endpoint must
        // answer nothing and shed everything, fully accounted.
        let mut cfg = config(50.0, 2, 0);
        cfg.policy.queue_depth = 0;
        let report = run_cfg(cfg);
        assert!(report.offered > 0);
        assert_eq!(report.completed, 0);
        assert_eq!(report.rejected, report.offered);
        assert_eq!(report.log.rejections().len() as u64, report.offered);
        assert_eq!(report.batches, 0);
    }

    #[test]
    fn batching_is_transparent_to_predictions() {
        // Same seed, same fleet; batch of 1 vs batch of 8 must serve the
        // same class for every request id — the acceptance criterion.
        let classes = |max_batch: usize| {
            let mut cfg = config(30.0, 4, 0); // cache off: everything executes
            cfg.policy.max_batch = max_batch;
            cfg.policy.max_wait_ms = if max_batch == 1 { 0.0 } else { 5.0 };
            classes_by_id(&run_cfg(cfg))
        };
        let unbatched = classes(1);
        let batched = classes(8);
        assert_eq!(unbatched, batched, "batching changed served predictions");
        assert!(!unbatched.is_empty());
    }

    #[test]
    fn oversized_policy_batch_clamps_to_compiled_largest() {
        // --batch 1000 on a model whose largest compiled variant is 8:
        // every executed batch (and so every logged batch_size) must be a
        // real compiled batch, never the raw policy number.
        let mut cfg = config(200.0, 8, 0);
        cfg.policy.max_batch = 1000;
        let report = run_cfg(cfg);
        assert!(report.batches > 0);
        for r in report.log.records() {
            assert!(r.batch_size <= 8, "{r:?}");
        }
    }

    #[test]
    fn cache_entries_become_visible_only_after_completion() {
        // With coalescing OFF, a duplicate input arriving while its twin
        // is still being computed must execute too (no answer can be
        // served before the computation that produced it finishes).
        let mut cfg = config(400.0, 4, 4096);
        cfg.fleets[0].input_pool = 2;
        cfg.policy.queue_depth = 4096;
        let report = run_cfg(cfg);
        // A flush-time cache would serve ~2 misses total (one per distinct
        // input); completion-time visibility forces every duplicate that
        // arrives during the first in-flight batch to execute as well.
        assert!(report.batch_examples > 2, "{}", report.summary());
        assert!(report.cache_hits > 0, "{}", report.summary());
        assert_eq!(report.batch_examples + report.cache_hits, report.completed);
    }

    #[test]
    fn coalescing_dedupes_inflight_duplicates() {
        // Cache off, tiny input pool: without coalescing every request
        // executes; with it, in-flight duplicates ride along.  Answers
        // must be identical either way.
        let mut base = config(400.0, 4, 0);
        base.fleets[0].input_pool = 2;
        base.policy.queue_depth = 4096; // no shedding: compare full runs
        let off = run_cfg(base.clone());
        let mut on_cfg = base;
        on_cfg.router.coalesce = true;
        let on = run_cfg(on_cfg);
        assert_eq!(off.rejected, 0);
        assert_eq!(on.rejected, 0);
        assert_eq!(off.completed, on.completed);
        assert!(on.coalesced > 0, "{}", on.summary());
        assert!(
            on.batch_examples < off.batch_examples,
            "coalescing must shrink executed examples: on {} vs off {}",
            on.summary(),
            off.summary()
        );
        // Every completed request is a hit, a waiter, or executed.
        assert_eq!(
            on.batch_examples + on.cache_hits + on.coalesced,
            on.completed
        );
        assert_eq!(classes_by_id(&off), classes_by_id(&on));
        // Waiters never occupy an executed batch slot, and their answers
        // exist only after the leader's computation completes.
        for r in on.log.records().iter().filter(|r| r.coalesced) {
            assert_eq!(r.batch_size, 0, "{r:?}");
            assert!(!r.cache_hit, "{r:?}");
            assert!(r.done_ms > r.sent_ms, "{r:?}");
        }
    }

    #[test]
    fn multi_shard_run_reconciles_and_spreads_load() {
        let mut cfg = config(300.0, 8, 0);
        cfg.policy.queue_depth = 4096;
        cfg.router = RouterConfig {
            shards: 3,
            policy: RoutingPolicy::JoinShortestQueue,
            coalesce: true,
            ..RouterConfig::single()
        };
        let report = run_cfg(cfg);
        assert_eq!(report.completed + report.rejected, report.offered);
        assert_eq!(report.per_shard.len(), 3);
        let routed: u64 = report.per_shard.iter().map(|s| s.routed).sum();
        assert_eq!(routed, report.offered, "every arrival routed exactly once");
        for s in &report.per_shard {
            assert_eq!(
                s.routed,
                s.admitted + s.rejected + s.cache_hits + s.coalesced,
                "shard {} counters must reconcile",
                s.shard
            );
            assert!(s.routed > 0, "JSQ at this load spills onto every shard");
        }
        assert!(
            report.per_shard.iter().filter(|s| s.batch_examples > 0).count() >= 2,
            "backlog must spread execution beyond one shard: {}",
            report.summary()
        );
        for r in report.log.records() {
            assert!(r.shard < 3, "{r:?}");
        }
    }

    #[test]
    fn affinity_pins_duplicate_inputs_to_one_shard() {
        let mut cfg = config(100.0, 4, 0);
        cfg.fleets[0].input_pool = 1; // one distinct input → one key
        cfg.router = RouterConfig {
            shards: 4,
            policy: RoutingPolicy::InputAffinity,
            ..RouterConfig::single()
        };
        let report = run_cfg(cfg);
        let active: Vec<&ShardStats> =
            report.per_shard.iter().filter(|s| s.routed > 0).collect();
        assert_eq!(active.len(), 1, "one key must route to exactly one shard");
        assert_eq!(active[0].routed, report.offered);
    }

    #[test]
    fn autotune_cuts_partial_batch_wait_at_low_load() {
        // At 8 rps aggregate, a 5 ms deadline is pure added latency: the
        // expected extra arrivals within the budget are ~0.04.  Autotune
        // should flush (nearly) immediately once the rate estimate forms.
        let mut fixed_cfg = config(2.0, 4, 0);
        fixed_cfg.fleets[0].duration_s = 10.0;
        let fixed = run_cfg(fixed_cfg.clone());
        let mut auto_cfg = fixed_cfg;
        auto_cfg.router.autotune = true;
        let auto = run_cfg(auto_cfg);
        assert_eq!(fixed.rejected, 0);
        assert_eq!(auto.rejected, 0);
        let (p50_fixed, p50_auto) = (fixed.latency().median(), auto.latency().median());
        assert!(
            p50_auto + 2.0 < p50_fixed,
            "autotune should shed most of the 5 ms deadline: auto {p50_auto:.2} vs fixed {p50_fixed:.2}"
        );
        // The report surfaces the retuned knobs.
        assert!(auto.per_shard[0].max_wait_ms < 5.0);
        assert!(auto.per_shard[0].max_batch <= 8);
        // Identical answers — tuning the deadline is timing-only.
        assert_eq!(classes_by_id(&fixed), classes_by_id(&auto));
    }

    #[test]
    fn autotune_snaps_flush_size_to_a_compiled_variant() {
        // ~400 rps aggregate → ~0.4 arrivals/ms → expected fill within
        // the 5 ms budget ≈ 3: the flush size should settle on the
        // compiled 4-variant, not the configured 8 — and answers must not
        // change (batch composition is answer-invariant).
        let mut fixed_cfg = config(50.0, 8, 0);
        fixed_cfg.policy.queue_depth = 4096;
        let fixed = run_cfg(fixed_cfg.clone());
        let mut auto_cfg = fixed_cfg;
        auto_cfg.router.autotune = true;
        let auto = run_cfg(auto_cfg);
        assert_eq!(auto.rejected, 0, "{}", auto.summary());
        let tuned = auto.per_shard[0].max_batch;
        assert!(
            tuned < 8 && [1usize, 4].contains(&tuned),
            "flush size must land on a smaller compiled variant, got {tuned}"
        );
        assert_eq!(classes_by_id(&fixed), classes_by_id(&auto));
    }

    #[test]
    fn failover_reroutes_around_a_drained_shard() {
        // ROADMAP satellite: `queue_depth: 0` models a closed endpoint.
        // With a second healthy shard behind the router, drained traffic
        // must be re-routed, not shed.
        let mut cfg = config(50.0, 4, 0);
        cfg.router = RouterConfig {
            shards: 2,
            ..RouterConfig::single()
        };
        cfg.drained_shards = vec![0];
        let report = run_cfg(cfg);
        assert!(report.offered > 0);
        assert_eq!(report.rejected, 0, "{}", report.summary());
        assert_eq!(report.completed, report.offered);
        assert!(report.failovers > 0, "{}", report.summary());
        assert_eq!(report.per_shard[0].batch_examples, 0, "drained shard idle");
        for r in report.log.records() {
            assert_eq!(r.shard, 1, "everything lands on the healthy shard");
        }
    }

    #[test]
    fn shed_only_when_every_shard_refuses() {
        let mut cfg = config(50.0, 4, 0);
        cfg.router = RouterConfig {
            shards: 2,
            ..RouterConfig::single()
        };
        cfg.drained_shards = vec![0, 1];
        let report = run_cfg(cfg);
        assert!(report.offered > 0);
        assert_eq!(report.completed, 0);
        assert_eq!(report.rejected, report.offered);
        assert_eq!(report.failovers, 0);
        assert_eq!(report.log.rejections().len() as u64, report.offered);
    }

    #[test]
    fn failover_spills_overflow_and_reconciles() {
        // A tiny per-shard queue under burst: overflow from the routed
        // shard spills to its peer before anything is shed, and the
        // per-shard counters still reconcile exactly.
        let mut cfg = config(1_200.0, 8, 0);
        cfg.policy.queue_depth = 8;
        cfg.router = RouterConfig {
            shards: 2,
            ..RouterConfig::single()
        };
        let report = run_cfg(cfg);
        assert!(report.failovers > 0, "{}", report.summary());
        assert_eq!(report.completed + report.rejected, report.offered);
        let routed: u64 = report.per_shard.iter().map(|s| s.routed).sum();
        assert_eq!(routed, report.offered);
        for s in &report.per_shard {
            assert_eq!(
                s.routed,
                s.admitted + s.rejected + s.cache_hits + s.coalesced,
                "shard {} counters must reconcile",
                s.shard
            );
        }
    }

    #[test]
    fn mixed_profiles_shift_execution_to_the_fast_shard() {
        // Satellite: heterogeneous shard profiles behind one router.
        // Shard 1 is 8× slower; millisecond-weighted JSQ must push the
        // bulk of execution onto shard 0 while both keep reconciling.
        let mut cfg = config(150.0, 8, 0);
        cfg.policy.queue_depth = 4096;
        cfg.router = RouterConfig {
            shards: 2,
            policy: RoutingPolicy::JoinShortestQueue,
            ..RouterConfig::single()
        };
        cfg.shard_profiles = vec![
            ServerProfile::default(),
            ServerProfile {
                power_vps: 500.0,
                ..ServerProfile::default()
            },
        ];
        let report = run_cfg(cfg);
        assert_eq!(report.rejected, 0, "{}", report.summary());
        let fast = &report.per_shard[0];
        let slow = &report.per_shard[1];
        assert!(
            fast.batch_examples > slow.batch_examples,
            "work-in-ms routing must favor the fast shard: fast {} vs slow {}",
            fast.batch_examples,
            slow.batch_examples
        );
    }

    #[test]
    fn jsq_beats_rr_on_tail_latency_at_high_load() {
        // With straggler jitter (real servers stall: GC, contention), a
        // round-robin deal keeps feeding a stalled shard while its twin
        // idles; work-aware JSQ routes around the backlog.  Toy-spec
        // effective capacity ≈ 8/(4.5 ms × 1.5 mean straggler factor) ≈
        // 1185 rps/shard; 2 shards at ~0.85 occupancy.  Deep queues so
        // no shed truncates the tail.  (With zero jitter and identical
        // deterministic shards RR is near-optimal and the two tie — the
        // spread is what state-aware routing is for.)
        let p99 = |policy: RoutingPolicy| {
            let mut cfg = config(126.0, 16, 0);
            cfg.server.jitter = 0.5;
            cfg.policy.queue_depth = 8192;
            cfg.fleets[0].input_pool = 4096;
            cfg.router = RouterConfig {
                shards: 2,
                policy,
                ..RouterConfig::single()
            };
            let report = run_cfg(cfg);
            assert_eq!(report.rejected, 0, "{}", report.summary());
            report.latency().quantile(0.99)
        };
        let rr = p99(RoutingPolicy::RoundRobin);
        let jsq = p99(RoutingPolicy::JoinShortestQueue);
        assert!(
            jsq < rr,
            "join-shortest-queue should cut the tail: jsq p99 {jsq:.1} ms vs rr p99 {rr:.1} ms"
        );
    }

    #[test]
    fn batching_amortizes_under_load() {
        // At high offered load, allowing batches must serve strictly more
        // requests within the horizon than single-request execution.
        let completed = |max_batch: usize| {
            let mut cfg = config(200.0, 8, 0);
            cfg.policy.max_batch = max_batch;
            cfg.policy.queue_depth = 32;
            run_cfg(cfg)
        };
        let single = completed(1);
        let batched = completed(8);
        assert!(
            batched.completed > single.completed,
            "batched {} vs single {}",
            batched.summary(),
            single.summary()
        );
        assert!(batched.mean_batch() > 1.5, "{}", batched.summary());
    }

    #[test]
    fn histogram_report_is_memory_bounded_at_1e5_requests() {
        // Satellite: with the log off, a 10⁵-request run retains no
        // per-request state — the histogram (fixed ~2k buckets) carries
        // the percentiles and every aggregate still reconciles.
        let mut cfg = config(2_500.0, 8, 0);
        cfg.policy.queue_depth = 64;
        cfg.keep_log = false;
        let report = run_cfg(cfg);
        assert!(report.offered >= 90_000, "offered {}", report.offered);
        assert_eq!(report.log.len(), 0, "no per-request records retained");
        assert!(report.log.rejections().is_empty());
        assert_eq!(report.completed + report.rejected, report.offered);
        assert!(report.completed > 0 && report.rejected > 0);
        let lat = report.latency();
        assert_eq!(lat.count(), report.completed);
        assert!(lat.median().is_finite() && lat.median() > 0.0);
        assert!(lat.p999() >= lat.p99() && lat.p99() >= lat.median());
        assert!(report.throughput_rps() > 0.0);
        assert!(report.span_s > 0.0);
        // Per-project mirrors stay counter-backed.
        let p = report.project(ProjectId::new(0));
        assert_eq!(p.completed, report.completed);
        assert_eq!(p.rejected, report.rejected);
    }

    #[test]
    fn keep_log_off_matches_keep_log_on_aggregates() {
        let on = run_cfg(config(40.0, 4, 16));
        let mut cfg = config(40.0, 4, 16);
        cfg.keep_log = false;
        let off = run_cfg(cfg);
        assert_eq!(on.completed, off.completed);
        assert_eq!(on.rejected, off.rejected);
        assert_eq!(on.cache_hits, off.cache_hits);
        assert_eq!(on.batches, off.batches);
        assert_eq!(on.latency().count(), off.latency().count());
        assert_eq!(on.latency().median(), off.latency().median());
        assert_eq!(on.span_s, off.span_s);
        assert_eq!(on.throughput_rps(), off.throughput_rps());
        assert_eq!(off.log.len(), 0);
    }

    #[test]
    fn trace_spans_balance_across_outcomes() {
        use crate::trace::EventKind;
        // Overloaded single shard: served, shed and (with coalescing)
        // coalesced outcomes all occur; every request span must close
        // with exactly one of them.
        let mut cfg = config(700.0, 4, 0);
        cfg.policy.queue_depth = 32;
        cfg.router.coalesce = true;
        cfg.fleets[0].input_pool = 64;
        let trace = TraceHandle::recording();
        let mut compute = ModeledCompute { param_count: 24 };
        let mut sim = ServeSim::new(cfg, plane(), &mut compute);
        let report = sim.run_traced(trace.clone()).unwrap();
        assert!(report.rejected > 0, "{}", report.summary());
        assert_eq!(trace.open_async(), 0, "every begin must have an end");
        let mut begins = std::collections::BTreeMap::new();
        let mut outcomes = std::collections::BTreeMap::new();
        for e in trace.snapshot() {
            match e.kind {
                EventKind::AsyncBegin { id } => *begins.entry(id).or_insert(0u32) += 1,
                EventKind::AsyncEnd { id } => {
                    let outcome = e
                        .args
                        .iter()
                        .find(|(k, _)| *k == "outcome")
                        .map(|(_, v)| format!("{v}"))
                        .expect("request end carries an outcome");
                    outcomes.entry(id).or_insert_with(Vec::new).push(outcome);
                }
                _ => {}
            }
        }
        assert_eq!(begins.len() as u64, report.offered);
        for (id, n) in &begins {
            assert_eq!(*n, 1, "request {id} began twice");
            let o = &outcomes[id];
            assert_eq!(o.len(), 1, "request {id} ended {} times", o.len());
            assert!(
                ["served", "shed", "coalesced"].contains(&o[0].as_str()),
                "request {id}: unknown outcome {}",
                o[0]
            );
        }
        let count = |what: &str| {
            outcomes.values().filter(|o| o[0] == what).count() as u64
        };
        assert_eq!(count("shed"), report.rejected);
        assert_eq!(count("coalesced"), report.coalesced);
        assert_eq!(count("served"), report.completed - report.coalesced);
    }

    // ───────────────────────── multi-project tier ─────────────────────

    /// Two projects behind one tier: project 0 is the hot one (high
    /// per-client rate), project 1 the cold one.
    fn hot_cold_cfg(hot_rps: f64, cold_rps: f64, depth: usize) -> (ServeConfig, ControlPlane) {
        let mut cfg = config(0.0, 0, 0);
        cfg.fleets = vec![
            FleetConfig {
                groups: vec![ClientSpec {
                    link: LinkProfile::Lan,
                    rate_rps: hot_rps,
                    count: 8,
                }],
                duration_s: 5.0,
                input_pool: 64,
                seed: 11,
            },
            FleetConfig {
                groups: vec![ClientSpec {
                    link: LinkProfile::Lan,
                    rate_rps: cold_rps,
                    count: 2,
                }],
                duration_s: 5.0,
                input_pool: 64,
                seed: 12,
            },
        ];
        cfg.policy.queue_depth = depth;
        let mut plane = ControlPlane::new();
        let hot = plane.register(spec(), 1.0);
        let cold = plane.register(spec(), 1.0);
        for p in [hot, cold] {
            plane
                .registry_mut(p)
                .publish_params(test_params(), 1, "init".into(), 0.0)
                .unwrap();
        }
        (cfg, plane)
    }

    fn run_two(cfg: ServeConfig, plane: ControlPlane) -> ServeReport {
        let mut compute = ModeledCompute { param_count: 24 };
        let mut sim = ServeSim::new(cfg, plane, &mut compute);
        sim.run().unwrap()
    }

    #[test]
    fn two_project_run_reconciles_per_project() {
        let (cfg, plane) = hot_cold_cfg(30.0, 10.0, 4096);
        let report = run_two(cfg, plane);
        assert_eq!(report.per_project.len(), 2);
        let hot = report.project(ProjectId::new(0));
        let cold = report.project(ProjectId::new(1));
        assert!(hot.offered > 0 && cold.offered > 0);
        assert_eq!(hot.offered + cold.offered, report.offered);
        assert_eq!(hot.completed + cold.completed, report.completed);
        assert_eq!(hot.rejected + cold.rejected, report.rejected);
        assert_eq!(report.rejected, 0, "no shedding at this load");
        // Every record's version names its own project, and the
        // per-project log view reconciles.
        for (i, p) in [hot, cold].into_iter().enumerate() {
            let view = report.log.for_project(ProjectId::new(i as u32));
            assert_eq!(view.len() as u64, p.completed);
            for r in view.records() {
                assert_eq!(r.version.project, ProjectId::new(i as u32));
            }
        }
    }

    #[test]
    fn fair_share_bounds_the_cold_projects_shed_rate() {
        // The acceptance criterion: the hot project overloads the tier
        // (~2× a single shard's service rate) while the cold project
        // trickles.  With fair-share admission the cold project's
        // reserved slice keeps it unshed; without it, the hot project's
        // backlog fills the whole queue and the cold project sheds at
        // nearly the hot rate.
        let (cfg, plane) = hot_cold_cfg(400.0, 5.0, 32);
        let fair = run_two(cfg.clone(), plane.clone());
        let fair_hot = *fair.project(ProjectId::new(0));
        let fair_cold = *fair.project(ProjectId::new(1));
        assert!(
            fair_hot.shed_rate() > 0.2,
            "hot project must be overloaded: {}",
            fair.summary()
        );
        assert_eq!(
            fair_cold.rejected, 0,
            "cold project's fair share keeps it unshed"
        );

        let mut unfair_cfg = cfg;
        unfair_cfg.router.fair_share = false;
        let unfair = run_two(unfair_cfg, plane);
        let unfair_cold = *unfair.project(ProjectId::new(1));
        assert!(
            unfair_cold.shed_rate() > 0.1,
            "without fair share the hot queue starves the cold project \
             (cold shed {:.3})",
            unfair_cold.shed_rate()
        );
        assert!(fair_cold.shed_rate() < unfair_cold.shed_rate());
    }
}
