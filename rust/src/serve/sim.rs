//! Discrete-event serving simulation: admission → cache → micro-batch →
//! execute → respond, over a snapshot registry and a simulated request
//! fleet.
//!
//! The counterpart of [`crate::sim::Simulation`] for the prediction
//! workload.  Two timelines interleave on one virtual clock: request
//! arrivals (precomputed by the load generator) and batch flushes (decided
//! by the admission queue against the executor's availability).  The
//! executor is serial — one serving process, matching the training
//! master's single-server model (§3.5) — so queueing delay is what the
//! latency percentiles measure under load.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::metrics::{RequestLog, RequestRecord, Summary};
use crate::netsim::LinkModel;
use crate::rng::Pcg32;
use crate::runtime::Compute;

use super::cache::{input_key, PredictionCache};
use super::executor::{BatchExecutor, Prediction, ServerProfile};
use super::loadgen::{FleetConfig, RequestFleet};
use super::queue::{AdmissionQueue, BatchPolicy, PredictRequest};
use super::registry::SnapshotRegistry;

/// Everything one serving run needs besides the registry and compute.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub fleet: FleetConfig,
    pub policy: BatchPolicy,
    pub server: ServerProfile,
    /// Prediction-cache capacity in entries (0 disables caching).
    pub cache_capacity: usize,
    /// Response payload on the downlink (class + confidence + envelope).
    pub response_bytes: u64,
}

/// Outcome of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub log: RequestLog,
    pub offered: u64,
    pub completed: u64,
    pub rejected: u64,
    pub cache_hits: u64,
    pub batches: u64,
    /// Real requests executed in batches (excludes cache hits + padding).
    pub batch_examples: u64,
    pub padded_examples: u64,
    /// Emission horizon (s) — offered-load normalizer.
    pub duration_s: f64,
    /// Virtual time of the last response (s).
    pub span_s: f64,
}

impl ServeReport {
    /// Completed requests per second of emission horizon.
    pub fn throughput_rps(&self) -> f64 {
        self.log.throughput_rps(self.duration_s.max(self.span_s))
    }

    /// End-to-end latency distribution.
    pub fn latency(&self) -> Summary {
        self.log.latency_summary()
    }

    pub fn hit_rate(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / self.completed as f64
    }

    /// Mean executed-batch size (real requests per flush).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batch_examples as f64 / self.batches as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let lat = self.latency();
        format!(
            "offered={} completed={} rejected={} hit_rate={:.2} mean_batch={:.1} \
             p50={:.1}ms p95={:.1}ms p99={:.1}ms throughput={:.1} rps",
            self.offered,
            self.completed,
            self.rejected,
            self.hit_rate(),
            self.mean_batch(),
            lat.median(),
            lat.p95(),
            lat.quantile(0.99),
            self.throughput_rps(),
        )
    }
}

/// A configured serving run over one registry + compute backend.
pub struct ServeSim<'c> {
    cfg: ServeConfig,
    registry: SnapshotRegistry,
    compute: &'c mut dyn Compute,
}

impl<'c> ServeSim<'c> {
    pub fn new(cfg: ServeConfig, registry: SnapshotRegistry, compute: &'c mut dyn Compute) -> Self {
        Self {
            cfg,
            registry,
            compute,
        }
    }

    pub fn registry(&self) -> &SnapshotRegistry {
        &self.registry
    }

    /// Run the full request schedule to completion.
    pub fn run(&mut self) -> Result<ServeReport> {
        let snapshot = self
            .registry
            .active()
            .ok_or_else(|| anyhow!("no snapshot published — registry is empty"))?
            .clone();
        let spec = self.registry.spec().clone();
        let fleet = RequestFleet::generate(&self.cfg.fleet, &spec);
        // Clamp the flush size to the largest compiled micro-batch so
        // every flushed batch is exactly one execution — `batch_size` in
        // the log then always names a real executed batch.
        let largest = spec
            .micro_batches
            .iter()
            .copied()
            .max()
            .unwrap_or(spec.batch_size)
            .max(1);
        let mut policy = self.cfg.policy;
        policy.max_batch = policy.max_batch.clamp(1, largest);
        let mut queue = AdmissionQueue::new(policy);
        let mut cache = PredictionCache::new(self.cfg.cache_capacity);
        let mut executor = BatchExecutor::new(spec, self.cfg.server);
        let mut log = RequestLog::new();
        // Cache fills only when a batch's computation *completes*: entries
        // queued here become visible once virtual time passes `ready_ms`.
        // A duplicate arriving while its twin is still in flight misses
        // and executes too (request coalescing is a ROADMAP follow-on).
        let mut pending_inserts: VecDeque<PendingInsert> = VecDeque::new();
        // Downlink jitter draws; separate stream from the load generator
        // so admission decisions cannot perturb arrival schedules.
        let mut rng = Pcg32::new(self.cfg.fleet.seed ^ 0x5E12E);

        let mut now = 0.0f64;
        let mut free_at = 0.0f64;
        let mut next = 0usize;
        loop {
            let arrival = fleet.events.get(next).map(|e| e.arrival_ms);
            let flush = queue.next_flush_at(free_at).map(|t| t.max(now));
            // Arrivals win ties so a request landing exactly at flush time
            // still joins the batch.
            let take_arrival = match (arrival, flush) {
                (None, None) => break,
                (Some(a), Some(f)) => a <= f,
                (Some(_), None) => true,
                (None, Some(_)) => false,
            };
            if take_arrival {
                let ev = &fleet.events[next];
                next += 1;
                now = ev.arrival_ms;
                // With the cache disabled, skip hashing ~KB of pixels per
                // request — nothing would ever consume the key.
                let caching = cache.capacity() > 0;
                let key = if caching {
                    apply_ready_inserts(&mut cache, &mut pending_inserts, now);
                    input_key(snapshot.id, &ev.input)
                } else {
                    0
                };
                let hit = if caching { cache.get(key, &ev.input) } else { None };
                if let Some(pred) = hit {
                    let done = now
                        + self.cfg.server.cache_lookup_ms
                        + respond_ms(&fleet.links, ev.client, self.cfg.response_bytes, &mut rng);
                    log.push(RequestRecord {
                        id: ev.id,
                        client: ev.client,
                        sent_ms: ev.sent_ms,
                        done_ms: done,
                        latency_ms: done - ev.sent_ms,
                        batch_size: 0,
                        cache_hit: true,
                        class: pred.class as u32,
                    });
                } else {
                    // Shedding is silent from the log's perspective: the
                    // client gets a fast error, not a prediction.
                    queue.offer(PredictRequest {
                        id: ev.id,
                        client: ev.client,
                        sent_ms: ev.sent_ms,
                        arrival_ms: ev.arrival_ms,
                        input: Arc::clone(&ev.input),
                        key,
                    });
                }
            } else if let Some(f) = flush {
                now = f;
                apply_ready_inserts(&mut cache, &mut pending_inserts, now);
                let batch = queue.take_batch();
                let inputs: Vec<&[f32]> = batch.iter().map(|r| r.input.as_slice()).collect();
                let (preds, service_ms) =
                    executor.execute(self.compute, &snapshot.params, &inputs)?;
                let computed_at = now + service_ms;
                free_at = computed_at;
                for (req, pred) in batch.iter().zip(&preds) {
                    if cache.capacity() > 0 {
                        pending_inserts.push_back(PendingInsert {
                            ready_ms: computed_at,
                            key: req.key,
                            input: Arc::clone(&req.input),
                            prediction: pred.clone(),
                        });
                    }
                    let done = computed_at
                        + respond_ms(&fleet.links, req.client, self.cfg.response_bytes, &mut rng);
                    log.push(RequestRecord {
                        id: req.id,
                        client: req.client,
                        sent_ms: req.sent_ms,
                        done_ms: done,
                        latency_ms: done - req.sent_ms,
                        batch_size: batch.len() as u32,
                        cache_hit: false,
                        class: pred.class as u32,
                    });
                }
            }
        }

        let span_s = log.span_ms() / 1000.0;
        Ok(ServeReport {
            offered: fleet.offered(),
            completed: log.len() as u64,
            rejected: queue.rejected(),
            cache_hits: cache.hits(),
            batches: executor.batches(),
            batch_examples: executor.examples(),
            padded_examples: executor.padded(),
            duration_s: self.cfg.fleet.duration_s,
            span_s,
            log,
        })
    }
}

/// Downlink time for a response to `client`: latency jitter + transmission.
fn respond_ms(links: &[LinkModel], client: u32, bytes: u64, rng: &mut Pcg32) -> f64 {
    let link = &links[client as usize];
    link.sample_latency_ms(rng) + link.transmit_ms(bytes)
}

/// A computed prediction awaiting cache visibility at its completion time.
struct PendingInsert {
    ready_ms: f64,
    key: u64,
    input: Arc<Vec<f32>>,
    prediction: Prediction,
}

/// Publish pending cache entries whose computation completed by `t`
/// (completions are monotone — the executor is serial — so the deque is
/// time-ordered and a front-drain suffices).
fn apply_ready_inserts(
    cache: &mut PredictionCache,
    pending: &mut VecDeque<PendingInsert>,
    t: f64,
) {
    while pending.front().is_some_and(|p| p.ready_ms <= t) {
        let p = pending.pop_front().expect("front checked");
        cache.insert(p.key, p.input, p.prediction);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelSpec, TensorSpec};
    use crate::netsim::LinkProfile;
    use crate::runtime::ModeledCompute;
    use crate::serve::loadgen::ClientSpec;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 24,
            batch_size: 8,
            micro_batches: vec![8, 4, 1],
            input: vec![4, 1, 1],
            classes: 3,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![24],
                offset: 0,
                size: 24,
                fan_in: 4,
            }],
            artifacts: Default::default(),
        }
    }

    fn config(rate: f64, clients: usize, cache: usize) -> ServeConfig {
        ServeConfig {
            fleet: FleetConfig {
                groups: vec![ClientSpec {
                    link: LinkProfile::Lan,
                    rate_rps: rate,
                    count: clients,
                }],
                duration_s: 5.0,
                input_pool: 16,
                seed: 11,
            },
            policy: BatchPolicy {
                max_batch: 8,
                max_wait_ms: 5.0,
                queue_depth: 64,
            },
            server: ServerProfile::default(),
            cache_capacity: cache,
            response_bytes: 256,
        }
    }

    fn registry() -> SnapshotRegistry {
        let mut reg = SnapshotRegistry::new(spec());
        let params: Vec<f32> = (0..24).map(|i| ((i * 13 % 7) as f32 - 3.0) * 0.2).collect();
        reg.publish_params(params, 5, "test".into(), 0.0).unwrap();
        reg
    }

    #[test]
    fn accounts_for_every_request() {
        let mut compute = ModeledCompute { param_count: 24 };
        let mut sim = ServeSim::new(config(20.0, 4, 0), registry(), &mut compute);
        let report = sim.run().unwrap();
        assert!(report.offered > 0);
        assert_eq!(report.completed + report.rejected, report.offered);
        assert_eq!(report.batch_examples, report.completed - report.cache_hits);
        for r in report.log.records() {
            assert!(r.latency_ms > 0.0, "{r:?}");
            assert!(r.done_ms > r.sent_ms);
        }
    }

    #[test]
    fn no_snapshot_is_an_error() {
        let mut compute = ModeledCompute { param_count: 24 };
        let empty = SnapshotRegistry::new(spec());
        let mut sim = ServeSim::new(config(5.0, 1, 0), empty, &mut compute);
        assert!(sim.run().is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed: u64| {
            let mut compute = ModeledCompute { param_count: 24 };
            let mut cfg = config(10.0, 3, 32);
            cfg.fleet.seed = seed;
            let mut sim = ServeSim::new(cfg, registry(), &mut compute);
            sim.run().unwrap().log.to_csv()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn small_input_pool_drives_cache_hits() {
        let mut compute = ModeledCompute { param_count: 24 };
        let mut cfg = config(40.0, 4, 256);
        cfg.fleet.input_pool = 4;
        let mut sim = ServeSim::new(cfg, registry(), &mut compute);
        let report = sim.run().unwrap();
        assert!(
            report.hit_rate() > 0.5,
            "4-input pool should mostly hit: {}",
            report.summary()
        );
        assert!(report.cache_hits > 0 && report.batch_examples > 0);
        // Cache hits skip the executor, so executed examples + hits must
        // still account for every completed request.
        assert_eq!(report.batch_examples + report.cache_hits, report.completed);
    }

    #[test]
    fn overload_sheds_and_stays_bounded() {
        let mut compute = ModeledCompute { param_count: 24 };
        let mut cfg = config(2_000.0, 8, 0);
        cfg.policy.queue_depth = 16;
        let mut sim = ServeSim::new(cfg, registry(), &mut compute);
        let report = sim.run().unwrap();
        assert!(report.rejected > 0, "{}", report.summary());
        assert_eq!(report.completed + report.rejected, report.offered);
    }

    #[test]
    fn batching_is_transparent_to_predictions() {
        // Same seed, same fleet; batch of 1 vs batch of 8 must serve the
        // same class for every request id — the acceptance criterion.
        let classes = |max_batch: usize| {
            let mut compute = ModeledCompute { param_count: 24 };
            let mut cfg = config(30.0, 4, 0); // cache off: everything executes
            cfg.policy.max_batch = max_batch;
            cfg.policy.max_wait_ms = if max_batch == 1 { 0.0 } else { 5.0 };
            let mut sim = ServeSim::new(cfg, registry(), &mut compute);
            let report = sim.run().unwrap();
            let mut by_id: Vec<(u64, u32)> = report
                .log
                .records()
                .iter()
                .map(|r| (r.id, r.class))
                .collect();
            by_id.sort_unstable();
            by_id
        };
        let unbatched = classes(1);
        let batched = classes(8);
        assert_eq!(unbatched, batched, "batching changed served predictions");
        assert!(!unbatched.is_empty());
    }

    #[test]
    fn oversized_policy_batch_clamps_to_compiled_largest() {
        // --batch 1000 on a model whose largest compiled variant is 8:
        // every executed batch (and so every logged batch_size) must be a
        // real compiled batch, never the raw policy number.
        let mut compute = ModeledCompute { param_count: 24 };
        let mut cfg = config(200.0, 8, 0);
        cfg.policy.max_batch = 1000;
        let mut sim = ServeSim::new(cfg, registry(), &mut compute);
        let report = sim.run().unwrap();
        assert!(report.batches > 0);
        for r in report.log.records() {
            assert!(r.batch_size <= 8, "{r:?}");
        }
    }

    #[test]
    fn cache_entries_become_visible_only_after_completion() {
        // A duplicate input arriving while its twin is still being
        // computed must execute too (no answer can be served before the
        // computation that produced it finishes).
        let mut compute = ModeledCompute { param_count: 24 };
        let mut cfg = config(400.0, 4, 4096);
        cfg.fleet.input_pool = 2;
        let mut sim = ServeSim::new(cfg, registry(), &mut compute);
        let report = sim.run().unwrap();
        // A flush-time cache would serve ~2 misses total (one per distinct
        // input); completion-time visibility forces every duplicate that
        // arrives during the first in-flight batch to execute as well.
        assert!(report.batch_examples > 2, "{}", report.summary());
        assert!(report.cache_hits > 0, "{}", report.summary());
        assert_eq!(report.batch_examples + report.cache_hits, report.completed);
    }

    #[test]
    fn batching_amortizes_under_load() {
        // At high offered load, allowing batches must serve strictly more
        // requests within the horizon than single-request execution.
        let completed = |max_batch: usize| {
            let mut compute = ModeledCompute { param_count: 24 };
            let mut cfg = config(200.0, 8, 0);
            cfg.policy.max_batch = max_batch;
            cfg.policy.queue_depth = 32;
            let mut sim = ServeSim::new(cfg, registry(), &mut compute);
            sim.run().unwrap()
        };
        let single = completed(1);
        let batched = completed(8);
        assert!(
            batched.completed > single.completed,
            "batched {} vs single {}",
            batched.summary(),
            single.summary()
        );
        assert!(batched.mean_batch() > 1.5, "{}", batched.summary());
    }
}
