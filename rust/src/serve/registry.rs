//! Snapshot registry — versioned parameter vectors behind one project's
//! serving endpoint.
//!
//! The paper's prediction story (§2.3, §3.6): trained models are saved in
//! a universally readable format — the JSON research closure — and "any
//! device" downloads them for inference.  The registry is the server side
//! of that hand-off for **one project** of the multi-tenant master
//! (§3.1): it ingests closures (or live parameter vectors from a training
//! master), validates them against the project's manifest spec, assigns
//! monotonically increasing [`ModelVersion`] handles, and designates the
//! *active* snapshot new prediction requests are served from.  The
//! [`super::ControlPlane`] owns one registry per project.
//!
//! **Staged publication.**  A live publication is no longer free: the
//! snapshot's bytes must cross the master-egress link before the serving
//! tier can switch to it.  [`SnapshotRegistry::stage_params`] makes a
//! version resident without activating it; [`SnapshotRegistry::activate`]
//! flips serving to it once the transfer completes (and doubles as
//! rollback onto any resident version).  A staged version is GC-immune —
//! evicting a snapshot whose transfer is still in flight would activate
//! a hole.
//!
//! **Traffic-driven GC.**  Under the co-simulation a live master publishes
//! mid-traffic, so a retention policy alone is unsafe: a request admitted
//! under version v must execute against v even if three newer versions
//! land before its batch flushes.  Each admitted request takes a *reader
//! pin* ([`SnapshotRegistry::pin_reader`]) released after its batch
//! executes; [`SnapshotRegistry::gc_keep_latest`] evicts a version only
//! when the retention policy *and* a zero reader count agree (the active
//! snapshot and staged versions are always kept too).  Pins are
//! per-project state: one project's pinned versions never block another
//! project's eviction (pinned by `control` tests).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::model::{ModelSpec, ResearchClosure};

use super::control::{ModelVersion, ProjectId};

/// Copyable identity/provenance of a snapshot — what the serving path
/// threads through records without holding a registry borrow.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SnapshotMeta {
    pub version: ModelVersion,
    /// Training iteration the parameters were captured at.
    pub iteration: u64,
    /// Virtual publish time (ms).
    pub published_ms: f64,
}

/// One servable model version.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub version: ModelVersion,
    pub model: String,
    /// Training iteration the parameters were captured at.
    pub iteration: u64,
    /// Shared parameter vector (the executor and cache key off it without
    /// copying ~100k f32 per request batch).
    pub params: Arc<Vec<f32>>,
    /// Free-form provenance (mirrors the closure's notes).
    pub notes: String,
    /// Virtual publish time (ms) — input to retention policies.
    pub published_ms: f64,
}

impl Snapshot {
    /// Copyable identity for records and observers.
    pub fn meta(&self) -> SnapshotMeta {
        SnapshotMeta {
            version: self.version,
            iteration: self.iteration,
            published_ms: self.published_ms,
        }
    }
}

/// Serializable row of one resident snapshot — what the storage plane
/// writes as a segment file plus a manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotRow {
    pub version: u64,
    pub model: String,
    pub iteration: u64,
    pub params: Arc<Vec<f32>>,
    pub notes: String,
    pub published_ms: f64,
}

/// Serializable state of a whole registry.  Reader pins are deliberately
/// absent: they track *in-flight* requests, which do not survive a
/// restart — a recovered registry starts pin-free, so versions retired
/// before the crash become compactable on the first GC after warm-up.
#[derive(Debug, Clone, PartialEq)]
pub struct RegistryState {
    pub next: u64,
    pub active: Option<u64>,
    pub staged: Vec<u64>,
    /// Resident snapshots, version-ascending.
    pub rows: Vec<SnapshotRow>,
}

/// Versioned snapshot store for one project's served model.
#[derive(Debug, Clone)]
pub struct SnapshotRegistry {
    project: ProjectId,
    spec: ModelSpec,
    next: u64,
    snapshots: BTreeMap<u64, Snapshot>,
    active: Option<u64>,
    /// In-flight reader pins per version (admitted-but-not-yet-executed
    /// requests); a pinned version survives retention GC.
    readers: BTreeMap<u64, u64>,
    /// Versions staged but not yet activated (snapshot transfer still in
    /// flight); GC-immune until activation.
    staged: BTreeSet<u64>,
}

impl SnapshotRegistry {
    pub fn new(project: ProjectId, spec: ModelSpec) -> Self {
        Self {
            project,
            spec,
            next: 1,
            snapshots: BTreeMap::new(),
            active: None,
            readers: BTreeMap::new(),
            staged: BTreeSet::new(),
        }
    }

    pub fn project(&self) -> ProjectId {
        self.project
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Capture the persistable registry state (resident snapshots, active
    /// pointer, staged set, version counter — not reader pins, see
    /// [`RegistryState`]).
    pub fn export_state(&self) -> RegistryState {
        RegistryState {
            next: self.next,
            active: self.active,
            staged: self.staged.iter().copied().collect(),
            rows: self
                .snapshots
                .values()
                .map(|s| SnapshotRow {
                    version: s.version.version,
                    model: s.model.clone(),
                    iteration: s.iteration,
                    params: Arc::clone(&s.params),
                    notes: s.notes.clone(),
                    published_ms: s.published_ms,
                })
                .collect(),
        }
    }

    /// Rebuild a registry from persisted state, re-validating every
    /// invariant the live path enforces (a manifest is attacker-grade
    /// input compared to our own in-memory state).
    pub fn from_state(
        project: ProjectId,
        spec: ModelSpec,
        st: RegistryState,
    ) -> Result<Self, String> {
        let mut reg = Self::new(project, spec);
        let versions: BTreeSet<u64> = st.rows.iter().map(|r| r.version).collect();
        if versions.len() != st.rows.len() {
            return Err("registry state has duplicate versions".into());
        }
        for row in &st.rows {
            if row.version == 0 {
                return Err("version 0 is never assigned".into());
            }
            if row.version >= st.next {
                return Err(format!(
                    "resident version {} not below next counter {}",
                    row.version, st.next
                ));
            }
            if row.model != reg.spec.name {
                return Err(format!(
                    "snapshot v{} is of model '{}', registry serves '{}'",
                    row.version, row.model, reg.spec.name
                ));
            }
            if row.params.len() != reg.spec.param_count {
                return Err(format!(
                    "snapshot v{} has {} params, model '{}' expects {}",
                    row.version,
                    row.params.len(),
                    reg.spec.name,
                    reg.spec.param_count
                ));
            }
        }
        if let Some(a) = st.active {
            if !versions.contains(&a) {
                return Err(format!("active version {a} is not resident"));
            }
        }
        for &s in &st.staged {
            if !versions.contains(&s) {
                return Err(format!("staged version {s} is not resident"));
            }
        }
        reg.next = st.next;
        reg.active = st.active;
        reg.staged = st.staged.into_iter().collect();
        for row in st.rows {
            let snapshot = Snapshot {
                version: reg.handle(row.version),
                model: row.model,
                iteration: row.iteration,
                params: row.params,
                notes: row.notes,
                published_ms: row.published_ms,
            };
            reg.snapshots.insert(row.version, snapshot);
        }
        Ok(reg)
    }

    /// The typed handle for a raw version number of *this* project.
    pub fn handle(&self, version: u64) -> ModelVersion {
        ModelVersion {
            project: self.project,
            version,
        }
    }

    /// Ingest a research closure (the paper's download/upload object);
    /// validates model identity and parameter count before versioning.
    /// The new snapshot becomes active.
    pub fn publish_closure(
        &mut self,
        closure: &ResearchClosure,
        now_ms: f64,
    ) -> Result<ModelVersion, String> {
        closure.check_compatible(&self.spec)?;
        self.publish_params(
            closure.params.clone(),
            closure.iteration,
            closure.notes.clone(),
            now_ms,
        )
    }

    /// Publish a raw parameter vector and activate it immediately (the
    /// zero-transfer-cost path: closures already on disk, test fixtures).
    /// Live masters under the egress budget use [`Self::stage_params`] +
    /// [`Self::activate`] instead.
    pub fn publish_params(
        &mut self,
        params: Vec<f32>,
        iteration: u64,
        notes: String,
        now_ms: f64,
    ) -> Result<ModelVersion, String> {
        let v = self.stage_params(params, iteration, notes, now_ms)?;
        self.activate(v)?;
        Ok(v)
    }

    /// Make a parameter vector resident *without* activating it — the
    /// snapshot's bytes are still crossing the master-egress link.  The
    /// staged version is GC-immune until [`Self::activate`] lands.
    pub fn stage_params(
        &mut self,
        params: Vec<f32>,
        iteration: u64,
        notes: String,
        now_ms: f64,
    ) -> Result<ModelVersion, String> {
        if params.len() != self.spec.param_count {
            return Err(format!(
                "snapshot has {} params, model '{}' expects {}",
                params.len(),
                self.spec.name,
                self.spec.param_count
            ));
        }
        if let Some(bad) = params.iter().position(|p| !p.is_finite()) {
            return Err(format!("snapshot param {bad} is not finite"));
        }
        let v = self.next;
        self.next += 1;
        self.snapshots.insert(
            v,
            Snapshot {
                version: self.handle(v),
                model: self.spec.name.clone(),
                iteration,
                params: Arc::new(params),
                notes,
                published_ms: now_ms,
            },
        );
        self.staged.insert(v);
        Ok(self.handle(v))
    }

    /// Flip serving to a resident version: transfer completion for a
    /// staged snapshot, or rollback / canary-undo onto an older one.
    pub fn activate(&mut self, version: ModelVersion) -> Result<(), String> {
        if version.project != self.project {
            return Err(format!(
                "version {version} belongs to another project (this registry serves {})",
                self.project
            ));
        }
        if !self.snapshots.contains_key(&version.version) {
            return Err(format!("snapshot {version} not in registry"));
        }
        self.staged.remove(&version.version);
        self.active = Some(version.version);
        Ok(())
    }

    pub fn get(&self, version: ModelVersion) -> Option<&Snapshot> {
        if version.project != self.project {
            return None;
        }
        self.snapshots.get(&version.version)
    }

    /// The snapshot new requests are served from.
    pub fn active(&self) -> Option<&Snapshot> {
        self.active.and_then(|v| self.snapshots.get(&v))
    }

    /// Is this version resident but awaiting its transfer completion?
    pub fn is_staged(&self, version: ModelVersion) -> bool {
        version.project == self.project && self.staged.contains(&version.version)
    }

    pub fn len(&self) -> usize {
        self.snapshots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.snapshots.is_empty()
    }

    /// Version handles, oldest first.
    pub fn ids(&self) -> Vec<ModelVersion> {
        self.snapshots.keys().map(|&v| self.handle(v)).collect()
    }

    // ------------------------------------------------- reader refcounts

    /// Take a reader pin on a version (a request was admitted under it and
    /// its batch has not executed yet).  A pinned version cannot be
    /// GC-evicted.  Errors if the version is not resident here.
    pub fn pin_reader(&mut self, version: ModelVersion) -> Result<(), String> {
        if version.project != self.project || !self.snapshots.contains_key(&version.version) {
            return Err(format!("cannot pin snapshot {version}: not in registry"));
        }
        *self.readers.entry(version.version).or_insert(0) += 1;
        Ok(())
    }

    /// Release a reader pin (the request's batch executed).
    pub fn unpin_reader(&mut self, version: ModelVersion) {
        if version.project != self.project {
            debug_assert!(false, "unpin of foreign version {version}");
            return;
        }
        match self.readers.get_mut(&version.version) {
            Some(n) if *n > 1 => *n -= 1,
            Some(_) => {
                self.readers.remove(&version.version);
            }
            None => debug_assert!(false, "unpin without pin on {version}"),
        }
    }

    /// Outstanding reader pins on one version.
    pub fn reader_count(&self, version: ModelVersion) -> u64 {
        if version.project != self.project {
            return 0;
        }
        self.readers.get(&version.version).copied().unwrap_or(0)
    }

    /// Outstanding reader pins across all versions (0 once traffic drains).
    pub fn total_readers(&self) -> u64 {
        self.readers.values().sum()
    }

    /// Retention: keep the newest `keep` versions.  The active snapshot,
    /// staged (transfer-in-flight) versions and any version with
    /// outstanding reader pins are always kept — a version is evicted
    /// only when the retention policy *and* zero in-flight readers agree.
    /// Returns the handles dropped.
    pub fn gc_keep_latest(&mut self, keep: usize) -> Vec<ModelVersion> {
        let versions: Vec<u64> = self.snapshots.keys().copied().collect();
        let cutoff = versions.len().saturating_sub(keep);
        let mut dropped = Vec::new();
        for &v in &versions[..cutoff] {
            if Some(v) == self.active
                || self.staged.contains(&v)
                || self.readers.get(&v).copied().unwrap_or(0) > 0
            {
                continue;
            }
            self.snapshots.remove(&v);
            dropped.push(self.handle(v));
        }
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;

    const P: ProjectId = ProjectId::new(0);

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 4,
            batch_size: 2,
            micro_batches: vec![2, 1],
            input: vec![2, 1, 1],
            classes: 2,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![4],
                offset: 0,
                size: 4,
                fan_in: 2,
            }],
            artifacts: Default::default(),
        }
    }

    fn registry() -> SnapshotRegistry {
        SnapshotRegistry::new(P, spec())
    }

    #[test]
    fn publish_versions_and_activates_latest() {
        let mut reg = registry();
        assert!(reg.active().is_none());
        assert_eq!(reg.project(), P);
        let v1 = reg.publish_params(vec![0.0; 4], 10, "a".into(), 0.0).unwrap();
        let v2 = reg.publish_params(vec![1.0; 4], 20, "b".into(), 5.0).unwrap();
        assert_eq!((v1.version, v2.version), (1, 2));
        assert_eq!(v1.project, P);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.active().unwrap().version, v2);
        assert_eq!(reg.get(v1).unwrap().iteration, 10);
        assert_eq!(*reg.get(v2).unwrap().params, vec![1.0; 4]);
        assert_eq!(reg.handle(2), v2);
    }

    #[test]
    fn publish_closure_validates_against_spec() {
        let mut reg = registry();
        let mut c = ResearchClosure::new(&spec(), &[0.5; 4]);
        c.iteration = 7;
        let id = reg.publish_closure(&c, 1.0).unwrap();
        assert_eq!(reg.get(id).unwrap().iteration, 7);

        // Wrong model name is rejected before versioning.
        let mut other = spec();
        other.name = "other".into();
        let bad = ResearchClosure::new(&other, &[0.5; 4]);
        assert!(reg.publish_closure(&bad, 1.0).is_err());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn rejects_bad_param_vectors() {
        let mut reg = registry();
        assert!(reg.publish_params(vec![0.0; 3], 0, String::new(), 0.0).is_err());
        assert!(reg
            .publish_params(vec![0.0, f32::NAN, 0.0, 0.0], 0, String::new(), 0.0)
            .is_err());
        assert!(reg.is_empty());
    }

    #[test]
    fn staged_versions_serve_nothing_until_activated() {
        // The byte-accounted publication contract: staging makes the
        // version resident, but the active pointer moves only on
        // activation (when the transfer completes).
        let mut reg = registry();
        let v1 = reg.publish_params(vec![0.0; 4], 1, String::new(), 0.0).unwrap();
        let v2 = reg
            .stage_params(vec![1.0; 4], 5, "in flight".into(), 10.0)
            .unwrap();
        assert!(reg.is_staged(v2));
        assert!(!reg.is_staged(v1));
        assert_eq!(reg.active().unwrap().version, v1, "v2 not yet live");
        assert!(reg.get(v2).is_some(), "staged versions are resident");
        reg.activate(v2).unwrap();
        assert!(!reg.is_staged(v2));
        assert_eq!(reg.active().unwrap().version, v2);
    }

    #[test]
    fn gc_never_evicts_a_staged_version() {
        // Evicting a snapshot whose transfer is still in flight would
        // activate a hole — staged versions are retention-immune.
        let mut reg = registry();
        for i in 0..3 {
            reg.publish_params(vec![i as f32; 4], i, String::new(), i as f64)
                .unwrap();
        }
        let staged = reg
            .stage_params(vec![9.0; 4], 9, String::new(), 9.0)
            .unwrap();
        // keep=1 would normally evict everything but the newest; the
        // staged newest and the active v3 both survive by rule.
        let dropped = reg.gc_keep_latest(1);
        assert_eq!(dropped, vec![reg.handle(1), reg.handle(2)]);
        assert!(reg.get(staged).is_some());
        assert_eq!(reg.active().unwrap().version.version, 3);
        // Once activated, the *previous* active becomes evictable.
        reg.activate(staged).unwrap();
        assert_eq!(reg.gc_keep_latest(1), vec![reg.handle(3)]);
        assert_eq!(reg.ids(), vec![staged]);
    }

    #[test]
    fn rollback_activates_older_version() {
        let mut reg = registry();
        let v1 = reg.publish_params(vec![0.0; 4], 1, String::new(), 0.0).unwrap();
        let v2 = reg.publish_params(vec![1.0; 4], 2, String::new(), 0.0).unwrap();
        reg.activate(v1).unwrap();
        assert_eq!(reg.active().unwrap().version, v1);
        assert!(reg.activate(reg.handle(99)).is_err());
        // A handle from another project is refused outright.
        let foreign = ModelVersion {
            project: ProjectId::new(7),
            version: v2.version,
        };
        assert!(reg.activate(foreign).is_err());
        assert!(reg.get(foreign).is_none());
        assert_eq!(reg.active().unwrap().version, v1);
    }

    #[test]
    fn gc_keeps_newest_and_active() {
        let mut reg = registry();
        for i in 0..5 {
            reg.publish_params(vec![i as f32; 4], i, String::new(), i as f64)
                .unwrap();
        }
        reg.activate(reg.handle(1)).unwrap(); // pin the oldest
        let dropped = reg.gc_keep_latest(2);
        assert_eq!(dropped, vec![reg.handle(2), reg.handle(3)]);
        assert_eq!(reg.ids(), vec![reg.handle(1), reg.handle(4), reg.handle(5)]);
        assert_eq!(reg.active().unwrap().version.version, 1);
    }

    #[test]
    fn gc_never_evicts_a_snapshot_with_inflight_readers() {
        // The co-simulation acceptance criterion: hold a reader across a
        // GC call and the pinned version must survive retention.
        let mut reg = registry();
        for i in 0..4 {
            reg.publish_params(vec![i as f32; 4], i, String::new(), i as f64)
                .unwrap();
        }
        let v1 = reg.handle(1);
        reg.pin_reader(v1).unwrap();
        reg.pin_reader(v1).unwrap();
        assert_eq!(reg.reader_count(v1), 2);
        let dropped = reg.gc_keep_latest(1);
        assert_eq!(
            dropped,
            vec![reg.handle(2), reg.handle(3)],
            "pinned v1 and active v4 survive"
        );
        assert!(reg.get(v1).is_some());
        // One release is not enough — the second reader still holds it.
        reg.unpin_reader(v1);
        assert!(reg.gc_keep_latest(1).is_empty());
        // Last reader gone: retention finally wins.
        reg.unpin_reader(v1);
        assert_eq!(reg.total_readers(), 0);
        assert_eq!(reg.gc_keep_latest(1), vec![v1]);
        assert_eq!(reg.ids(), vec![reg.handle(4)]);
    }

    #[test]
    fn pin_requires_a_resident_version_of_this_project() {
        let mut reg = registry();
        assert!(reg.pin_reader(reg.handle(1)).is_err());
        reg.publish_params(vec![0.0; 4], 0, String::new(), 0.0).unwrap();
        assert!(reg.pin_reader(reg.handle(1)).is_ok());
        assert_eq!(reg.reader_count(reg.handle(2)), 0);
        let foreign = ModelVersion {
            project: ProjectId::new(3),
            version: 1,
        };
        assert!(reg.pin_reader(foreign).is_err());
        assert_eq!(reg.reader_count(foreign), 0);
    }

    #[test]
    fn state_roundtrip_preserves_active_staged_and_rollback() {
        let mut reg = registry();
        let v1 = reg.publish_params(vec![0.0; 4], 1, "first".into(), 0.0).unwrap();
        reg.publish_params(vec![1.0; 4], 2, "second".into(), 1.0).unwrap();
        let staged = reg
            .stage_params(vec![2.0; 4], 3, "in flight".into(), 2.0)
            .unwrap();
        reg.activate(v1).unwrap(); // rolled back to v1
        reg.pin_reader(v1).unwrap(); // pins must NOT survive the roundtrip

        let st = reg.export_state();
        let warm = SnapshotRegistry::from_state(P, spec(), st.clone()).unwrap();
        assert_eq!(warm.active().unwrap().version, v1);
        assert!(warm.is_staged(staged));
        assert_eq!(warm.ids(), reg.ids());
        assert_eq!(warm.total_readers(), 0, "pins are in-flight state");
        assert_eq!(warm.get(v1).unwrap().notes, "first");
        assert_eq!(*warm.get(staged).unwrap().params, vec![2.0; 4]);
        // The version counter survives: the next publication does not
        // reuse a retired number.
        let mut warm = warm;
        let v4 = warm.publish_params(vec![3.0; 4], 9, String::new(), 3.0).unwrap();
        assert_eq!(v4.version, 4);
        // Round-trip of the roundtrip is stable.
        assert_eq!(st.rows.len(), 3);
    }

    #[test]
    fn from_state_rejects_inconsistent_manifests() {
        let mut reg = registry();
        reg.publish_params(vec![0.0; 4], 1, String::new(), 0.0).unwrap();
        let good = reg.export_state();

        let mut active_missing = good.clone();
        active_missing.active = Some(9);
        assert!(SnapshotRegistry::from_state(P, spec(), active_missing)
            .unwrap_err()
            .contains("not resident"));

        let mut staged_missing = good.clone();
        staged_missing.staged = vec![9];
        assert!(SnapshotRegistry::from_state(P, spec(), staged_missing)
            .unwrap_err()
            .contains("not resident"));

        let mut counter_behind = good.clone();
        counter_behind.next = 1;
        assert!(SnapshotRegistry::from_state(P, spec(), counter_behind)
            .unwrap_err()
            .contains("next counter"));

        let mut wrong_dim = good.clone();
        wrong_dim.rows[0].params = Arc::new(vec![0.0; 3]);
        assert!(SnapshotRegistry::from_state(P, spec(), wrong_dim)
            .unwrap_err()
            .contains("expects 4"));

        let mut wrong_model = good;
        wrong_model.rows[0].model = "other".into();
        assert!(SnapshotRegistry::from_state(P, spec(), wrong_model)
            .unwrap_err()
            .contains("registry serves"));
    }

    #[test]
    fn meta_mirrors_snapshot_identity() {
        let mut reg = registry();
        reg.publish_params(vec![0.0; 4], 7, "m".into(), 3.5).unwrap();
        let m = reg.active().unwrap().meta();
        assert_eq!(m.version, reg.handle(1));
        assert_eq!(m.iteration, 7);
        assert_eq!(m.published_ms, 3.5);
    }
}
