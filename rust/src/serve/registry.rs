//! Snapshot registry — versioned parameter vectors behind the serving
//! endpoint.
//!
//! The paper's prediction story (§2.3, §3.6): trained models are saved in
//! a universally readable format — the JSON research closure — and "any
//! device" downloads them for inference.  The registry is the server side
//! of that hand-off: it ingests closures (or live parameter vectors from a
//! training master), validates them against the model's manifest spec,
//! assigns monotonically increasing version ids, and designates the
//! *active* snapshot new prediction requests are served from.  Publishing
//! activates the new version; `set_active` rolls back.
//!
//! **Traffic-driven GC.**  Under the co-simulation a live master publishes
//! mid-traffic, so a retention policy alone is unsafe: a request admitted
//! under version v must execute against v even if three newer versions
//! land before its batch flushes.  Each admitted request takes a *reader
//! pin* ([`SnapshotRegistry::pin_reader`]) released after its batch
//! executes; [`SnapshotRegistry::gc_keep_latest`] evicts a version only
//! when the retention policy *and* a zero reader count agree (the active
//! snapshot is always kept too).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::model::{ModelSpec, ResearchClosure};

/// Monotonic snapshot version (1-based; 0 is never assigned).
pub type SnapshotId = u64;

/// Copyable identity/provenance of a snapshot — what the serving path
/// threads through records without holding a registry borrow.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SnapshotMeta {
    pub id: SnapshotId,
    /// Training iteration the parameters were captured at.
    pub iteration: u64,
    /// Virtual publish time (ms).
    pub published_ms: f64,
}

/// One servable model version.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub id: SnapshotId,
    pub model: String,
    /// Training iteration the parameters were captured at.
    pub iteration: u64,
    /// Shared parameter vector (the executor and cache key off it without
    /// copying ~100k f32 per request batch).
    pub params: Arc<Vec<f32>>,
    /// Free-form provenance (mirrors the closure's notes).
    pub notes: String,
    /// Virtual publish time (ms) — input to retention policies.
    pub published_ms: f64,
}

impl Snapshot {
    /// Copyable identity for records and observers.
    pub fn meta(&self) -> SnapshotMeta {
        SnapshotMeta {
            id: self.id,
            iteration: self.iteration,
            published_ms: self.published_ms,
        }
    }
}

/// Versioned snapshot store for one served model.
#[derive(Debug, Clone)]
pub struct SnapshotRegistry {
    spec: ModelSpec,
    next_id: SnapshotId,
    snapshots: BTreeMap<SnapshotId, Snapshot>,
    active: Option<SnapshotId>,
    /// In-flight reader pins per version (admitted-but-not-yet-executed
    /// requests); a pinned version survives retention GC.
    readers: BTreeMap<SnapshotId, u64>,
}

impl SnapshotRegistry {
    pub fn new(spec: ModelSpec) -> Self {
        Self {
            spec,
            next_id: 1,
            snapshots: BTreeMap::new(),
            active: None,
            readers: BTreeMap::new(),
        }
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Ingest a research closure (the paper's download/upload object);
    /// validates model identity and parameter count before versioning.
    pub fn publish_closure(
        &mut self,
        closure: &ResearchClosure,
        now_ms: f64,
    ) -> Result<SnapshotId, String> {
        closure.check_compatible(&self.spec)?;
        self.publish_params(
            closure.params.clone(),
            closure.iteration,
            closure.notes.clone(),
            now_ms,
        )
    }

    /// Publish a raw parameter vector (live hand-off from a training
    /// master).  The new snapshot becomes active.
    pub fn publish_params(
        &mut self,
        params: Vec<f32>,
        iteration: u64,
        notes: String,
        now_ms: f64,
    ) -> Result<SnapshotId, String> {
        if params.len() != self.spec.param_count {
            return Err(format!(
                "snapshot has {} params, model '{}' expects {}",
                params.len(),
                self.spec.name,
                self.spec.param_count
            ));
        }
        if let Some(bad) = params.iter().position(|p| !p.is_finite()) {
            return Err(format!("snapshot param {bad} is not finite"));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.snapshots.insert(
            id,
            Snapshot {
                id,
                model: self.spec.name.clone(),
                iteration,
                params: Arc::new(params),
                notes,
                published_ms: now_ms,
            },
        );
        self.active = Some(id);
        Ok(id)
    }

    pub fn get(&self, id: SnapshotId) -> Option<&Snapshot> {
        self.snapshots.get(&id)
    }

    /// The snapshot new requests are served from.
    pub fn active(&self) -> Option<&Snapshot> {
        self.active.and_then(|id| self.snapshots.get(&id))
    }

    /// Pin serving to an existing version (rollback / canary-undo).
    pub fn set_active(&mut self, id: SnapshotId) -> Result<(), String> {
        if !self.snapshots.contains_key(&id) {
            return Err(format!("snapshot v{id} not in registry"));
        }
        self.active = Some(id);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.snapshots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.snapshots.is_empty()
    }

    /// Version ids, oldest first.
    pub fn ids(&self) -> Vec<SnapshotId> {
        self.snapshots.keys().copied().collect()
    }

    // ------------------------------------------------- reader refcounts

    /// Take a reader pin on a version (a request was admitted under it and
    /// its batch has not executed yet).  A pinned version cannot be
    /// GC-evicted.  Errors if the version is not resident.
    pub fn pin_reader(&mut self, id: SnapshotId) -> Result<(), String> {
        if !self.snapshots.contains_key(&id) {
            return Err(format!("cannot pin snapshot v{id}: not in registry"));
        }
        *self.readers.entry(id).or_insert(0) += 1;
        Ok(())
    }

    /// Release a reader pin (the request's batch executed).
    pub fn unpin_reader(&mut self, id: SnapshotId) {
        match self.readers.get_mut(&id) {
            Some(n) if *n > 1 => *n -= 1,
            Some(_) => {
                self.readers.remove(&id);
            }
            None => debug_assert!(false, "unpin without pin on v{id}"),
        }
    }

    /// Outstanding reader pins on one version.
    pub fn reader_count(&self, id: SnapshotId) -> u64 {
        self.readers.get(&id).copied().unwrap_or(0)
    }

    /// Outstanding reader pins across all versions (0 once traffic drains).
    pub fn total_readers(&self) -> u64 {
        self.readers.values().sum()
    }

    /// Retention: keep the newest `keep` versions.  The active snapshot
    /// and any version with outstanding reader pins are always kept — a
    /// version is evicted only when the retention policy *and* zero
    /// in-flight readers agree.  Returns the ids dropped.
    pub fn gc_keep_latest(&mut self, keep: usize) -> Vec<SnapshotId> {
        let ids = self.ids();
        let cutoff = ids.len().saturating_sub(keep);
        let mut dropped = Vec::new();
        for id in &ids[..cutoff] {
            if Some(*id) == self.active || self.reader_count(*id) > 0 {
                continue;
            }
            self.snapshots.remove(id);
            dropped.push(*id);
        }
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 4,
            batch_size: 2,
            micro_batches: vec![2, 1],
            input: vec![2, 1, 1],
            classes: 2,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![4],
                offset: 0,
                size: 4,
                fan_in: 2,
            }],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn publish_versions_and_activates_latest() {
        let mut reg = SnapshotRegistry::new(spec());
        assert!(reg.active().is_none());
        let v1 = reg.publish_params(vec![0.0; 4], 10, "a".into(), 0.0).unwrap();
        let v2 = reg.publish_params(vec![1.0; 4], 20, "b".into(), 5.0).unwrap();
        assert_eq!((v1, v2), (1, 2));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.active().unwrap().id, v2);
        assert_eq!(reg.get(v1).unwrap().iteration, 10);
        assert_eq!(*reg.get(v2).unwrap().params, vec![1.0; 4]);
    }

    #[test]
    fn publish_closure_validates_against_spec() {
        let mut reg = SnapshotRegistry::new(spec());
        let mut c = ResearchClosure::new(&spec(), &[0.5; 4]);
        c.iteration = 7;
        let id = reg.publish_closure(&c, 1.0).unwrap();
        assert_eq!(reg.get(id).unwrap().iteration, 7);

        // Wrong model name is rejected before versioning.
        let mut other = spec();
        other.name = "other".into();
        let bad = ResearchClosure::new(&other, &[0.5; 4]);
        assert!(reg.publish_closure(&bad, 1.0).is_err());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn rejects_bad_param_vectors() {
        let mut reg = SnapshotRegistry::new(spec());
        assert!(reg.publish_params(vec![0.0; 3], 0, String::new(), 0.0).is_err());
        assert!(reg
            .publish_params(vec![0.0, f32::NAN, 0.0, 0.0], 0, String::new(), 0.0)
            .is_err());
        assert!(reg.is_empty());
    }

    #[test]
    fn rollback_pins_older_version() {
        let mut reg = SnapshotRegistry::new(spec());
        let v1 = reg.publish_params(vec![0.0; 4], 1, String::new(), 0.0).unwrap();
        let v2 = reg.publish_params(vec![1.0; 4], 2, String::new(), 0.0).unwrap();
        reg.set_active(v1).unwrap();
        assert_eq!(reg.active().unwrap().id, v1);
        assert!(reg.set_active(99).is_err());
        assert_eq!(reg.active().unwrap().id, v1);
        let _ = v2;
    }

    #[test]
    fn gc_keeps_newest_and_active() {
        let mut reg = SnapshotRegistry::new(spec());
        for i in 0..5 {
            reg.publish_params(vec![i as f32; 4], i, String::new(), i as f64)
                .unwrap();
        }
        reg.set_active(1).unwrap(); // pin the oldest
        let dropped = reg.gc_keep_latest(2);
        assert_eq!(dropped, vec![2, 3]);
        assert_eq!(reg.ids(), vec![1, 4, 5]);
        assert_eq!(reg.active().unwrap().id, 1);
    }

    #[test]
    fn gc_never_evicts_a_snapshot_with_inflight_readers() {
        // The co-simulation acceptance criterion: hold a reader across a
        // GC call and the pinned version must survive retention.
        let mut reg = SnapshotRegistry::new(spec());
        for i in 0..4 {
            reg.publish_params(vec![i as f32; 4], i, String::new(), i as f64)
                .unwrap();
        }
        reg.pin_reader(1).unwrap();
        reg.pin_reader(1).unwrap();
        assert_eq!(reg.reader_count(1), 2);
        let dropped = reg.gc_keep_latest(1);
        assert_eq!(dropped, vec![2, 3], "pinned v1 and active v4 survive");
        assert!(reg.get(1).is_some());
        // One release is not enough — the second reader still holds it.
        reg.unpin_reader(1);
        assert!(reg.gc_keep_latest(1).is_empty());
        // Last reader gone: retention finally wins.
        reg.unpin_reader(1);
        assert_eq!(reg.total_readers(), 0);
        assert_eq!(reg.gc_keep_latest(1), vec![1]);
        assert_eq!(reg.ids(), vec![4]);
    }

    #[test]
    fn pin_requires_a_resident_version() {
        let mut reg = SnapshotRegistry::new(spec());
        assert!(reg.pin_reader(1).is_err());
        reg.publish_params(vec![0.0; 4], 0, String::new(), 0.0).unwrap();
        assert!(reg.pin_reader(1).is_ok());
        assert_eq!(reg.reader_count(2), 0);
    }

    #[test]
    fn meta_mirrors_snapshot_identity() {
        let mut reg = SnapshotRegistry::new(spec());
        reg.publish_params(vec![0.0; 4], 7, "m".into(), 3.5).unwrap();
        let m = reg.active().unwrap().meta();
        assert_eq!(m.id, 1);
        assert_eq!(m.iteration, 7);
        assert_eq!(m.published_ms, 3.5);
    }
}
