//! Admission control + deadline-bounded micro-batching, fair-shared
//! across projects.
//!
//! The serving analogue of the master's gradient-ingestion queue: requests
//! arriving from the fleet are admitted into a bounded FIFO and coalesced
//! into batches.  A batch flushes as soon as the executor is free and
//! either (a) a full `max_batch` is waiting, or (b) the oldest admitted
//! request has waited `max_wait_ms` — the latency/throughput dial every
//! serving system exposes.  When the queue is at `queue_depth` the request
//! is rejected (open-loop load shedding: the client sees a fast error
//! rather than an unbounded tail, the counterpart of §3.3d work-shedding
//! on the training side).
//!
//! **Fair share.**  On a multi-project tier the queue additionally
//! enforces per-project caps ([`AdmissionQueue::set_project_caps`],
//! derived from [`crate::serve::ControlPlane::queue_caps`] weights): a
//! request is admitted only while its project is under both the global
//! depth and its own cap, so a hot project saturating the tier cannot
//! occupy the cold project's reserved slice.
//!
//! **Version purity.**  Requests carry the typed [`ModelVersion`] they
//! were admitted under; [`AdmissionQueue::take_batch`] cuts at version
//! boundaries, so a flushed batch is version-pure *and* project-pure by
//! construction (a `ModelVersion` names both).

use std::collections::VecDeque;
use std::sync::Arc;

use super::control::{ModelVersion, ProjectId};

/// One admitted prediction request waiting for a batch slot.
#[derive(Debug, Clone)]
pub struct PredictRequest {
    pub id: u64,
    pub client: u32,
    /// When the client sent it (virtual ms).
    pub sent_ms: f64,
    /// When it reached the server (virtual ms).
    pub arrival_ms: f64,
    /// Shared input tensor (HWC f32, same pool the load generator draws
    /// from — no per-request pixel copies).
    pub input: Arc<Vec<f32>>,
    /// Prediction-cache key (computed at admission).
    pub key: u64,
    /// Model version (project + snapshot) active when the request was
    /// admitted.  The answer-consistency guarantee: the request is
    /// computed entirely against this version, even if newer versions
    /// activate before its batch flushes.
    pub version: ModelVersion,
}

impl PredictRequest {
    /// The project this request belongs to.
    pub fn project(&self) -> ProjectId {
        self.version.project
    }
}

/// Batching/admission knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Largest batch one flush forms.  `ServeSim` clamps it to the
    /// model's largest compiled micro-batch so one flush is always one
    /// execution.
    pub max_batch: usize,
    /// Deadline: a partial batch waits at most this long past its oldest
    /// member's arrival before flushing.
    pub max_wait_ms: f64,
    /// Admission bound: requests beyond this many pending are rejected.
    /// A depth of 0 is a closed endpoint — **every** request is shed
    /// (useful for draining a shard); it is not rounded up to 1.
    pub queue_depth: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait_ms: 5.0,
            queue_depth: 256,
        }
    }
}

/// Bounded FIFO of admitted requests with flush-time computation and
/// per-project fair-share caps.
#[derive(Debug, Clone)]
pub struct AdmissionQueue {
    policy: BatchPolicy,
    pending: VecDeque<PredictRequest>,
    /// Per-project admission caps (index = `ProjectId::index()`); empty —
    /// or a missing entry — means "global depth only" (single-project
    /// runs, fair share disabled).
    project_caps: Vec<usize>,
    /// Pending count per project (index = `ProjectId::index()`).
    per_project: Vec<u64>,
    admitted: u64,
    rejected: u64,
    /// Why the most recent `take_batch` cut where it did — stamped onto
    /// the trace plane's batch spans.
    last_cut: &'static str,
}

impl AdmissionQueue {
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            policy,
            pending: VecDeque::new(),
            project_caps: Vec::new(),
            per_project: Vec::new(),
            admitted: 0,
            rejected: 0,
            last_cut: "",
        }
    }

    /// Install weighted fair-share caps (one per project, dense by
    /// project index — see `ControlPlane::queue_caps`).
    pub fn set_project_caps(&mut self, caps: Vec<usize>) {
        self.project_caps = caps;
    }

    /// The installed fair-share caps (empty when fair share is off) —
    /// surfaced as `serve/fair-share-cap` counter tracks.
    pub fn project_caps(&self) -> &[usize] {
        &self.project_caps
    }

    /// This project's admission cap: its fair share when caps are
    /// installed, the whole queue otherwise.
    fn cap(&self, project: ProjectId) -> usize {
        self.project_caps
            .get(project.index())
            .copied()
            .unwrap_or(self.policy.queue_depth)
    }

    /// Pending requests of one project.
    pub fn project_pending(&self, project: ProjectId) -> u64 {
        self.per_project.get(project.index()).copied().unwrap_or(0)
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Retune the partial-batch deadline (the autotuning router re-derives
    /// it per shard from the observed arrival rate).  Clamped at zero.
    pub fn set_max_wait_ms(&mut self, wait_ms: f64) {
        self.policy.max_wait_ms = wait_ms.max(0.0);
    }

    /// Retune the flush size (autotune picks a compiled variant from the
    /// observed arrival rate).  Clamped to at least one.
    pub fn set_max_batch(&mut self, max_batch: usize) {
        self.policy.max_batch = max_batch.max(1);
    }

    /// Re-bound admission.  A depth of 0 closes the endpoint (drain mode:
    /// every subsequent offer is shed).
    pub fn set_queue_depth(&mut self, depth: usize) {
        self.policy.queue_depth = depth;
    }

    /// Whether one more request of `project` would be admitted right now
    /// (global depth *and* the project's fair-share cap both have room).
    /// The router probes this before committing an arrival to a shard, so
    /// failover can try another endpoint instead of shedding.
    pub fn can_admit(&self, project: ProjectId) -> bool {
        self.pending.len() < self.policy.queue_depth
            && self.project_pending(project) < self.cap(project) as u64
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Count a shed decided at the router level: every candidate shard
    /// refused the arrival, and this (the originally routed) queue takes
    /// the rejection on its books.  Keeps `rejected` the single shed
    /// counter without constructing a request for a queue that cannot
    /// take it.
    pub fn note_shed(&mut self) {
        self.rejected += 1;
    }

    /// Admit a request, or shed it when the queue (or the request's
    /// project fair share) is full.  Returns whether it was admitted.
    /// `queue_depth: 0` sheds everything — a zero-capacity queue is
    /// closed, not depth-1 (the `.max(1)` rounding this used to do
    /// silently admitted through a "closed" endpoint).
    pub fn offer(&mut self, req: PredictRequest) -> bool {
        let project = req.project();
        if !self.can_admit(project) {
            self.rejected += 1;
            return false;
        }
        let i = project.index();
        if self.per_project.len() <= i {
            self.per_project.resize(i + 1, 0);
        }
        self.per_project[i] += 1;
        self.pending.push_back(req);
        self.admitted += 1;
        true
    }

    /// Arrival time of the oldest pending request.
    pub fn oldest_arrival(&self) -> Option<f64> {
        self.pending.front().map(|r| r.arrival_ms)
    }

    /// Earliest time the next batch may flush, given the executor frees at
    /// `free_at`: a full batch goes as soon as the executor is free; a
    /// partial batch additionally waits for the oldest member's deadline.
    /// `None` when nothing is pending.  Callers clamp to "now" — pending
    /// requests arrived in the past, so the returned time may precede the
    /// caller's clock.
    pub fn next_flush_at(&self, free_at: f64) -> Option<f64> {
        let oldest = self.oldest_arrival()?;
        let ready = if self.pending.len() >= self.policy.max_batch {
            oldest
        } else {
            oldest + self.policy.max_wait_ms
        };
        Some(ready.max(free_at))
    }

    /// Pop up to `max_batch` requests, FIFO — stopping at a version
    /// boundary.  When a hot-swap lands mid-traffic (or two projects'
    /// arrivals interleave) the queue can hold requests admitted under
    /// several `ModelVersion`s; a flushed batch executes against exactly
    /// one project's parameter vector, so the batch is cut where the
    /// version changes (the newer — or other-project — requests flush
    /// next round).  Version purity implies project purity: the handle
    /// names both.
    pub fn take_batch(&mut self) -> Vec<PredictRequest> {
        let max = self.policy.max_batch.max(1);
        let Some(first) = self.pending.front() else {
            return Vec::new();
        };
        let version = first.version;
        let n = self
            .pending
            .iter()
            .take(max)
            .take_while(|r| r.version == version)
            .count();
        self.last_cut = if n == max {
            "full"
        } else if n < self.pending.len() {
            // Stopped early with more pending: the next request carries a
            // different version (or project).
            "version-boundary"
        } else {
            "deadline"
        };
        let batch: Vec<PredictRequest> = self.pending.drain(..n).collect();
        let i = version.project.index();
        debug_assert!(self.per_project.len() > i, "admitted project untracked");
        if let Some(count) = self.per_project.get_mut(i) {
            *count -= batch.len() as u64;
        }
        batch
    }

    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Why the most recent `take_batch` cut: `"full"` (hit `max_batch`),
    /// `"version-boundary"` (a newer version / other project was next) or
    /// `"deadline"` (partial batch, wait expired).  Empty before any cut.
    pub fn last_cut(&self) -> &'static str {
        self.last_cut
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival_ms: f64) -> PredictRequest {
        req_v(id, arrival_ms, 1)
    }

    fn req_v(id: u64, arrival_ms: f64, version: u64) -> PredictRequest {
        req_pv(id, arrival_ms, 0, version)
    }

    fn req_pv(id: u64, arrival_ms: f64, project: u32, version: u64) -> PredictRequest {
        PredictRequest {
            id,
            client: 0,
            sent_ms: arrival_ms - 1.0,
            arrival_ms,
            input: Arc::new(vec![0.0; 4]),
            key: id,
            version: ModelVersion {
                project: ProjectId::new(project),
                version,
            },
        }
    }

    const P0: ProjectId = ProjectId::new(0);

    fn queue(max_batch: usize, max_wait_ms: f64, depth: usize) -> AdmissionQueue {
        AdmissionQueue::new(BatchPolicy {
            max_batch,
            max_wait_ms,
            queue_depth: depth,
        })
    }

    #[test]
    fn empty_queue_has_no_flush() {
        let q = queue(4, 5.0, 16);
        assert!(q.next_flush_at(0.0).is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut q = queue(4, 5.0, 16);
        q.offer(req(1, 10.0));
        q.offer(req(2, 11.0));
        // Oldest arrived at 10, so the partial batch flushes at 15.
        assert_eq!(q.next_flush_at(0.0), Some(15.0));
        // A busy executor pushes the flush later.
        assert_eq!(q.next_flush_at(20.0), Some(20.0));
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut q = queue(2, 50.0, 16);
        q.offer(req(1, 10.0));
        q.offer(req(2, 12.0));
        // Full: no deadline wait; only executor availability matters.
        assert_eq!(q.next_flush_at(0.0), Some(10.0));
        assert_eq!(q.next_flush_at(13.0), Some(13.0));
    }

    #[test]
    fn take_batch_is_fifo_and_bounded() {
        let mut q = queue(2, 5.0, 16);
        for i in 0..5 {
            q.offer(req(i, i as f64));
        }
        let b1 = q.take_batch();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(q.len(), 3);
        let b2 = q.take_batch();
        assert_eq!(b2[0].id, 2);
        assert_eq!(q.take_batch().len(), 1);
        assert!(q.take_batch().is_empty());
    }

    #[test]
    fn take_batch_records_its_cut_reason() {
        let mut q = queue(2, 5.0, 16);
        assert_eq!(q.last_cut(), "", "no cut yet");
        q.offer(req(1, 0.0));
        q.offer(req(2, 1.0));
        q.offer(req_v(3, 2.0, 2));
        q.take_batch();
        assert_eq!(q.last_cut(), "full");
        q.take_batch();
        assert_eq!(q.last_cut(), "deadline", "partial batch, nothing behind it");
        q.offer(req(4, 3.0));
        q.offer(req_v(5, 4.0, 2));
        q.take_batch();
        assert_eq!(q.last_cut(), "version-boundary");
    }

    #[test]
    fn zero_depth_sheds_everything() {
        // Regression: `offer` used to round depth 0 up to 1 and admit one
        // request through a closed endpoint.
        let mut q = queue(4, 5.0, 0);
        assert!(!q.offer(req(1, 0.0)), "closed queue must shed");
        assert!(!q.offer(req(2, 1.0)));
        assert_eq!(q.admitted(), 0);
        assert_eq!(q.rejected(), 2);
        assert!(q.is_empty());
        assert!(q.next_flush_at(0.0).is_none());
    }

    #[test]
    fn retuned_wait_moves_the_flush_deadline() {
        let mut q = queue(4, 5.0, 16);
        q.offer(req(1, 10.0));
        assert_eq!(q.next_flush_at(0.0), Some(15.0));
        q.set_max_wait_ms(0.0);
        assert_eq!(q.next_flush_at(0.0), Some(10.0), "no-wait flushes now");
        q.set_max_wait_ms(-3.0);
        assert_eq!(q.policy().max_wait_ms, 0.0, "negative clamps to zero");
    }

    #[test]
    fn take_batch_never_mixes_snapshot_versions() {
        // Hot-swap mid-traffic: v1 requests queued before the swap, v2
        // after.  One flush must carry one version only — even when a
        // full max_batch of mixed requests is pending.
        let mut q = queue(4, 5.0, 16);
        q.offer(req_v(1, 0.0, 1));
        q.offer(req_v(2, 1.0, 1));
        q.offer(req_v(3, 2.0, 2));
        q.offer(req_v(4, 3.0, 2));
        let b1 = q.take_batch();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert!(b1.iter().all(|r| r.version.version == 1));
        let b2 = q.take_batch();
        assert_eq!(b2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
        assert!(b2.iter().all(|r| r.version.version == 2));
        assert!(q.is_empty());
    }

    #[test]
    fn take_batch_never_mixes_projects() {
        // Two projects interleaved on one shard queue, both on their own
        // v1: each flush must carry exactly one project, cut at every
        // project boundary.
        let mut q = queue(4, 5.0, 16);
        q.offer(req_pv(1, 0.0, 0, 1));
        q.offer(req_pv(2, 1.0, 1, 1));
        q.offer(req_pv(3, 2.0, 1, 1));
        q.offer(req_pv(4, 3.0, 0, 1));
        let b1 = q.take_batch();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(b1[0].project(), ProjectId::new(0));
        let b2 = q.take_batch();
        assert_eq!(b2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        assert!(b2.iter().all(|r| r.project() == ProjectId::new(1)));
        let b3 = q.take_batch();
        assert_eq!(b3.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
        assert!(q.is_empty());
        assert_eq!(q.project_pending(ProjectId::new(0)), 0);
        assert_eq!(q.project_pending(ProjectId::new(1)), 0);
    }

    #[test]
    fn fair_share_caps_bound_each_project() {
        // Depth 8, caps 2/6: the hot project (p0) is shed at its cap even
        // though the global queue still has room, and the cold project's
        // reserved slice stays admittable throughout.
        let mut q = queue(8, 5.0, 8);
        q.set_project_caps(vec![2, 6]);
        assert!(q.offer(req_pv(1, 0.0, 0, 1)));
        assert!(q.offer(req_pv(2, 0.0, 0, 1)));
        assert!(!q.can_admit(ProjectId::new(0)), "hot project at its cap");
        assert!(!q.offer(req_pv(3, 0.0, 0, 1)), "over-cap hot request sheds");
        assert_eq!(q.rejected(), 1);
        assert!(q.can_admit(ProjectId::new(1)), "cold share untouched");
        assert!(q.offer(req_pv(4, 0.0, 1, 1)));
        assert_eq!(q.project_pending(ProjectId::new(0)), 2);
        assert_eq!(q.project_pending(ProjectId::new(1)), 1);
        // Draining the hot project's batch reopens its share.
        let batch = q.take_batch();
        assert_eq!(batch.len(), 2);
        assert!(q.can_admit(ProjectId::new(0)));
    }

    #[test]
    fn note_shed_counts_without_touching_the_queue() {
        let mut q = queue(4, 5.0, 2);
        q.offer(req(1, 0.0));
        q.note_shed();
        assert_eq!(q.rejected(), 1);
        assert_eq!(q.admitted(), 1);
        assert_eq!(q.len(), 1, "a router-level shed never enqueues");
    }

    #[test]
    fn can_admit_mirrors_offer() {
        let mut q = queue(4, 5.0, 2);
        assert!(q.can_admit(P0));
        q.offer(req(1, 0.0));
        q.offer(req(2, 0.0));
        assert!(!q.can_admit(P0), "at depth: the probe must refuse");
        q.take_batch();
        assert!(q.can_admit(P0));
        q.set_queue_depth(0);
        assert!(!q.can_admit(P0), "a drained endpoint admits nothing");
    }

    #[test]
    fn retuned_max_batch_changes_flush_threshold() {
        let mut q = queue(4, 50.0, 16);
        q.offer(req(1, 10.0));
        q.offer(req(2, 11.0));
        // Partial under max_batch 4: waits for the 50 ms deadline.
        assert_eq!(q.next_flush_at(0.0), Some(60.0));
        q.set_max_batch(2);
        // Now a full batch: flushes as soon as the executor allows.
        assert_eq!(q.next_flush_at(0.0), Some(10.0));
        assert_eq!(q.take_batch().len(), 2);
        q.set_max_batch(0);
        assert_eq!(q.policy().max_batch, 1, "zero clamps to one");
    }

    #[test]
    fn overflow_is_rejected_and_counted() {
        let mut q = queue(4, 5.0, 2);
        assert!(q.offer(req(1, 0.0)));
        assert!(q.offer(req(2, 0.0)));
        assert!(!q.offer(req(3, 0.0)), "queue at depth must shed");
        assert_eq!(q.admitted(), 2);
        assert_eq!(q.rejected(), 1);
        // Draining frees capacity again.
        q.take_batch();
        assert!(q.offer(req(4, 1.0)));
    }
}
