//! Request routing across replicated serving endpoints shared by every
//! project.
//!
//! PR 1's serving tier was the paper's §3.5 single-master model: one
//! serial endpoint.  This module turns it into a fleet: N [`Shard`]s —
//! each its own fair-shared [`AdmissionQueue`], per-project
//! [`BatchExecutor`]s (the control plane hosts several projects, each
//! with its own model spec, behind the *same* shard fleet) + per-shard
//! [`PredictionCache`] — behind a pluggable [`RoutingPolicy`]:
//!
//! * `rr` — round-robin: cyclic deal, oblivious to backlog.
//! * `jsq` — join-shortest-queue: route to the shard with the least
//!   outstanding work in estimated *milliseconds* ([`Shard::work_ms`]) —
//!   remaining execution time of the in-flight batch plus the pending
//!   requests costed at the shard's own speed.  Counting requests goes
//!   blind twice: the instant a batch is taken, and whenever shard
//!   profiles are mixed (two pending requests on a 10× slower shard are
//!   ten times the work).  Ties break to the lowest index.
//! * `affinity` — input-key affinity: `key mod shards`, so duplicate
//!   inputs always land on the shard whose cache (and in-flight table)
//!   already knows them — per-shard caches then partition the keyspace
//!   instead of replicating it.
//!
//! Two per-shard mechanisms ride along:
//!
//! * **Request coalescing** ([`Shard::coalesce_join`]): a duplicate of an
//!   input that is already queued or executing does not execute again —
//!   it attaches as a waiter and the single computed answer fans out to
//!   every waiter at completion time.  The cache fills once, by the
//!   leader.  (Removes the miss-twice window `serve::sim` documented.)
//! * **Batching autotune** ([`tuned_wait_ms`]): each shard re-derives its
//!   partial-batch deadline from the queue-feeding (admission) rate
//!   observed over a sliding [`RateWindow`] — hits and waiters are
//!   excluded, they never fill a batch slot; the configured
//!   `max_wait_ms` becomes a latency budget ceiling, not a fixed stall.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::model::ModelSpec;

use super::cache::PredictionCache;
use super::control::ProjectId;
use super::executor::{BatchExecutor, Prediction, ServerProfile};
use super::queue::{AdmissionQueue, BatchPolicy, PredictRequest};

/// How arriving requests are spread across shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cyclic deal, backlog-oblivious.
    RoundRobin,
    /// Least outstanding work in estimated milliseconds wins; ties break
    /// to the lowest index.
    JoinShortestQueue,
    /// `input key mod shards` — duplicates share a shard's cache.
    InputAffinity,
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "rr" | "round-robin" => Ok(Self::RoundRobin),
            "jsq" | "shortest-queue" => Ok(Self::JoinShortestQueue),
            "affinity" | "hash" => Ok(Self::InputAffinity),
            other => Err(format!("unknown routing policy '{other}' (rr|jsq|affinity)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::RoundRobin => "rr",
            Self::JoinShortestQueue => "jsq",
            Self::InputAffinity => "affinity",
        }
    }
}

/// Fleet shape + per-shard mechanisms for one serving run.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Replicated endpoints (0 is treated as 1).
    pub shards: usize,
    pub policy: RoutingPolicy,
    /// Dedupe duplicate in-flight inputs before admission and fan the one
    /// computed answer out to every waiter.
    pub coalesce: bool,
    /// Re-derive each shard's `max_wait_ms` from its observed arrival
    /// rate (the configured value becomes the ceiling).
    pub autotune: bool,
    /// Sliding window backing the arrival-rate estimate (ms).
    pub window_ms: f64,
    /// Enforce weighted per-project admission caps on every shard queue
    /// (`ControlPlane::queue_caps`).  Off reproduces the pre-control-plane
    /// tier, where a hot project could occupy the whole queue and starve
    /// a cold one.
    pub fair_share: bool,
}

impl RouterConfig {
    /// PR-1 behavior: one endpoint, no coalescing, fixed deadline.
    /// (Fair share is on but vacuous with a single project.)
    pub fn single() -> Self {
        Self {
            shards: 1,
            policy: RoutingPolicy::RoundRobin,
            coalesce: false,
            autotune: false,
            window_ms: 1_000.0,
            fair_share: true,
        }
    }
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self::single()
    }
}

/// The routing decision state (round-robin cursor).
#[derive(Debug)]
pub struct Router {
    policy: RoutingPolicy,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: RoutingPolicy) -> Self {
        Self { policy, rr_next: 0 }
    }

    /// Pick the shard for a request with cache key `key`, arriving at
    /// `now`.  Deterministic: equal work breaks to the lowest index.
    pub fn route(&mut self, key: u64, shards: &[Shard], now: f64) -> usize {
        let n = shards.len().max(1);
        match self.policy {
            RoutingPolicy::RoundRobin => {
                let i = self.rr_next % n;
                self.rr_next = (self.rr_next + 1) % n;
                i
            }
            RoutingPolicy::JoinShortestQueue => {
                // Least estimated milliseconds of outstanding work; strict
                // `<` keeps the lowest index on exact ties.
                let mut best = 0usize;
                let mut best_ms = f64::INFINITY;
                for (i, s) in shards.iter().enumerate() {
                    let w = s.work_ms(now);
                    if w < best_ms {
                        best_ms = w;
                        best = i;
                    }
                }
                best
            }
            RoutingPolicy::InputAffinity => (key % n as u64) as usize,
        }
    }
}

/// Failover candidate order for an arrival the routed shard refused:
/// every other shard, least outstanding work first (ties to the lowest
/// index).  Deterministic.
pub fn failover_order(routed: usize, shards: &[Shard], now: f64) -> Vec<usize> {
    let mut order: Vec<usize> = (0..shards.len()).filter(|&j| j != routed).collect();
    order.sort_by(|&a, &b| {
        shards[a]
            .work_ms(now)
            .total_cmp(&shards[b].work_ms(now))
            .then(a.cmp(&b))
    });
    order
}

/// Sliding-window arrival counter for the rate estimate behind autotune.
#[derive(Debug, Clone)]
pub struct RateWindow {
    window_ms: f64,
    arrivals: VecDeque<f64>,
}

impl RateWindow {
    pub fn new(window_ms: f64) -> Self {
        Self {
            window_ms: window_ms.max(1.0),
            arrivals: VecDeque::new(),
        }
    }

    /// Record an arrival at `now_ms` and drop those older than the window.
    pub fn observe(&mut self, now_ms: f64) {
        self.arrivals.push_back(now_ms);
        while self
            .arrivals
            .front()
            .is_some_and(|&t| t < now_ms - self.window_ms)
        {
            self.arrivals.pop_front();
        }
    }

    /// Observed arrival rate (per ms) over the window span, or `None`
    /// until two arrivals landed inside it.
    pub fn rate_per_ms(&self) -> Option<f64> {
        if self.arrivals.len() < 2 {
            return None;
        }
        let span = self.arrivals.back().expect("len checked")
            - self.arrivals.front().expect("len checked");
        if span <= 0.0 {
            return None;
        }
        Some((self.arrivals.len() - 1) as f64 / span)
    }
}

/// Pick a shard's partial-batch deadline from its observed arrival rate.
///
/// The configured `max_wait_ms` is the latency budget ceiling.  When the
/// rate is so low that not even one extra request is expected within the
/// whole budget (`rate × budget < 1`), waiting buys no batching — flush
/// immediately.  Otherwise wait just long enough for a full batch to
/// accumulate (`(max_batch − 1) / rate`), capped by the budget.  With no
/// estimate yet, fall back to the configured deadline.
pub fn tuned_wait_ms(rate_per_ms: Option<f64>, base: &BatchPolicy) -> f64 {
    let cap = base.max_wait_ms;
    let Some(rate) = rate_per_ms else {
        return cap;
    };
    if rate <= 0.0 || rate * cap < 1.0 {
        0.0
    } else {
        (base.max_batch.saturating_sub(1) as f64 / rate).min(cap)
    }
}

/// Pick a shard's flush size from its observed arrival rate, clamped to
/// the compiled `predict_b{n}` variants.
///
/// The expected batch fill within the latency budget is the request that
/// opens the batch plus the arrivals the rate predicts during the
/// configured deadline.  Flushing bigger than that just waits for
/// requests that won't come; flushing at the smallest compiled variant
/// that covers the expected fill converts the full-batch flush path from
/// "wait for a 32 that never fills" into "go as soon as the realistic
/// batch is here".  The configured `max_batch` stays the ceiling; with no
/// rate estimate the configured value is used.
pub fn tuned_max_batch(rate_per_ms: Option<f64>, base: &BatchPolicy, variants: &[usize]) -> usize {
    let cap = base.max_batch.max(1);
    let Some(rate) = rate_per_ms else {
        return cap;
    };
    if rate <= 0.0 {
        return cap;
    }
    let expected = (1.0 + rate * base.max_wait_ms).floor() as usize;
    let target = expected.max(1);
    if target >= cap {
        return cap;
    }
    variants
        .iter()
        .copied()
        .filter(|&v| v <= cap && v >= target)
        .min()
        .unwrap_or(cap)
}

/// One request waiting on a duplicate's in-flight computation.
#[derive(Debug, Clone, Copy)]
pub struct Waiter {
    pub id: u64,
    pub client: u32,
    pub sent_ms: f64,
}

/// Outcome of a coalescing attempt for an arriving request.
#[derive(Debug)]
pub enum Join {
    /// No duplicate in flight — admit normally.
    Admit,
    /// Joined a pending computation; the answer fans out when the
    /// leader's batch completes.
    Queued,
    /// The duplicate already computed (completes at `.0`) — serve `.1`.
    Ready(f64, Prediction),
}

/// In-flight table entry: the leader's input (collision guard), attached
/// waiters, and — once the leader's batch flushed — the completion time
/// and answer.
#[derive(Debug)]
struct Inflight {
    input: Arc<Vec<f32>>,
    waiters: Vec<Waiter>,
    done: Option<(f64, Prediction)>,
}

/// A computed prediction awaiting cache visibility at its completion time.
#[derive(Debug)]
struct PendingInsert {
    ready_ms: f64,
    key: u64,
    input: Arc<Vec<f32>>,
    prediction: Prediction,
}

/// One replicated serving endpoint shared by every project: bounded
/// fair-shared admission, per-shard cache (keys are project-scoped), one
/// micro-batch executor *per project* (each project serves its own model
/// spec) behind a single serial execution slot, and the coalescing
/// in-flight table.
#[derive(Debug)]
pub struct Shard {
    /// Stable index; tags `RequestRecord.shard` and the stats row.
    pub id: u32,
    pub queue: AdmissionQueue,
    pub cache: PredictionCache,
    /// One executor per project (index = `ProjectId::index()`) — batches
    /// are project-pure, so each flush runs exactly one of these.
    executors: Vec<BatchExecutor>,
    /// Hardware model shared by every executor on this shard.
    pub profile: ServerProfile,
    /// Virtual time this shard's serial executor frees up.
    pub free_at: f64,
    /// Requests in the batch currently executing (meaningful while
    /// `free_at` is in the future) — the in-flight half of [`Self::depth`].
    pub executing: usize,
    routed: u64,
    coalesced: u64,
    autotune: bool,
    base_policy: BatchPolicy,
    /// Compiled micro-batch variants across every project's spec
    /// (ascending, deduped) — the sizes `tuned_max_batch` may pick from.
    variants: Vec<usize>,
    window: RateWindow,
    /// Cache entries queued until their computation completes.
    pending_inserts: VecDeque<PendingInsert>,
    /// key → in-flight entry (leader queued/executing, or resolved and
    /// awaiting its completion instant).  Determinism audit: point
    /// access only (entry/get_mut/remove by key) — never iterated, so
    /// map order cannot reach observable state.
    inflight: HashMap<u64, Inflight>,
    /// (completion time, key) of resolved entries — completions are
    /// monotone per shard (serial executor), so a front-drain retires
    /// them in order.
    resolved: VecDeque<(f64, u64)>,
}

impl Shard {
    pub fn new(
        id: u32,
        policy: BatchPolicy,
        cache_capacity: usize,
        specs: &[ModelSpec],
        profile: ServerProfile,
        router: &RouterConfig,
    ) -> Self {
        let mut variants: Vec<usize> = specs
            .iter()
            .flat_map(|s| s.micro_batches.iter().copied())
            .filter(|&b| b >= 1)
            .collect();
        variants.sort_unstable();
        variants.dedup();
        Self {
            id,
            queue: AdmissionQueue::new(policy),
            cache: PredictionCache::new(cache_capacity),
            executors: specs
                .iter()
                .map(|s| BatchExecutor::new(s.clone(), profile))
                .collect(),
            profile,
            free_at: 0.0,
            executing: 0,
            routed: 0,
            coalesced: 0,
            autotune: router.autotune,
            base_policy: policy,
            variants,
            window: RateWindow::new(router.window_ms),
            pending_inserts: VecDeque::new(),
            inflight: HashMap::new(),
            resolved: VecDeque::new(),
        }
    }

    /// The executor serving one project's model on this shard.
    pub fn executor_mut(&mut self, project: ProjectId) -> &mut BatchExecutor {
        &mut self.executors[project.index()]
    }

    /// Close this shard's admission queue (drain mode): every subsequent
    /// arrival is refused here and fails over to another endpoint.
    pub fn drain(&mut self) {
        self.queue.set_queue_depth(0);
    }

    /// Advance shard-local state to `now`: publish cache entries whose
    /// computation completed, retire resolved in-flight entries.  Callers
    /// invoke this before any cache lookup or coalescing decision at
    /// `now`, so a request never sees a stale in-flight entry for an
    /// already-finished computation.
    pub fn tick(&mut self, now: f64) {
        while self
            .pending_inserts
            .front()
            .is_some_and(|p| p.ready_ms <= now)
        {
            let p = self.pending_inserts.pop_front().expect("front checked");
            self.cache.insert(p.key, p.input, p.prediction);
        }
        while self.resolved.front().is_some_and(|&(t, _)| t <= now) {
            let (_, key) = self.resolved.pop_front().expect("front checked");
            self.inflight.remove(&key);
        }
    }

    /// Outstanding work at `now` in request counts: pending plus the
    /// batch still executing.  Reported in stats; the JSQ signal is
    /// [`Self::work_ms`], which weighs these by the shard's speed.
    pub fn depth(&self, now: f64) -> usize {
        let busy = if self.free_at > now { self.executing } else { 0 };
        self.queue.len() + busy
    }

    /// Outstanding work at `now` in estimated *milliseconds*: the
    /// remaining service time of the in-flight batch, plus the pending
    /// requests costed at this shard's own forward rate and per-batch
    /// overhead.  With mixed [`ServerProfile`]s behind one router, two
    /// pending requests on a 10× slower shard are ten times the work —
    /// request counts can't see that, milliseconds can.
    pub fn work_ms(&self, now: f64) -> f64 {
        let busy_ms = (self.free_at - now).max(0.0);
        let pending = self.queue.len();
        if pending == 0 {
            return busy_ms;
        }
        let per_example_ms = 1000.0 / self.profile.power_vps;
        let batches = pending.div_ceil(self.queue.policy().max_batch.max(1));
        busy_ms
            + pending as f64 * per_example_ms
            + batches as f64 * self.profile.per_batch_overhead_ms
    }

    /// Count a routed arrival (all of them: hits, waiters, admissions).
    pub fn note_routed(&mut self) {
        self.routed += 1;
    }

    /// Observe a queue-feeding arrival (one that reached admission); with
    /// autotune on, re-derive the flush size *and* the partial-batch
    /// deadline from the updated rate estimate — the flush size snaps to
    /// a compiled variant covering the expected fill, the deadline to
    /// that batch's fill time.  Cache hits and coalesced waiters are
    /// deliberately excluded: they never occupy a batch slot, so counting
    /// them would overestimate how fast a batch fills and under-batch hot
    /// caches.
    pub fn observe_admission(&mut self, now: f64) {
        if self.autotune {
            self.window.observe(now);
            let rate = self.window.rate_per_ms();
            let batch = tuned_max_batch(rate, &self.base_policy, &self.variants);
            self.queue.set_max_batch(batch);
            let basis = BatchPolicy {
                max_batch: batch,
                ..self.base_policy
            };
            self.queue.set_max_wait_ms(tuned_wait_ms(rate, &basis));
        }
    }

    /// Try to piggyback on an in-flight duplicate of `input`.  A key match
    /// with a different stored input (64-bit hash collision) does not
    /// coalesce — the arrival admits normally and executes.  Pool
    /// duplicates share one `Arc`, so the pointer test short-circuits the
    /// O(input_len) collision-guard compare on the hot path.
    pub fn coalesce_join(&mut self, key: u64, input: &Arc<Vec<f32>>, w: Waiter) -> Join {
        let Some(e) = self.inflight.get_mut(&key) else {
            return Join::Admit;
        };
        if !Arc::ptr_eq(&e.input, input) && e.input.as_slice() != input.as_slice() {
            return Join::Admit;
        }
        self.coalesced += 1;
        match &e.done {
            Some((t, pred)) => Join::Ready(*t, pred.clone()),
            None => {
                e.waiters.push(w);
                Join::Queued
            }
        }
    }

    /// Offer to the admission queue; when admitted and coalescing is on,
    /// register the in-flight entry duplicates attach to.  A key already
    /// owned by a collided entry keeps its owner (the new leader simply
    /// isn't coalescable).  Returns whether the request was admitted.
    pub fn admit(&mut self, req: PredictRequest, coalesce: bool) -> bool {
        let key = req.key;
        let input = Arc::clone(&req.input);
        if !self.queue.offer(req) {
            return false;
        }
        if coalesce {
            self.inflight.entry(key).or_insert_with(|| Inflight {
                input,
                waiters: Vec::new(),
                done: None,
            });
        }
        true
    }

    /// Mark an executed leader's computation finished at `computed_at`;
    /// returns the waiters to fan the answer out to.  The entry stays
    /// visible (as `Join::Ready`) until virtual time passes
    /// `computed_at`, closing the window where a duplicate arrives after
    /// the flush but before the result exists.
    pub fn resolve_inflight(
        &mut self,
        req: &PredictRequest,
        computed_at: f64,
        prediction: &Prediction,
    ) -> Vec<Waiter> {
        let Some(e) = self.inflight.get_mut(&req.key) else {
            return Vec::new();
        };
        if !Arc::ptr_eq(&e.input, &req.input) && e.input.as_slice() != req.input.as_slice() {
            // Collided entry owned by another input; leave it alone.
            return Vec::new();
        }
        e.done = Some((computed_at, prediction.clone()));
        self.resolved.push_back((computed_at, req.key));
        std::mem::take(&mut e.waiters)
    }

    /// Queue a cache fill that becomes visible once virtual time passes
    /// `ready_ms` (the computation's completion).
    pub fn schedule_insert(
        &mut self,
        ready_ms: f64,
        key: u64,
        input: Arc<Vec<f32>>,
        prediction: Prediction,
    ) {
        self.pending_inserts.push_back(PendingInsert {
            ready_ms,
            key,
            input,
            prediction,
        });
    }

    /// End-of-run (or point-in-time) counters for the report (execution
    /// counters summed across every project's executor).
    pub fn stats(&self) -> ShardStats {
        ShardStats {
            shard: self.id,
            routed: self.routed,
            admitted: self.queue.admitted(),
            rejected: self.queue.rejected(),
            cache_hits: self.cache.hits(),
            coalesced: self.coalesced,
            batches: self.executors.iter().map(BatchExecutor::batches).sum(),
            batch_examples: self.executors.iter().map(BatchExecutor::examples).sum(),
            padded_examples: self.executors.iter().map(BatchExecutor::padded).sum(),
            max_wait_ms: self.queue.policy().max_wait_ms,
            max_batch: self.queue.policy().max_batch,
        }
    }
}

/// Per-shard counters surfaced in [`super::ServeReport`].
#[derive(Debug, Clone, Copy)]
pub struct ShardStats {
    pub shard: u32,
    /// Arrivals routed here (hits + coalesced + admitted + rejected).
    pub routed: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub cache_hits: u64,
    pub coalesced: u64,
    pub batches: u64,
    /// Real requests executed in batches (excludes hits/waiters/padding).
    pub batch_examples: u64,
    pub padded_examples: u64,
    /// The partial-batch deadline at end of run (autotune moves it).
    pub max_wait_ms: f64,
    /// The flush size at end of run (autotune snaps it to a compiled
    /// variant).
    pub max_batch: usize,
}

impl ShardStats {
    /// Requests this shard answered (every routed, non-shed request
    /// completes once the run drains).
    pub fn completed(&self) -> u64 {
        self.routed - self.rejected
    }

    /// Mean executed-batch size (real requests per flush).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batch_examples as f64 / self.batches as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 8,
            batch_size: 4,
            micro_batches: vec![4, 1],
            input: vec![2, 1, 1],
            classes: 2,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![8],
                offset: 0,
                size: 8,
                fan_in: 2,
            }],
            artifacts: Default::default(),
        }
    }

    fn policy() -> BatchPolicy {
        BatchPolicy {
            max_batch: 4,
            max_wait_ms: 5.0,
            queue_depth: 16,
        }
    }

    fn shard(id: u32) -> Shard {
        Shard::new(
            id,
            policy(),
            8,
            &[spec()],
            ServerProfile::default(),
            &RouterConfig::single(),
        )
    }

    fn req(id: u64, key: u64, input: Arc<Vec<f32>>) -> PredictRequest {
        PredictRequest {
            id,
            client: 0,
            sent_ms: 0.0,
            arrival_ms: 1.0,
            input,
            key,
            version: crate::serve::ModelVersion {
                project: ProjectId::new(0),
                version: 1,
            },
        }
    }

    fn pred(class: usize) -> Prediction {
        Prediction {
            class,
            confidence: 1.0,
            probs: vec![0.0, 1.0],
        }
    }

    #[test]
    fn policy_parse_round_trips() {
        for p in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::JoinShortestQueue,
            RoutingPolicy::InputAffinity,
        ] {
            assert_eq!(RoutingPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(RoutingPolicy::parse("bogus").is_err());
    }

    #[test]
    fn round_robin_cycles() {
        let shards: Vec<Shard> = (0..3).map(shard).collect();
        let mut r = Router::new(RoutingPolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| r.route(0, &shards, 0.0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_picks_min_depth_tie_low() {
        let mut shards: Vec<Shard> = (0..3).map(shard).collect();
        let mut r = Router::new(RoutingPolicy::JoinShortestQueue);
        // All empty → lowest index.
        assert_eq!(r.route(9, &shards, 0.0), 0);
        // Load shard 0 and 1; shard 2 becomes shortest.
        let input = Arc::new(vec![0.0; 2]);
        shards[0].admit(req(1, 1, Arc::clone(&input)), false);
        shards[1].admit(req(2, 2, Arc::clone(&input)), false);
        assert_eq!(r.route(9, &shards, 0.0), 2);
    }

    #[test]
    fn jsq_counts_in_flight_work_not_just_queue_length() {
        let mut shards: Vec<Shard> = (0..2).map(shard).collect();
        // Shard 0: empty queue but a batch of 4 executing until t=10.
        shards[0].executing = 4;
        shards[0].free_at = 10.0;
        // Shard 1: one request pending, executor idle.
        let input = Arc::new(vec![0.0; 2]);
        shards[1].admit(req(1, 1, Arc::clone(&input)), false);
        assert_eq!(shards[0].depth(5.0), 4);
        assert_eq!(shards[1].depth(5.0), 1);
        let mut r = Router::new(RoutingPolicy::JoinShortestQueue);
        assert_eq!(r.route(9, &shards, 5.0), 1, "busy shard is not 'empty'");
        // Once shard 0's execution completes, its depth drops back to 0.
        assert_eq!(shards[0].depth(10.0), 0);
        assert_eq!(r.route(9, &shards, 10.0), 0);
    }

    #[test]
    fn jsq_weighs_work_in_milliseconds_under_mixed_profiles() {
        // The ROADMAP satellite: a shard fleet with mixed speeds.  Shard 0
        // is 10× slower than shard 1; both hold the same *number* of
        // pending requests, so a count-based JSQ would tie and pick shard
        // 0 — the worst choice.  Milliseconds see through it.
        let slow = ServerProfile {
            power_vps: 400.0,
            ..ServerProfile::default()
        };
        let fast = ServerProfile {
            power_vps: 4_000.0,
            ..ServerProfile::default()
        };
        let mk = |id: u32, profile: ServerProfile| {
            Shard::new(id, policy(), 0, &[spec()], profile, &RouterConfig::single())
        };
        let mut shards = vec![mk(0, slow), mk(1, fast)];
        let input = Arc::new(vec![0.0; 2]);
        for i in 0..2 {
            shards[0].admit(req(i, i, Arc::clone(&input)), false);
            shards[1].admit(req(10 + i, 10 + i, Arc::clone(&input)), false);
        }
        assert_eq!(shards[0].depth(0.0), shards[1].depth(0.0), "counts tie");
        assert!(
            shards[0].work_ms(0.0) > shards[1].work_ms(0.0),
            "same count, more milliseconds on the slow shard"
        );
        let mut r = Router::new(RoutingPolicy::JoinShortestQueue);
        assert_eq!(r.route(9, &shards, 0.0), 1, "route to the fast shard");
    }

    #[test]
    fn work_ms_counts_remaining_execution_and_pending_cost() {
        let mut s = shard(0);
        assert_eq!(s.work_ms(0.0), 0.0);
        // In-flight batch until t=10: remaining time shrinks as now moves.
        s.free_at = 10.0;
        s.executing = 4;
        assert_eq!(s.work_ms(4.0), 6.0);
        assert_eq!(s.work_ms(12.0), 0.0);
        // Pending work: default profile = 0.25 ms/example + 2.5 ms/batch.
        let input = Arc::new(vec![0.0; 2]);
        s.admit(req(1, 1, Arc::clone(&input)), false);
        s.admit(req(2, 2, Arc::clone(&input)), false);
        let w = s.work_ms(12.0);
        assert!((w - (2.0 * 0.25 + 2.5)).abs() < 1e-9, "got {w}");
    }

    #[test]
    fn failover_order_prefers_least_loaded_and_skips_routed() {
        let mut shards: Vec<Shard> = (0..3).map(shard).collect();
        let input = Arc::new(vec![0.0; 2]);
        shards[1].admit(req(1, 1, Arc::clone(&input)), false);
        // Routed shard 0 excluded; empty shard 2 before loaded shard 1.
        assert_eq!(failover_order(0, &shards, 0.0), vec![2, 1]);
        // Ties break to the lowest index.
        assert_eq!(failover_order(1, &shards, 0.0), vec![0, 2]);
    }

    #[test]
    fn drained_shard_refuses_admission() {
        let p0 = ProjectId::new(0);
        let mut s = shard(0);
        assert!(s.queue.can_admit(p0));
        s.drain();
        assert!(!s.queue.can_admit(p0));
        let input = Arc::new(vec![0.0; 2]);
        assert!(!s.admit(req(1, 1, input), false));
        assert_eq!(s.stats().rejected, 1);
    }

    #[test]
    fn tuned_max_batch_snaps_to_compiled_variants() {
        let base = policy(); // max_batch 4, wait 5 ms
        let variants = [1usize, 4, 8, 32];
        // No estimate → configured ceiling.
        assert_eq!(tuned_max_batch(None, &base, &variants), 4);
        // 0.1/ms × 5 ms budget → expected fill 1.5 → variant 1.
        assert_eq!(tuned_max_batch(Some(0.1), &base, &variants), 1);
        // 0.5/ms → expected 3.5 → smallest covering variant is 4.
        assert_eq!(tuned_max_batch(Some(0.5), &base, &variants), 4);
        // 10/ms → expected 51 — capped at the configured ceiling, never
        // the larger compiled variants.
        assert_eq!(tuned_max_batch(Some(10.0), &base, &variants), 4);
        // No variant covers the target but stays under the cap → cap.
        assert_eq!(tuned_max_batch(Some(0.5), &base, &[1, 32]), 4);
    }

    #[test]
    fn affinity_is_deterministic_mod_shards() {
        let shards: Vec<Shard> = (0..4).map(shard).collect();
        let mut r = Router::new(RoutingPolicy::InputAffinity);
        for key in [0u64, 1, 5, 17, u64::MAX] {
            let first = r.route(key, &shards, 0.0);
            assert_eq!(first, (key % 4) as usize);
            assert_eq!(r.route(key, &shards, 0.0), first, "same key, same shard");
        }
    }

    #[test]
    fn rate_window_slides() {
        let mut w = RateWindow::new(100.0);
        assert!(w.rate_per_ms().is_none());
        w.observe(0.0);
        assert!(w.rate_per_ms().is_none(), "one arrival is not a rate");
        for t in [10.0, 20.0, 30.0, 40.0] {
            w.observe(t);
        }
        // 5 arrivals over 40 ms → 0.1/ms.
        assert!((w.rate_per_ms().unwrap() - 0.1).abs() < 1e-9);
        // A much later arrival evicts the old ones.
        w.observe(1_000.0);
        assert!(w.rate_per_ms().is_none(), "window slid past old arrivals");
    }

    #[test]
    fn tuned_wait_tracks_rate() {
        let base = policy(); // max_batch 4, cap 5 ms
        assert_eq!(tuned_wait_ms(None, &base), 5.0, "no estimate → configured");
        // 0.01/ms (10 rps): 0.05 expected arrivals per budget → don't wait.
        assert_eq!(tuned_wait_ms(Some(0.01), &base), 0.0);
        // 3/ms: a full batch accumulates in 1 ms — wait just that long.
        assert!((tuned_wait_ms(Some(3.0), &base) - 1.0).abs() < 1e-9);
        // 0.3/ms: fill time 10 ms clamps to the 5 ms budget.
        assert_eq!(tuned_wait_ms(Some(0.3), &base), 5.0);
    }

    #[test]
    fn coalesce_join_dedupes_and_fans_out() {
        let mut s = shard(0);
        let input = Arc::new(vec![0.5, 0.25]);
        let leader = req(1, 7, Arc::clone(&input));
        assert!(s.admit(leader.clone(), true));
        // Duplicate while the leader is queued: joins as a waiter.
        let w = Waiter { id: 2, client: 1, sent_ms: 0.5 };
        assert!(matches!(s.coalesce_join(7, &input, w), Join::Queued));
        assert_eq!(s.stats().coalesced, 1);
        // Leader's batch completes at t=10: waiters drain once.
        let waiters = s.resolve_inflight(&leader, 10.0, &pred(1));
        assert_eq!(waiters.len(), 1);
        assert_eq!(waiters[0].id, 2);
        // A duplicate arriving before t=10 sees the computed answer.
        let w2 = Waiter { id: 3, client: 2, sent_ms: 8.0 };
        match s.coalesce_join(7, &input, w2) {
            Join::Ready(t, p) => {
                assert_eq!(t, 10.0);
                assert_eq!(p.class, 1);
            }
            other => panic!("expected Ready, got {other:?}"),
        }
        // Past t=10 the entry retires; the next duplicate admits afresh.
        s.tick(10.0);
        let w3 = Waiter { id: 4, client: 3, sent_ms: 11.0 };
        assert!(matches!(s.coalesce_join(7, &input, w3), Join::Admit));
    }

    #[test]
    fn hash_collision_does_not_coalesce() {
        let mut s = shard(0);
        let a = Arc::new(vec![1.0, 0.0]);
        let b = Arc::new(vec![0.0, 1.0]);
        assert!(s.admit(req(1, 7, Arc::clone(&a)), true));
        // Same key, different input: must NOT attach to a's computation.
        let w = Waiter { id: 2, client: 0, sent_ms: 0.0 };
        assert!(matches!(s.coalesce_join(7, &b, w), Join::Admit));
        assert_eq!(s.stats().coalesced, 0);
        // b admits under the same key; a's entry keeps its owner, and
        // resolving b must not release a's waiters or answer.
        let rb = req(2, 7, Arc::clone(&b));
        assert!(s.admit(rb.clone(), true));
        assert!(s.resolve_inflight(&rb, 5.0, &pred(0)).is_empty());
        let w2 = Waiter { id: 3, client: 0, sent_ms: 1.0 };
        assert!(
            matches!(s.coalesce_join(7, &a, w2), Join::Queued),
            "a's entry must still be live for a-duplicates"
        );
    }

    #[test]
    fn pending_inserts_publish_at_tick() {
        let mut s = shard(0);
        let input = Arc::new(vec![0.5, 0.25]);
        s.schedule_insert(10.0, 3, Arc::clone(&input), pred(1));
        s.tick(9.0);
        assert!(s.cache.get(3, &input).is_none(), "not visible before ready");
        s.tick(10.0);
        assert_eq!(s.cache.get(3, &input).unwrap().class, 1);
    }

    #[test]
    fn observe_admission_retunes_queue_wait() {
        let mut s = Shard::new(
            0,
            policy(),
            0,
            &[spec()],
            ServerProfile::default(),
            &RouterConfig {
                autotune: true,
                ..RouterConfig::single()
            },
        );
        assert_eq!(s.queue.policy().max_wait_ms, 5.0);
        // Sparse arrivals (10 ms apart → 0.1/ms × 5 ms budget = 0.5 < 1):
        // the tuned deadline drops to zero.
        s.observe_admission(0.0);
        s.observe_admission(10.0);
        assert_eq!(s.queue.policy().max_wait_ms, 0.0);
        // A dense burst (0.2 ms apart → 5/ms) brings a fill-time wait
        // back: (4−1)/5 = 0.6 ms.
        for i in 0..50 {
            s.observe_admission(10.0 + 0.2 * (i + 1) as f64);
        }
        let wait = s.queue.policy().max_wait_ms;
        assert!(wait > 0.0 && wait < 5.0, "fill-time wait, got {wait}");
    }

    #[test]
    fn shard_keeps_one_executor_per_project() {
        // Two projects with different specs behind one shard: each flush
        // must run the owning project's executor, and the stats row sums
        // both.
        let mut other = spec();
        other.name = "other".into();
        other.input = vec![3, 1, 1];
        other.param_count = 12;
        other.tensors[0].size = 12;
        other.tensors[0].shape = vec![12];
        let mut s = Shard::new(
            0,
            policy(),
            0,
            &[spec(), other],
            ServerProfile::default(),
            &RouterConfig::single(),
        );
        let mut compute = crate::runtime::ModeledCompute { param_count: 12 };
        let a_in = vec![0.1f32, 0.2];
        let b_in = vec![0.1f32, 0.2, 0.3];
        let a_params = vec![0.0f32; 8];
        let b_params = vec![0.0f32; 12];
        s.executor_mut(ProjectId::new(0))
            .execute(&mut compute, &a_params, &[&a_in])
            .unwrap();
        s.executor_mut(ProjectId::new(1))
            .execute(&mut compute, &b_params, &[&b_in])
            .unwrap();
        // Cross-project shapes are rejected by the owning executor.
        assert!(s
            .executor_mut(ProjectId::new(0))
            .execute(&mut compute, &a_params, &[&b_in])
            .is_err());
        assert_eq!(s.stats().batches, 2);
    }

    #[test]
    fn stats_reconcile() {
        let mut s = shard(0);
        let input = Arc::new(vec![0.5, 0.25]);
        s.note_routed();
        assert!(s.admit(req(1, 7, Arc::clone(&input)), true));
        s.note_routed();
        let w = Waiter { id: 2, client: 0, sent_ms: 1.5 };
        assert!(matches!(s.coalesce_join(7, &input, w), Join::Queued));
        let st = s.stats();
        assert_eq!(st.routed, 2);
        assert_eq!(st.admitted, 1);
        assert_eq!(st.coalesced, 1);
        assert_eq!(st.rejected, 0);
        assert_eq!(st.completed(), 2);
    }
}
