//! The multi-project control plane — §3.1's "one master hosts several
//! projects" made typed.
//!
//! MLitB's master is explicitly multi-tenant: one master process hosts
//! *several projects*, each with its own model, data and clients.  The
//! serving tier used to hard-code a single anonymous model; this module
//! is the ownership root that lifts it to N projects:
//!
//! * [`ProjectId`] — typed project identity.  Only the control plane
//!   mints them (registration order), so an id always names a registered
//!   project; raw integers no longer flow through the serving API.
//! * [`ModelVersion`] — typed model handle `(project, version)` replacing
//!   the bare `u64` snapshot ids end-to-end: requests, batches, cache
//!   keys, logs and publication records all carry it, so a version can
//!   never be confused across projects.
//! * [`ControlPlane`] — owns one [`SnapshotRegistry`] (and a fair-share
//!   weight) per project.  The serving engine routes every arrival
//!   through it: active-version lookup, reader pins and GC are all
//!   per-project, so one project's pinned versions never block another
//!   project's eviction.
//! * [`ControlPlane::queue_caps`] — weighted fair-share admission: each
//!   project may occupy at most `weight_share × queue_depth` slots of a
//!   shard's admission queue, so a hot project saturating the tier
//!   cannot starve a cold one out of its share.

use std::fmt;
use std::path::Path;

use crate::model::ModelSpec;
use crate::storage::registry_store;

use super::registry::{Snapshot, SnapshotRegistry};

/// Typed identity of one hosted project (§3.1).  Minted by
/// [`ControlPlane::register`] in registration order; `new` exists for
/// tests and for decoding logs, not for inventing projects.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ProjectId(u32);

impl ProjectId {
    pub const fn new(raw: u32) -> Self {
        Self(raw)
    }

    /// Dense index (registration order) — what per-project tables and
    /// queue caps are keyed by.
    pub fn index(self) -> usize {
        self.0 as usize
    }

    pub fn as_u32(self) -> u32 {
        self.0
    }
}

impl fmt::Display for ProjectId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "p{}", self.0)
    }
}

/// Typed model-version handle: which project, which snapshot.  Replaces
/// the old bare `u64` snapshot id everywhere a version crosses an API
/// boundary — a `ModelVersion` from one project cannot silently index
/// into another project's registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ModelVersion {
    pub project: ProjectId,
    /// 1-based version number within the project (0 is never assigned).
    pub version: u64,
}

impl ModelVersion {
    /// Globally unique flow-edge id for the trace plane: the publication
    /// of this version and the first batch served on it share this id.
    /// Project in the high 32 bits, version in the low 32 — well inside
    /// f64's exact-integer range for any realistic run, so the id
    /// round-trips through JSON untouched.
    pub fn flow_id(&self) -> u64 {
        ((self.project.as_u32() as u64) << 32) | (self.version & 0xFFFF_FFFF)
    }
}

impl fmt::Display for ModelVersion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}v{}", self.project, self.version)
    }
}

/// Per-project serving counters surfaced in [`super::ServeReport`].
#[derive(Debug, Clone, Copy)]
pub struct ProjectStats {
    pub project: ProjectId,
    pub offered: u64,
    pub completed: u64,
    pub rejected: u64,
}

impl ProjectStats {
    /// Fraction of this project's offered requests shed at admission.
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.offered as f64
    }
}

/// One hosted project: its registry plus its fair-share weight.
#[derive(Debug, Clone)]
struct ProjectEntry {
    registry: SnapshotRegistry,
    weight: f64,
}

/// The multi-project ownership root: one snapshot registry per project,
/// fair-share weights, and cross-project version lookup.  See the module
/// docs for the full story.
#[derive(Debug, Clone, Default)]
pub struct ControlPlane {
    entries: Vec<ProjectEntry>,
}

impl ControlPlane {
    pub fn new() -> Self {
        Self::default()
    }

    /// Convenience: a plane hosting exactly one project (weight 1) — the
    /// single-tenant shape benches and the `serve-sim` CLI use.
    pub fn single(spec: ModelSpec) -> Self {
        let mut plane = Self::new();
        plane.register(spec, 1.0);
        plane
    }

    /// Register a project; returns its minted id.  Non-positive weights
    /// clamp to a tiny positive share (a zero-weight project would be
    /// unservable, not merely deprioritized).
    pub fn register(&mut self, spec: ModelSpec, weight: f64) -> ProjectId {
        let id = ProjectId(self.entries.len() as u32);
        self.entries.push(ProjectEntry {
            registry: SnapshotRegistry::new(id, spec),
            weight: if weight > 0.0 { weight } else { 1e-6 },
        });
        id
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Registered project ids, registration order.
    pub fn ids(&self) -> Vec<ProjectId> {
        (0..self.entries.len() as u32).map(ProjectId).collect()
    }

    /// Served model specs, one per project (registration order) — what
    /// the engine builds its per-project executors from.
    pub fn specs(&self) -> Vec<ModelSpec> {
        self.entries
            .iter()
            .map(|e| e.registry.spec().clone())
            .collect()
    }

    pub fn registry(&self, project: ProjectId) -> &SnapshotRegistry {
        &self.entries[project.index()].registry
    }

    pub fn registry_mut(&mut self, project: ProjectId) -> &mut SnapshotRegistry {
        &mut self.entries[project.index()].registry
    }

    pub fn weight(&self, project: ProjectId) -> f64 {
        self.entries[project.index()].weight
    }

    pub fn total_weight(&self) -> f64 {
        self.entries.iter().map(|e| e.weight).sum()
    }

    /// The snapshot a version handle names, routed to its own project's
    /// registry (`None` when evicted or never published).
    pub fn get(&self, version: ModelVersion) -> Option<&Snapshot> {
        self.entries
            .get(version.project.index())?
            .registry
            .get(version)
    }

    /// The snapshot new requests of `project` are served from.
    pub fn active(&self, project: ProjectId) -> Option<&Snapshot> {
        self.entries.get(project.index())?.registry.active()
    }

    /// Pin a version against GC (routed to its project's registry).
    pub fn pin_reader(&mut self, version: ModelVersion) -> Result<(), String> {
        self.registry_mut(version.project).pin_reader(version)
    }

    /// Release a reader pin.
    pub fn unpin_reader(&mut self, version: ModelVersion) {
        self.registry_mut(version.project).unpin_reader(version);
    }

    /// Outstanding reader pins across every project (0 once traffic
    /// drains).
    pub fn total_readers(&self) -> u64 {
        self.entries
            .iter()
            .map(|e| e.registry.total_readers())
            .sum()
    }

    /// Snapshots resident across every project's registry.
    pub fn resident(&self) -> usize {
        self.entries.iter().map(|e| e.registry.len()).sum()
    }

    /// Weighted fair-share admission caps for a shard queue of `depth`
    /// slots: project `p` may occupy at most
    /// `max(1, floor(depth × weight_p / Σweights))` pending slots.
    ///
    /// The cap sum is kept ≤ `depth` whenever `depth` can seat every
    /// project at all (each project's share is then a *real*
    /// reservation: a hot project at its cap always leaves the cold
    /// project's share admittable) — raising a zero floor to 1 shaves
    /// the largest caps to compensate.  Only when `depth` is smaller
    /// than the project count does the sum exceed it (everyone keeps one
    /// admittable slot and races for the global depth).  A
    /// single-project plane gets the whole queue; `depth == 0` stays a
    /// closed endpoint for everyone.
    pub fn queue_caps(&self, depth: usize) -> Vec<usize> {
        let n = self.entries.len();
        if depth == 0 {
            return vec![0; n];
        }
        if n <= 1 {
            return vec![depth; n];
        }
        let total = self.total_weight();
        let mut caps: Vec<usize> = self
            .entries
            .iter()
            .map(|e| {
                let share = (depth as f64 * e.weight / total).floor() as usize;
                share.clamp(1, depth)
            })
            .collect();
        // The max(1) floor can push the sum past `depth` under skewed
        // weights; shave the largest caps (never below 1) so every cap
        // stays a genuine reservation against the global bound.
        if depth >= n {
            let mut excess = caps.iter().sum::<usize>().saturating_sub(depth);
            while excess > 0 {
                let (i, &largest) = caps
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
                    .expect("n >= 2");
                if largest <= 1 {
                    break;
                }
                let shave = excess.min(largest - 1);
                caps[i] -= shave;
                excess -= shave;
            }
        }
        caps
    }

    /// Persist every project's registry under `root` — project `p{i}`
    /// lands in `root/p{i}` via [`crate::storage::registry_store::save`].
    /// Reader pins are runtime state and are not persisted.
    pub fn persist(&self, root: &Path) -> crate::storage::Result<()> {
        for (i, entry) in self.entries.iter().enumerate() {
            registry_store::save(&root.join(format!("p{i}")), &entry.registry)?;
        }
        Ok(())
    }

    /// Warm this plane's registries from a directory written by
    /// [`Self::persist`].  Projects must already be registered (the specs
    /// define what each directory may contain); a project with no
    /// persisted state keeps its freshly-registered empty registry.
    /// Returns how many registries were restored.
    pub fn restore_registries(&mut self, root: &Path) -> crate::storage::Result<usize> {
        let mut restored = 0;
        for (i, entry) in self.entries.iter_mut().enumerate() {
            let dir = root.join(format!("p{i}"));
            if !dir.exists() {
                continue;
            }
            let spec = entry.registry.spec().clone();
            if let Some(reg) = registry_store::load(&dir, ProjectId(i as u32), &spec)? {
                entry.registry = reg;
                restored += 1;
            }
        }
        Ok(restored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;

    fn spec(name: &str) -> ModelSpec {
        ModelSpec {
            name: name.into(),
            param_count: 4,
            batch_size: 2,
            micro_batches: vec![2, 1],
            input: vec![2, 1, 1],
            classes: 2,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![4],
                offset: 0,
                size: 4,
                fan_in: 2,
            }],
            artifacts: Default::default(),
        }
    }

    fn two_project_plane() -> (ControlPlane, ProjectId, ProjectId) {
        let mut plane = ControlPlane::new();
        let a = plane.register(spec("a"), 1.0);
        let b = plane.register(spec("b"), 1.0);
        (plane, a, b)
    }

    #[test]
    fn registration_mints_dense_ids() {
        let (plane, a, b) = two_project_plane();
        assert_eq!(plane.len(), 2);
        assert_eq!((a.index(), b.index()), (0, 1));
        assert_eq!(plane.ids(), vec![a, b]);
        assert_eq!(plane.registry(a).spec().name, "a");
        assert_eq!(plane.registry(b).spec().name, "b");
        assert_eq!(a.to_string(), "p0");
    }

    #[test]
    fn versions_are_project_scoped() {
        let (mut plane, a, b) = two_project_plane();
        let va = plane
            .registry_mut(a)
            .publish_params(vec![0.0; 4], 1, "a1".into(), 0.0)
            .unwrap();
        assert_eq!(va.project, a);
        assert_eq!(va.version, 1);
        assert_eq!(va.to_string(), "p0v1");
        // The same version *number* under project b names nothing until b
        // publishes — handles don't leak across projects.
        let vb_handle = ModelVersion { project: b, version: 1 };
        assert!(plane.get(vb_handle).is_none());
        assert!(plane.get(va).is_some());
        let vb = plane
            .registry_mut(b)
            .publish_params(vec![1.0; 4], 5, "b1".into(), 0.0)
            .unwrap();
        assert_eq!(plane.get(vb).unwrap().iteration, 5);
        assert_eq!(plane.get(va).unwrap().iteration, 1);
        assert_eq!(plane.active(a).unwrap().version, va);
        assert_eq!(plane.active(b).unwrap().version, vb);
    }

    #[test]
    fn one_projects_pins_never_block_anothers_eviction() {
        // The cross-project GC satellite: reader pins are per-registry, so
        // a pinned version in project a must not save project b's stale
        // versions from retention.
        let (mut plane, a, b) = two_project_plane();
        for i in 0..4 {
            plane
                .registry_mut(a)
                .publish_params(vec![i as f32; 4], i, String::new(), i as f64)
                .unwrap();
            plane
                .registry_mut(b)
                .publish_params(vec![i as f32; 4], i, String::new(), i as f64)
                .unwrap();
        }
        let a1 = plane.registry(a).handle(1);
        plane.pin_reader(a1).unwrap();
        assert_eq!(plane.registry(a).reader_count(a1), 1);
        // Project b GCs to 1 resident version: everything old goes, the
        // pin in project a notwithstanding.
        let evicted_b = plane.registry_mut(b).gc_keep_latest(1);
        assert_eq!(
            evicted_b,
            (1..4)
                .map(|v| ModelVersion { project: b, version: v })
                .collect::<Vec<_>>()
        );
        assert_eq!(plane.registry(b).len(), 1);
        // Project a's GC keeps its pinned v1 (and active v4) only.
        let evicted_a = plane.registry_mut(a).gc_keep_latest(1);
        assert_eq!(
            evicted_a,
            (2..4)
                .map(|v| ModelVersion { project: a, version: v })
                .collect::<Vec<_>>()
        );
        assert!(plane.get(a1).is_some(), "pinned version survives");
        plane.unpin_reader(a1);
        assert_eq!(plane.total_readers(), 0);
        assert_eq!(plane.registry_mut(a).gc_keep_latest(1), vec![a1]);
        assert_eq!(plane.resident(), 2);
    }

    #[test]
    fn fair_share_caps_reserve_each_projects_slice() {
        let mut plane = ControlPlane::new();
        plane.register(spec("hot"), 3.0);
        plane.register(spec("cold"), 1.0);
        assert_eq!(plane.queue_caps(64), vec![48, 16]);
        // Floors keep the sum within the queue depth.
        assert!(plane.queue_caps(7).iter().sum::<usize>() <= 7);
        // Tiny queues: everyone stays admittable.
        assert_eq!(plane.queue_caps(1), vec![1, 1]);
        // Skewed weights + small depth: raising zero floors to 1 must
        // shave the hot cap, not oversubscribe the queue — otherwise the
        // "reserved" cold slices are not actually admittable under the
        // global depth bound.
        let mut skewed = ControlPlane::new();
        skewed.register(spec("hot"), 10.0);
        skewed.register(spec("c1"), 1.0);
        skewed.register(spec("c2"), 1.0);
        assert_eq!(skewed.queue_caps(4), vec![2, 1, 1]);
        assert!(skewed.queue_caps(4).iter().sum::<usize>() <= 4);
        // Depth below the project count: everyone keeps one slot and
        // races for the global bound (the documented exception).
        assert_eq!(skewed.queue_caps(2), vec![1, 1, 1]);
        // Closed endpoint stays closed for all.
        assert_eq!(plane.queue_caps(0), vec![0, 0]);
        // Single project owns the whole queue.
        let single = ControlPlane::single(spec("solo"));
        assert_eq!(single.queue_caps(64), vec![64]);
        assert_eq!(single.total_weight(), 1.0);
    }

    #[test]
    fn nonpositive_weights_clamp_to_servable() {
        let mut plane = ControlPlane::new();
        let a = plane.register(spec("a"), 0.0);
        let b = plane.register(spec("b"), -2.0);
        assert!(plane.weight(a) > 0.0);
        assert!(plane.weight(b) > 0.0);
        // Both stay admittable under any depth.
        assert!(plane.queue_caps(16).iter().all(|&c| c >= 1));
    }
}
