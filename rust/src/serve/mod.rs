//! Prediction serving — MLitB's second pillar, as a simulated subsystem.
//!
//! The paper's goal is not only distributed *training* but bringing
//! "sophisticated machine learning … **and prediction** to the public at
//! large": trained models are saved in universally readable formats
//! (research closures, §2.3/§3.6) and downloaded by any device for
//! inference.  Where `coordinator`/`sim` reproduce the training side,
//! this module opens the inference-under-load workload the ROADMAP's
//! "heavy traffic from millions of users" north star demands — for the
//! paper's §3.1 *multi-tenant* master: one serving tier hosts several
//! projects, each with its own model, fleet and publication policy:
//!
//! * [`ControlPlane`] — the multi-project ownership root: mints typed
//!   [`ProjectId`]s, owns one registry (and a fair-share weight) per
//!   project, and derives the weighted per-project admission caps a hot
//!   project cannot starve a cold one through.  [`ModelVersion`] —
//!   `(project, version)` — replaces bare `u64` snapshot ids end-to-end.
//! * [`SnapshotRegistry`] — one project's versioned parameter snapshots
//!   ingested from research closures or live training masters, with
//!   staged (transfer-in-flight) publication, activation/rollback and
//!   retention GC.
//! * [`AdmissionQueue`] + [`BatchPolicy`] — bounded admission and
//!   deadline-bounded micro-batching (flush on full batch or oldest-wait
//!   deadline), the serving latency/throughput dial.
//! * [`PredictionCache`] — LRU over (snapshot, input) exact-match keys;
//!   hits skip the queue entirely.
//! * [`BatchExecutor`] — pads flushed batches to the compiled micro-batch
//!   variants and runs them through [`crate::runtime::Compute`];
//!   per-example purity guarantees batching never changes a prediction.
//! * [`RequestFleet`] — open-loop Poisson request generators over
//!   heterogeneous `netsim` link profiles (Lan/Wifi/Cellular).
//! * [`RoutingPolicy`] + [`RouterConfig`] — N replicated shard endpoints
//!   (each its own queue + executor + cache; profiles may be mixed)
//!   behind round-robin, join-shortest-queue (weighing outstanding work
//!   in estimated *milliseconds*) or input-key-affinity routing, with
//!   in-flight request coalescing (duplicates dedupe before admission;
//!   one computation, one cache fill, the answer fanned out to every
//!   waiter), router-level failover (a refused arrival re-offers to the
//!   other shards; shed only when all refuse) and per-shard batching
//!   autotune (`max_wait_ms` *and* `max_batch` re-derived from the
//!   observed admission rate, the flush size snapped to a compiled
//!   `predict_b{n}` variant).
//! * [`ServeEngine`] + [`ServeSim`] — the discrete-event loop binding the
//!   above over the control plane.  The engine is incrementally pumpable
//!   to a virtual-time horizon (what [`crate::cosim`] interleaves with
//!   training iterations; requests are stamped with their project's
//!   active [`ModelVersion`] at arrival, batches never mix versions —
//!   and therefore never mix projects — and admitted requests hold
//!   registry reader pins so GC can't evict a version with in-flight
//!   work); `ServeSim` wraps it for serving-only runs and emits a
//!   [`ServeReport`] with per-request latency percentiles, throughput,
//!   shed attribution, per-shard and per-project stats via `metrics`.
//!
//! Entry points: the `mlitb serve-sim` and `mlitb cosim` CLI subcommands,
//! `benches/fig_serving.rs` (throughput/latency vs offered load),
//! `benches/fig_routing.rs` (shards × routing policy × rate),
//! `benches/fig_cosim.rs` (staleness vs latency), and
//! `examples/serving.rs`.

mod cache;
mod control;
mod executor;
mod loadgen;
mod queue;
mod registry;
mod router;
mod sim;

pub use cache::{input_key, PredictionCache};
pub use control::{ControlPlane, ModelVersion, ProjectId, ProjectStats};
pub use executor::{BatchExecutor, Prediction, ServerProfile};
pub use loadgen::{ClientSpec, FleetConfig, RequestEvent, RequestFleet};
pub use queue::{AdmissionQueue, BatchPolicy, PredictRequest};
pub use registry::{RegistryState, Snapshot, SnapshotMeta, SnapshotRegistry, SnapshotRow};
pub use router::{
    failover_order, tuned_max_batch, tuned_wait_ms, RateWindow, RouterConfig, RoutingPolicy,
    Shard, ShardStats,
};
pub use sim::{NoopObserver, ServeConfig, ServeEngine, ServeObserver, ServeReport, ServeSim};

use crate::model::{ModelSpec, TensorSpec};

/// A manifest-free MNIST-shaped MLP spec (784→16→10) so serving demos,
/// benches and the CLI run end-to-end without compiled AOT artifacts —
/// predictions then come from `ModeledCompute`'s deterministic scorer.
pub fn demo_spec() -> ModelSpec {
    let tensors = vec![
        TensorSpec {
            name: "l0_fc_w".into(),
            shape: vec![784, 16],
            offset: 0,
            size: 12_544,
            fan_in: 784,
        },
        TensorSpec {
            name: "l0_fc_b".into(),
            shape: vec![16],
            offset: 12_544,
            size: 16,
            fan_in: 784,
        },
        TensorSpec {
            name: "l1_fc_w".into(),
            shape: vec![16, 10],
            offset: 12_560,
            size: 160,
            fan_in: 16,
        },
        TensorSpec {
            name: "l1_fc_b".into(),
            shape: vec![10],
            offset: 12_720,
            size: 10,
            fan_in: 16,
        },
    ];
    ModelSpec {
        name: "demo_mlp".into(),
        param_count: 12_730,
        batch_size: 32,
        micro_batches: vec![32, 8, 1],
        input: vec![28, 28, 1],
        classes: 10,
        tensors,
        artifacts: Default::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_spec_is_structurally_valid() {
        let spec = demo_spec();
        assert_eq!(spec.input_len(), 784);
        let sum: usize = spec.tensors.iter().map(|t| t.size).sum();
        assert_eq!(sum, spec.param_count);
        let mut offset = 0;
        for t in &spec.tensors {
            assert_eq!(t.offset, offset, "tensor {} offset gap", t.name);
            offset += t.size;
        }
        // init_params works on it (biases stay zero).
        let params = crate::model::init_params(&spec, 1);
        assert_eq!(params.len(), spec.param_count);
        assert!(params[12_544..12_560].iter().all(|&b| b == 0.0));
    }
}
