//! Prediction LRU cache.
//!
//! "Prediction to the public at large" traffic has heavy-hitter inputs:
//! the same image is submitted by many clients (and retried by the same
//! one).  A hit skips admission, batching and execution entirely and is
//! served at lookup cost.  Keys are exact-match: FNV-1a over the typed
//! [`ModelVersion`] (project **and** version) and the input's f32 bit
//! pattern — a new snapshot version invalidates the whole cache by
//! construction, with no epoch bookkeeping, and two projects can never
//! collide on a shared shard cache even for identical inputs.  Hashing
//! alone is not trusted: each entry keeps its input (a shared handle, not
//! a copy) and a hit compares it, so a 64-bit collision degrades to a
//! miss instead of silently serving another input's answer.

use std::collections::HashMap;
use std::sync::Arc;

use super::control::ModelVersion;
use super::executor::Prediction;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Cache key for (version, input): FNV-1a over the project id, the
/// version number and the pixel bit patterns (exact match; no float
/// tolerance).
pub fn input_key(version: ModelVersion, pixels: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for b in version.project.as_u32().to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for b in version.version.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for px in pixels {
        for b in px.to_bits().to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

#[derive(Debug, Clone)]
struct Entry {
    /// The exact input this prediction answers (collision guard).
    input: Arc<Vec<f32>>,
    prediction: Prediction,
    last_used: u64,
}

/// Entry-capacity-bounded LRU of served predictions.
///
/// A `tick → key` recency index rides alongside the entry map so
/// eviction picks the LRU victim in O(log n) instead of scanning the
/// whole map — the cache sits on the serving hot path and the load
/// sweeps insert tens of thousands of entries per run.
#[derive(Debug, Clone)]
pub struct PredictionCache {
    capacity: usize,
    /// Determinism audit: point access only — eviction order comes from
    /// the ordered `recency` index below, never from map iteration.
    entries: HashMap<u64, Entry>,
    /// last_used tick → key (ticks are unique; first entry is the LRU).
    recency: std::collections::BTreeMap<u64, u64>,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl PredictionCache {
    /// `capacity` in entries; 0 disables caching (every get misses).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            entries: HashMap::new(),
            recency: std::collections::BTreeMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up a key for `input`, refreshing recency and counting
    /// hit/miss.  A key match with a different stored input (64-bit hash
    /// collision) is a miss.
    pub fn get(&mut self, key: u64, input: &[f32]) -> Option<Prediction> {
        self.tick += 1;
        let tick = self.tick;
        match self.entries.get_mut(&key) {
            Some(e) if e.input.as_slice() == input => {
                self.recency.remove(&e.last_used);
                e.last_used = tick;
                self.recency.insert(tick, key);
                self.hits += 1;
                Some(e.prediction.clone())
            }
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a served prediction, evicting LRU entries beyond capacity.
    pub fn insert(&mut self, key: u64, input: Arc<Vec<f32>>, prediction: Prediction) {
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        if let Some(prev) = self.entries.insert(
            key,
            Entry {
                input,
                prediction,
                last_used: self.tick,
            },
        ) {
            self.recency.remove(&prev.last_used);
        }
        self.recency.insert(self.tick, key);
        while self.entries.len() > self.capacity {
            let Some((&lru_tick, &victim)) = self.recency.iter().next() else {
                break;
            };
            self.recency.remove(&lru_tick);
            self.entries.remove(&victim);
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Hit fraction of all lookups (0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    /// Fill fraction of the configured capacity (0 when caching is
    /// disabled) — the `serve/cache` counter's `size` companion.
    pub fn occupancy(&self) -> f64 {
        if self.capacity == 0 {
            return 0.0;
        }
        self.entries.len() as f64 / self.capacity as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pred(class: usize) -> Prediction {
        Prediction {
            class,
            confidence: 0.9,
            probs: vec![0.1, 0.9],
        }
    }

    fn input(v: f32) -> Arc<Vec<f32>> {
        Arc::new(vec![v])
    }

    fn v(project: u32, version: u64) -> ModelVersion {
        ModelVersion {
            project: crate::serve::ProjectId::new(project),
            version,
        }
    }

    #[test]
    fn key_is_exact_and_version_scoped() {
        let a = input_key(v(0, 1), &[0.1, 0.2]);
        assert_eq!(a, input_key(v(0, 1), &[0.1, 0.2]));
        assert_ne!(a, input_key(v(0, 2), &[0.1, 0.2]), "new snapshot, new keyspace");
        assert_ne!(
            a,
            input_key(v(1, 1), &[0.1, 0.2]),
            "same version number, other project: distinct keyspace"
        );
        assert_ne!(a, input_key(v(0, 1), &[0.2, 0.1]), "order matters");
        // -0.0 and 0.0 have different bit patterns: exact-match semantics.
        assert_ne!(input_key(v(0, 1), &[0.0]), input_key(v(0, 1), &[-0.0]));
    }

    #[test]
    fn get_insert_roundtrip_counts() {
        let mut c = PredictionCache::new(4);
        let k = input_key(1, &[0.5]);
        assert!(c.get(k, &[0.5]).is_none());
        c.insert(k, Arc::new(vec![0.5]), pred(3));
        assert_eq!(c.get(k, &[0.5]).unwrap().class, 3);
        assert_eq!((c.hits(), c.misses()), (1, 1));
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn hash_collision_degrades_to_miss() {
        // Same key, different input bits: the stored-input comparison must
        // refuse to serve the other input's answer.
        let mut c = PredictionCache::new(4);
        c.insert(42, input(1.0), pred(3));
        assert!(c.get(42, &[2.0]).is_none(), "collision must miss");
        assert_eq!(c.get(42, &[1.0]).unwrap().class, 3);
    }

    #[test]
    fn evicts_lru_beyond_capacity() {
        let mut c = PredictionCache::new(2);
        c.insert(1, input(1.0), pred(1));
        c.insert(2, input(2.0), pred(2));
        c.get(1, &[1.0]); // refresh 1 → 2 becomes LRU
        c.insert(3, input(3.0), pred(3));
        assert_eq!(c.len(), 2);
        assert!(c.get(2, &[2.0]).is_none(), "LRU entry should be evicted");
        assert!(c.get(1, &[1.0]).is_some());
        assert!(c.get(3, &[3.0]).is_some());
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = PredictionCache::new(0);
        c.insert(1, input(1.0), pred(1));
        assert!(c.is_empty());
        assert!(c.get(1, &[1.0]).is_none());
        assert_eq!(c.hit_rate(), 0.0);
    }

    #[test]
    fn reinsert_updates_value() {
        let mut c = PredictionCache::new(2);
        c.insert(1, input(1.0), pred(1));
        c.insert(1, input(1.0), pred(7));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(1, &[1.0]).unwrap().class, 7);
    }

    #[test]
    fn recency_index_survives_churn() {
        // Interleave inserts, refreshes and reinserts well past capacity:
        // the recency index and entry map must stay in lockstep.
        let mut c = PredictionCache::new(3);
        for k in 0..50u64 {
            c.insert(k, input(k as f32), pred(k as usize));
            let probe = k.saturating_sub(1);
            c.get(probe, &[probe as f32]);
            c.insert(k / 2, input((k / 2) as f32), pred(99));
        }
        assert_eq!(c.len(), 3);
        c.insert(100, input(100.0), pred(1));
        assert_eq!(c.len(), 3);
        assert!(
            c.get(100, &[100.0]).is_some(),
            "most recent insert must be resident"
        );
    }
}
