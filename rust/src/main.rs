//! `mlitb` — leader entrypoint for the MLitB reproduction.
//!
//! Subcommands:
//!   train        run a distributed-SGD training simulation (real gradients)
//!   scale        run the Fig-4 style coordination sweep (modeled compute)
//!   serve-sim    run a prediction-serving simulation under request load
//!   cosim        co-simulate training + serving on one shared clock
//!   trace-report analyze an exported trace CSV: flame rollup, critical
//!                paths, counter stats, saturation verdicts
//!   inspect      print manifest/model info
//!   closure      save/load round-trip check on a research closure
//!   lint         run the determinism static analyzer over Rust sources
//!
//! Example:
//!   mlitb train --model mnist_conv --nodes 4 --iters 50 --track-every 10
//!   mlitb serve-sim --clients 16 --rate 8 --duration 20 --link mixed
//!   mlitb cosim --publish-every 5 --shards 2
//!   mlitb cosim --trace cosim_trace.json --report   # timeline + rollup
//!   mlitb trace-report cosim_trace.json.csv         # analyze later

use mlitb::cli::Args;
use mlitb::client::DeviceClass;
use mlitb::coordinator::ReducePolicy;
use mlitb::cosim::{
    run_cosim_durable, CosimConfig, CosimDurability, CosimProject, PublicationPolicy,
};
use mlitb::faults::FaultProfile;
use mlitb::model::{init_params, Manifest, ModelSpec, ResearchClosure};
use mlitb::netsim::{LinkProfile, ReduceMode};
use mlitb::params::{AggregationMode, OptimizerKind};
use mlitb::runtime::{Compute, DriftingCompute, Engine, ModeledCompute};
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, ControlPlane, FleetConfig, ProjectId, RouterConfig,
    RoutingPolicy, ServeConfig, ServeReport, ServeSim, ServerProfile,
};
use mlitb::sim::{RunReport, SimConfig, Simulation};
use mlitb::storage::{digest_f32s, recover, RecoverMode, RunStore};
use mlitb::trace::TraceHandle;

fn main() {
    let args = Args::from_env();
    let cmd = args
        .positional()
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "recover" => cmd_recover(&args),
        "scale" => cmd_scale(&args),
        "serve-sim" => cmd_serve_sim(&args),
        "cosim" => cmd_cosim(&args),
        "trace-report" => cmd_trace_report(&args),
        "inspect" => cmd_inspect(&args),
        "closure" => cmd_closure(&args),
        "lint" => cmd_lint(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "mlitb {} — Machine Learning in the Browser, reproduced in Rust+JAX\n\n\
         USAGE: mlitb <train|recover|scale|serve-sim|cosim|trace-report|inspect|closure|lint> [options]\n\n\
         train:   --model <name> --nodes N --iters N --t-secs F --lr F\n\
                  --optimizer sgd|momentum|adagrad|rmsprop --policy sync|async|partial:<f>\n\
                  --track-every N --train-size N --test-size N --power-scale F\n\
                  --capacity N --seed N --save-closure <path> --csv <path>\n\
                  --master-processes N --reduce-mode message|sharded|sharded:<S>\n\
                  --merge-ns F --fanin-ns F  (reduce calibration overrides)\n\
                  --data-dir <dir> --checkpoint-every N --resume\n\
                  --kill-at N  (durable WAL+checkpoints; fault injection)\n\
                  --fault-profile none|flaky|storm|hostile:<f>[:<mode>]|mixed:<f>\n\
                  (mode: nan|inf|scaled:<k>|sign-flip — seeded adversity)\n\
                  --aggregation mean|trimmed:<k>|median|clip:<c> --quorum F\n\
                  --trace <path>  (Perfetto trace-event JSON + <path>.csv)\n\
                  --report  (print flame/critical-path rollup after the run)\n\
                  --trace-capacity N  (trace ring size in events)\n\
         recover: --data-dir <dir> [--verify] + the run's train flags\n\
                  (rebuilds the world, loads the newest checkpoint, replays\n\
                  the WAL; --verify only checks, never repairs a torn tail)\n\
         scale:   --nodes-list 1,2,4,...  --iters N  (modeled compute)\n\
                  --reduce-mode message|sharded:<S> --merge-ns F --fanin-ns F\n\
         serve-sim: --model <name> --closure <path> --clients N --rate F\n\
                  --duration F --link lan|wifi|cellular|mixed --batch N\n\
                  --max-wait F --queue-depth N --cache N --input-pool N\n\
                  --shards N --router rr|jsq|affinity --no-coalesce\n\
                  --autotune --jitter F --seed N --csv <path> --trace <path>\n\
                  --report --trace-capacity N\n\
         cosim:   --model <name> --projects N --nodes N --iters N --t-secs F\n\
                  --track-every N --train-size N --test-size N --publish-every K\n\
                  --publish-delta F --publish-hysteresis M --egress-mb-min F\n\
                  --retain N --no-delta --clients N --rate F --hot-rate F\n\
                  --link <profile> --shards N --router rr|jsq|affinity --batch N\n\
                  --queue-depth N --cache N --input-pool N --seed N --csv <path>\n\
                  --data-dir <dir> --checkpoint-every N --resume --kill-at N\n\
                  --kill-mid  (with --kill-at: die mid-window, between pumps)\n\
                  --fault-profile <p> --aggregation <m> --quorum F  (as train)\n\
                  --trace <path>  (spans from all three planes on one timeline)\n\
                  --report --trace-capacity N\n\
         trace-report: <trace.json.csv> [--json <path>]  (flame rollup,\n\
                  critical paths, counter stats, saturation verdicts)\n\
         inspect: [--model <name>]\n\
         closure: --model <name> --out <path>\n\
         lint:    [paths...]  (default rust/src; exits 1 on any\n\
                  unsuppressed determinism finding — see DESIGN.md)",
        mlitb::VERSION
    );
}

/// Recording handle when `--trace <path>` or `--report` was given, no-op
/// handle otherwise (the disabled path costs one `Option` check per
/// event).  `--trace-capacity` sizes the ring buffer.
fn trace_for(args: &Args) -> Result<TraceHandle, String> {
    if args.get("trace").is_some() || args.flag("report") {
        let capacity = args.get_usize("trace-capacity", mlitb::trace::DEFAULT_CAPACITY)?;
        Ok(TraceHandle::with_capacity(capacity.max(1)))
    } else {
        Ok(TraceHandle::off())
    }
}

/// Post-run trace handling: surface ring-buffer drops (a truncated trace
/// must never look complete), write the exports where `--trace` pointed
/// (Perfetto JSON at the path, flat CSV at `<path>.csv`), and print the
/// analyzer rollup when `--report` asked for it.
fn finish_trace(args: &Args, trace: &TraceHandle) -> Result<(), String> {
    if trace.dropped() > 0 {
        let needed = trace.len() as u64 + trace.dropped();
        eprintln!(
            "warning: trace ring dropped {} oldest event(s) — the export is a suffix \
             window; rerun with --trace-capacity {needed} for the full timeline",
            trace.dropped()
        );
    }
    if let Some(path) = args.get("trace") {
        trace.write(path)?;
        println!("wrote trace to {path} (Perfetto JSON; CSV at {path}.csv)");
    }
    if args.flag("report") {
        let analysis = mlitb::trace::analyze::TraceAnalysis::from_events(&trace.snapshot());
        print!("{}", mlitb::trace::report::render_text(&analysis));
    }
    Ok(())
}

/// `mlitb trace-report <trace.json.csv>` — analyze a previously exported
/// trace CSV: flame rollup, per-iteration and per-request critical paths,
/// counter statistics, saturation verdicts.
fn cmd_trace_report(args: &Args) -> Result<(), String> {
    let positional = args.positional();
    let Some(path) = positional.get(1) else {
        return Err("usage: mlitb trace-report <trace.json.csv> [--json <path>]".into());
    };
    let csv = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    if csv.starts_with('{') {
        return Err(format!(
            "{path} looks like the Perfetto JSON export — pass the CSV beside it \
             (<trace>.csv)"
        ));
    }
    let analysis = mlitb::trace::analyze::TraceAnalysis::from_csv(&csv)
        .map_err(|e| format!("analyze {path}: {e}"))?;
    print!("{}", mlitb::trace::report::render_text(&analysis));
    if let Some(json_path) = args.get("json") {
        std::fs::write(json_path, mlitb::trace::report::render_json(&analysis))
            .map_err(|e| format!("write {json_path}: {e}"))?;
        println!("wrote JSON report to {json_path}");
    }
    Ok(())
}

fn build_sim_config(args: &Args, spec: &mlitb::model::ModelSpec) -> Result<SimConfig, String> {
    let nodes = args.get_usize("nodes", 4)?;
    let mut cfg = SimConfig::paper_scaling(nodes, spec);
    cfg.iterations = args.get_u64("iters", 50)?;
    cfg.train_size = args.get_usize("train-size", 60_000)?;
    cfg.test_size = args.get_usize("test-size", 2_000)?;
    cfg.track_every = args.get_u64("track-every", 0)?;
    cfg.power_scale = args.get_f64("power-scale", 1.0)?;
    cfg.seed = args.get_u64("seed", 1)?;
    cfg.master.iter_duration_s = args.get_f64("t-secs", 4.0)?;
    cfg.master.learning_rate = args.get_f64("lr", 0.01)? as f32;
    cfg.master.capacity = args.get_usize("capacity", 3000)?;
    cfg.master.optimizer = OptimizerKind::parse(args.get_or("optimizer", "adagrad"))?;
    cfg.master.policy = ReducePolicy::parse(args.get_or("policy", "sync"))?;
    cfg.master.master_model.processes = args.get_usize("master-processes", 1)?;
    cfg.master.master_model.reduce_mode = ReduceMode::parse(args.get_or("reduce-mode", "message"))?;
    // Calibration overrides: paste the ns/param the reduce micro-bench
    // measured on this machine (`cargo bench --bench micro -- --reduce-only`).
    cfg.master.master_model.merge_ns_per_param =
        args.get_f64("merge-ns", cfg.master.master_model.merge_ns_per_param)?;
    cfg.master.master_model.fanin_ns_per_shard =
        args.get_f64("fanin-ns", cfg.master.master_model.fanin_ns_per_shard)?;
    // Robustness plane: seeded adversity and the defenses against it.
    cfg.faults = FaultProfile::parse(args.get_or("fault-profile", "none"))?;
    cfg.master.aggregation = AggregationMode::parse(args.get_or("aggregation", "mean"))?;
    cfg.master.quorum = args.get_f64("quorum", 0.0)?;
    let device = DeviceClass::parse(args.get_or("device", "workstation"))?;
    cfg.fleet = vec![device; nodes];
    Ok(cfg)
}

/// Training compute backend: the PJRT engine over AOT artifacts when both
/// exist, else the deterministic drifting scorer over the built-in demo
/// spec — parameters still move, so durable training and crash-recovery
/// drills run anywhere (only gradient realism needs the artifacts).
enum TrainCompute {
    Engine(Box<Engine>),
    Drifting(DriftingCompute),
}

impl TrainCompute {
    fn as_dyn(&mut self) -> &mut dyn Compute {
        match self {
            TrainCompute::Engine(e) => e.as_mut(),
            TrainCompute::Drifting(d) => d,
        }
    }
}

fn train_backend(args: &Args) -> Result<(ModelSpec, TrainCompute), String> {
    if cfg!(feature = "pjrt") && manifest_on_disk().is_some() {
        let model = args.get_or("model", "mnist_conv").to_string();
        let mut engine = Engine::from_default_artifacts().map_err(|e| e.to_string())?;
        engine.load_model(&model).map_err(|e| e.to_string())?;
        let spec = engine.spec(&model).map_err(|e| e.to_string())?.clone();
        Ok((spec, TrainCompute::Engine(Box::new(engine))))
    } else {
        let spec = demo_spec();
        let param_count = spec.param_count;
        println!(
            "note: no PJRT artifacts — training the built-in '{}' spec on the \
             deterministic drifting backend",
            spec.name
        );
        Ok((spec, TrainCompute::Drifting(DriftingCompute { param_count })))
    }
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let (spec, mut backend) = train_backend(args)?;
    let cfg = build_sim_config(args, &spec)?;
    println!(
        "training {}: {} nodes, {} iters, T={}s, {} params, policy={}",
        spec.name,
        cfg.fleet.len(),
        cfg.iterations,
        cfg.master.iter_duration_s,
        spec.param_count,
        cfg.master.policy.name()
    );
    let trace = trace_for(args)?;
    let checkpoint_every = args.get_u64("checkpoint-every", 25)?;
    let kill_at = args.get_u64("kill-at", 0)?;
    let resume = args.flag("resume");
    let total = cfg.iterations;
    let store = match args.get("data-dir") {
        Some(dir) => Some(
            RunStore::open_for_config(std::path::Path::new(dir), &cfg)
                .map_err(|e| e.to_string())?,
        ),
        None => None,
    };
    let mut sim = Simulation::new(cfg, spec.clone(), backend.as_dyn());
    sim.set_trace(trace.clone(), 0);
    let report = if let Some(store) = &store {
        run_train_durable(store, &mut sim, total, checkpoint_every, kill_at, resume, &trace)?
    } else {
        sim.run().map_err(|e| e.to_string())?
    };
    finish_trace(args, &trace)?;
    for r in report.timeline.records() {
        if r.iteration % 10 == 0 || r.test_error.is_some() {
            println!(
                "  iter {:>4}  loss={}  vectors={}  latency={:.1} ms{}",
                r.iteration,
                r.loss.map_or("-".into(), |l| format!("{l:.4}")),
                r.vectors,
                r.mean_latency_ms,
                r.test_error
                    .map_or(String::new(), |e| format!("  test_err={e:.4}"))
            );
        }
    }
    println!("done: {}", report.summary());
    if let Some(path) = args.get("csv") {
        std::fs::write(path, report.timeline.to_csv()).map_err(|e| e.to_string())?;
        println!("wrote timeline to {path}");
    }
    if let Some(path) = args.get("save-closure") {
        let mut closure = ResearchClosure::new(&spec, sim.master().params());
        closure.iteration = sim.master().iteration();
        closure.optimizer = sim.master().config().optimizer_name();
        closure.learning_rate = sim.master().config().learning_rate;
        closure.iter_duration_s = sim.master().config().iter_duration_s;
        closure.notes = format!("mlitb train, {} nodes", report.workers);
        closure.save(std::path::Path::new(path))?;
        println!("saved research closure to {path}");
    }
    Ok(())
}

/// The durable training loop: WAL every iteration (buffered append),
/// checkpoint + fsync at the cadence, `DIGEST` written at completion so
/// crash-recovery drills can compare runs bitwise.  `--kill-at N` dies
/// *without* flushing — exactly what a crash leaves behind.
fn run_train_durable(
    store: &RunStore,
    sim: &mut Simulation<'_>,
    total: u64,
    checkpoint_every: u64,
    kill_at: u64,
    resume: bool,
    trace: &TraceHandle,
) -> Result<RunReport, String> {
    let start = if resume {
        let rec = recover(sim, store, RecoverMode::Resume, trace, 0).map_err(|e| e.to_string())?;
        println!("recovery: {}", rec.summary());
        rec.tip
    } else {
        if store.wal_path().exists() {
            return Err(format!(
                "{} already holds a run — pass --resume to continue it, or point \
                 --data-dir elsewhere",
                store.dir().display()
            ));
        }
        0
    };
    let wal = store.open_wal_for_append().map_err(|e| e.to_string())?;
    sim.master_mut().attach_wal(wal, store.identity().seed);
    for done in start..total {
        sim.step().map_err(|e| e.to_string())?;
        let iteration = done + 1;
        if kill_at > 0 && iteration >= kill_at {
            eprintln!(
                "fault injection: killed at iteration {iteration} ({} holds the crash state)",
                store.dir().display()
            );
            // No destructors: buffered WAL records since the last
            // checkpoint sync are lost, as in a real crash.
            std::process::exit(3);
        }
        if checkpoint_every > 0 && iteration % checkpoint_every == 0 {
            store
                .write_checkpoint(&sim.capture_state())
                .map_err(|e| e.to_string())?;
            if let Some(w) = sim.master_mut().wal_mut() {
                w.sync().map_err(|e| e.to_string())?;
            }
        }
    }
    if let Some(w) = sim.master_mut().wal_mut() {
        w.sync().map_err(|e| e.to_string())?;
    }
    let digest = digest_f32s(sim.master().params());
    let line = format!("{digest:016x} iteration {}\n", sim.master().iteration());
    std::fs::write(store.dir().join("DIGEST"), &line).map_err(|e| e.to_string())?;
    println!(
        "params digest {digest:016x} at iteration {} (DIGEST in {})",
        sim.master().iteration(),
        store.dir().display()
    );
    Ok(RunReport::from_timeline(
        sim.master().timeline().clone(),
        sim.n_clients(),
    ))
}

/// `mlitb recover --data-dir <dir> [--verify]` — rebuild the run's world
/// from the same train flags, load the newest valid checkpoint and replay
/// the WAL through the deterministic step path, verifying every replayed
/// iteration's digests.  `--verify` never mutates the data dir (a torn
/// tail is reported, not repaired) and exits nonzero on any mismatch.
fn cmd_recover(args: &Args) -> Result<(), String> {
    let dir = args
        .get("data-dir")
        .ok_or("recover needs --data-dir <dir>")?
        .to_string();
    let (spec, mut backend) = train_backend(args)?;
    let cfg = build_sim_config(args, &spec)?;
    let store = RunStore::open_for_config(std::path::Path::new(&dir), &cfg)
        .map_err(|e| e.to_string())?;
    let mode = if args.flag("verify") {
        RecoverMode::Verify
    } else {
        RecoverMode::Resume
    };
    let mut sim = Simulation::new(cfg, spec, backend.as_dyn());
    let report = recover(&mut sim, &store, mode, &TraceHandle::off(), 0)
        .map_err(|e| e.to_string())?;
    println!("{}", report.summary());
    println!(
        "params digest {:016x} at iteration {}",
        digest_f32s(sim.master().params()),
        sim.master().iteration()
    );
    Ok(())
}

fn cmd_scale(args: &Args) -> Result<(), String> {
    let model = args.get_or("model", "mnist_conv").to_string();
    let manifest = Manifest::load_default()?;
    let spec = manifest.model(&model)?.clone();
    let nodes_list = args.get_usize_list("nodes-list", &[1, 2, 4, 8, 16, 32, 64, 96])?;
    let iters = args.get_u64("iters", 20)?;
    let reduce_mode = ReduceMode::parse(args.get_or("reduce-mode", "message"))?;
    let mut table = mlitb::metrics::Table::new(
        &format!("scaling (modeled compute, reduce={})", reduce_mode.name()),
        &["nodes", "power vec/s", "latency ms", "wall s/iter"],
    );
    for &n in &nodes_list {
        let mut cfg = SimConfig::paper_scaling(n, &spec);
        cfg.iterations = iters;
        cfg.seed = args.get_u64("seed", 1)?;
        cfg.master.master_model.reduce_mode = reduce_mode;
        cfg.master.master_model.merge_ns_per_param =
            args.get_f64("merge-ns", cfg.master.master_model.merge_ns_per_param)?;
        cfg.master.master_model.fanin_ns_per_shard =
            args.get_f64("fanin-ns", cfg.master.master_model.fanin_ns_per_shard)?;
        let mut compute = ModeledCompute {
            param_count: spec.param_count,
        };
        let mut sim = Simulation::new(cfg, spec.clone(), &mut compute);
        let report = sim.run().map_err(|e| e.to_string())?;
        table.row(vec![
            n.to_string(),
            format!("{:.0}", report.power_vps),
            format!("{:.1}", report.mean_latency_ms),
            format!("{:.2}", report.virtual_secs / iters as f64),
        ]);
    }
    table.print();
    Ok(())
}

/// Request-fleet client groups for one link-profile argument (`mixed`
/// splits the fleet across lan/wifi/cellular like the paper's volunteer
/// population; anything else is a homogeneous group).
fn client_groups(link: &str, clients: usize, rate: f64) -> Result<Vec<ClientSpec>, String> {
    Ok(match link {
        "mixed" => {
            let lan = clients / 3;
            let wifi = clients / 3;
            let cellular = clients - lan - wifi;
            vec![
                ClientSpec { link: LinkProfile::Lan, rate_rps: rate, count: lan },
                ClientSpec { link: LinkProfile::Wifi, rate_rps: rate, count: wifi },
                ClientSpec { link: LinkProfile::Cellular, rate_rps: rate, count: cellular },
            ]
        }
        other => vec![ClientSpec {
            link: LinkProfile::parse(other)?,
            rate_rps: rate,
            count: clients,
        }],
    })
}

/// Artifacts manifest path, if one exists on disk.
fn manifest_on_disk() -> Option<std::path::PathBuf> {
    let dir = std::env::var("MLITB_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let path = std::path::Path::new(&dir).join("manifest.json");
    path.exists().then_some(path)
}

/// Serving model spec: the manifest's entry when artifacts exist, else the
/// built-in demo spec (serving runs anywhere; only predictions' realism
/// depends on the PJRT artifacts).  Only a *missing* manifest falls back —
/// a present-but-broken one is a real error the user must see.
fn serve_spec(args: &Args) -> Result<ModelSpec, String> {
    let Some(manifest_path) = manifest_on_disk() else {
        let spec = demo_spec();
        println!(
            "note: no artifacts manifest on disk — using the built-in '{}' spec",
            spec.name
        );
        return Ok(spec);
    };
    let dir = manifest_path.parent().expect("manifest path has a parent");
    let manifest = Manifest::load(dir)?;
    let model = args.get_or("model", "mnist_conv");
    manifest.model(model).map(Clone::clone)
}

fn cmd_serve_sim(args: &Args) -> Result<(), String> {
    let spec = serve_spec(args)?;
    let seed = args.get_u64("seed", 1)?;

    // Single-project control plane; the snapshot comes from a saved
    // research closure, or fresh init parameters.
    let mut plane = ControlPlane::single(spec.clone());
    let project = ProjectId::new(0);
    if let Some(path) = args.get("closure") {
        let closure = ResearchClosure::load(std::path::Path::new(path))?;
        let id = plane.registry_mut(project).publish_closure(&closure, 0.0)?;
        println!(
            "published snapshot {id} from {path} (iteration {}, optimizer {})",
            closure.iteration, closure.optimizer
        );
    } else {
        plane
            .registry_mut(project)
            .publish_params(init_params(&spec, seed), 0, "init".into(), 0.0)?;
        println!("published snapshot p0v1 (fresh init parameters, seed {seed})");
    }

    // Request fleet.
    let clients = args.get_usize("clients", 16)?;
    let rate = args.get_f64("rate", 8.0)?;
    let groups = client_groups(args.get_or("link", "mixed"), clients, rate)?;

    let largest = spec
        .micro_batches
        .iter()
        .copied()
        .max()
        .unwrap_or(spec.batch_size);
    let router = RouterConfig {
        shards: args.get_usize("shards", 1)?.max(1),
        policy: RoutingPolicy::parse(args.get_or("router", "jsq"))?,
        // Coalescing duplicate in-flight inputs is the production
        // default; `--no-coalesce` reproduces the PR-1 miss-twice tier.
        coalesce: !args.flag("no-coalesce"),
        autotune: args.flag("autotune"),
        window_ms: 1_000.0,
        fair_share: true,
    };
    let cfg = ServeConfig {
        fleets: vec![FleetConfig {
            groups,
            duration_s: args.get_f64("duration", 20.0)?,
            input_pool: args.get_usize("input-pool", 200)?,
            seed,
        }],
        policy: BatchPolicy {
            max_batch: args.get_usize("batch", largest)?,
            max_wait_ms: args.get_f64("max-wait", 5.0)?,
            queue_depth: args.get_usize("queue-depth", 256)?,
        },
        server: ServerProfile {
            // Straggler spread on batch service times (0 = idealized
            // deterministic server; ~0.5 is a realistic endpoint).
            jitter: args.get_f64("jitter", 0.0)?,
            ..ServerProfile::default()
        },
        router,
        shard_profiles: Vec::new(),
        drained_shards: Vec::new(),
        cache_capacity: args.get_usize("cache", 1024)?,
        response_bytes: 256,
        // Per-request log retention only pays off when someone exports
        // it; percentiles come from the bounded histograms either way.
        keep_log: args.get("csv").is_some(),
    };
    println!(
        "serving {}: {} clients, {:.1} rps each, {}s horizon, batch ≤{}, wait ≤{} ms, \
         {} shard(s) [{}]{}{}",
        spec.name,
        clients,
        rate,
        cfg.fleets[0].duration_s,
        cfg.policy.max_batch,
        cfg.policy.max_wait_ms,
        router.shards,
        router.policy.name(),
        if router.coalesce { ", coalescing" } else { "" },
        if router.autotune { ", autotune" } else { "" },
    );

    // Compute backend.  A PJRT build with artifacts on disk must use them
    // — and must FAIL loudly if they don't compile, rather than silently
    // serving modeled predictions that look plausible but are fake.
    // Without the feature (or without artifacts) the deterministic
    // modeled predictor is the expected configuration.
    let trace = trace_for(args)?;
    let report = if cfg!(feature = "pjrt") && manifest_on_disk().is_some() {
        let mut engine = Engine::from_default_artifacts().map_err(|e| e.to_string())?;
        engine.load_model(&spec.name).map_err(|e| e.to_string())?;
        println!("compute: PJRT engine over AOT artifacts");
        run_serve(cfg, plane, &mut engine, trace.clone())?
    } else {
        let why = if cfg!(feature = "pjrt") {
            "no AOT artifacts on disk"
        } else {
            "built without the `pjrt` feature"
        };
        println!("compute: modeled predictor ({why}; deterministic linear-softmax)");
        let mut modeled = ModeledCompute { param_count: spec.param_count };
        run_serve(cfg, plane, &mut modeled, trace.clone())?
    };
    finish_trace(args, &trace)?;

    let lat = report.latency();
    let mut table = mlitb::metrics::Table::new(
        "serve-sim results",
        &["metric", "value"],
    );
    table.row(vec!["offered requests".into(), report.offered.to_string()]);
    table.row(vec!["completed".into(), report.completed.to_string()]);
    table.row(vec!["rejected (shed)".into(), report.rejected.to_string()]);
    table.row(vec!["shed rate".into(), format!("{:.3}", report.shed_rate())]);
    table.row(vec!["coalesced".into(), report.coalesced.to_string()]);
    table.row(vec!["cache hit rate".into(), format!("{:.3}", report.hit_rate())]);
    table.row(vec!["batches executed".into(), report.batches.to_string()]);
    table.row(vec!["mean batch size".into(), format!("{:.2}", report.mean_batch())]);
    table.row(vec!["throughput (rps)".into(), format!("{:.1}", report.throughput_rps())]);
    // Zero completions (e.g. --queue-depth 0 sheds everything) leave the
    // latency distribution empty — print n/a, not NaN.
    let fmt_ms = |v: f64| if v.is_finite() { format!("{v:.2}") } else { "n/a".into() };
    table.row(vec!["latency p50 (ms)".into(), fmt_ms(lat.median())]);
    table.row(vec!["latency p95 (ms)".into(), fmt_ms(lat.p95())]);
    table.row(vec!["latency p99 (ms)".into(), fmt_ms(lat.quantile(0.99))]);
    table.row(vec!["latency max (ms)".into(), fmt_ms(lat.max())]);
    table.print();

    if report.per_shard.len() > 1 {
        let mut shard_table = mlitb::metrics::Table::new(
            "per-shard stats",
            &[
                "shard", "routed", "completed", "shed", "hits", "coalesced", "batches",
                "mean batch", "batch<=", "wait ms",
            ],
        );
        for s in &report.per_shard {
            shard_table.row(vec![
                s.shard.to_string(),
                s.routed.to_string(),
                s.completed().to_string(),
                s.rejected.to_string(),
                s.cache_hits.to_string(),
                s.coalesced.to_string(),
                s.batches.to_string(),
                format!("{:.1}", s.mean_batch()),
                s.max_batch.to_string(),
                format!("{:.2}", s.max_wait_ms),
            ]);
        }
        shard_table.print();
    }
    println!("done: {}", report.summary());

    if let Some(path) = args.get("csv") {
        std::fs::write(path, report.log.to_csv()).map_err(|e| e.to_string())?;
        println!("wrote request log to {path}");
        // Always written (header-only when nothing shed) so a rerun at a
        // lighter load can't leave a stale shed log beside a fresh CSV.
        let rej_path = format!("{path}.rejections");
        std::fs::write(&rej_path, report.log.rejections_to_csv()).map_err(|e| e.to_string())?;
        println!(
            "wrote shed log to {rej_path} ({} rejections)",
            report.log.rejections().len()
        );
    }
    Ok(())
}

fn run_serve(
    cfg: ServeConfig,
    plane: ControlPlane,
    compute: &mut dyn Compute,
    trace: TraceHandle,
) -> Result<ServeReport, String> {
    ServeSim::new(cfg, plane, compute)
        .run_traced(trace)
        .map_err(|e| e.to_string())
}

/// Co-simulate training and serving on one shared virtual clock: N
/// project masters (`--projects`, §3.1's multi-tenant hosting) publish
/// snapshots mid-traffic (every k iterations and/or on persistent
/// test-error improvement), each publication charges master-egress bytes
/// and activates only when its transfer completes, the router hot-swaps
/// versions with answer-consistency guarantees, and the staleness log
/// correlates each served request with the age of the snapshot that
/// answered it — per project.
fn cmd_cosim(args: &Args) -> Result<(), String> {
    let spec = serve_spec(args)?;
    let seed = args.get_u64("seed", 1)?;
    let iters = args.get_u64("iters", 20)?;
    let nodes = args.get_usize("nodes", 4)?;
    let projects = args.get_usize("projects", 1)?.max(1);

    let mut train = SimConfig::paper_scaling(nodes, &spec);
    train.iterations = iters;
    train.train_size = args.get_usize("train-size", 2_000)?;
    train.test_size = args.get_usize("test-size", 512)?;
    train.track_every = args.get_u64("track-every", 5)?;
    train.power_scale = args.get_f64("power-scale", 1.0)?;
    train.seed = seed;
    train.master.iter_duration_s = args.get_f64("t-secs", 4.0)?;
    train.master.capacity = args.get_usize("capacity", 3000)?;
    train.faults = FaultProfile::parse(args.get_or("fault-profile", "none"))?;
    train.master.aggregation = AggregationMode::parse(args.get_or("aggregation", "mean"))?;
    train.master.quorum = args.get_f64("quorum", 0.0)?;

    let clients = args.get_usize("clients", 8)?;
    let rate = args.get_f64("rate", 4.0)?;
    // Project 0 may run hot (`--hot-rate` per-client rps) while the rest
    // stay at `--rate` — the fair-share demonstration knob.
    let hot_rate = args.get_f64("hot-rate", rate)?;
    let horizon = iters as f64 * train.master.iter_duration_s;
    let largest = spec
        .micro_batches
        .iter()
        .copied()
        .max()
        .unwrap_or(spec.batch_size);
    let publish = PublicationPolicy {
        every: args.get_u64("publish-every", 5)?,
        min_improvement: args.get_f64("publish-delta", 0.0)?,
        hysteresis: args.get_u64("publish-hysteresis", 0)?,
    };
    let retain = args.get_usize("retain", 4)?;
    let link = args.get_or("link", "lan").to_string();
    let duration_s = args.get_f64("duration", horizon)?;
    let input_pool = args.get_usize("input-pool", 200)?;

    let fleets: Result<Vec<FleetConfig>, String> = (0..projects)
        .map(|i| {
            let project_rate = if i == 0 { hot_rate } else { rate };
            Ok(FleetConfig {
                groups: client_groups(&link, clients, project_rate)?,
                duration_s,
                input_pool,
                seed: seed ^ 0xC0517 ^ ((i as u64) << 17),
            })
        })
        .collect();
    let serve = ServeConfig {
        fleets: fleets?,
        policy: BatchPolicy {
            max_batch: args.get_usize("batch", largest)?,
            max_wait_ms: args.get_f64("max-wait", 5.0)?,
            queue_depth: args.get_usize("queue-depth", 256)?,
        },
        server: ServerProfile::default(),
        router: RouterConfig {
            shards: args.get_usize("shards", 2)?.max(1),
            policy: RoutingPolicy::parse(args.get_or("router", "jsq"))?,
            coalesce: !args.flag("no-coalesce"),
            autotune: args.flag("autotune"),
            window_ms: 1_000.0,
            fair_share: !args.flag("no-fair-share"),
        },
        shard_profiles: Vec::new(),
        drained_shards: Vec::new(),
        cache_capacity: args.get_usize("cache", 1024)?,
        response_bytes: 256,
        keep_log: args.get("csv").is_some(),
    };
    let cfg = CosimConfig {
        projects: (0..projects)
            .map(|i| {
                let mut project_train = train.clone();
                // Decorrelate the project masters: same shape, own seed.
                project_train.seed = seed ^ (i as u64).wrapping_mul(0x9E37_79B9);
                CosimProject {
                    spec: spec.clone(),
                    train: project_train,
                    publish,
                    retain,
                    weight: 1.0,
                }
            })
            .collect(),
        serve,
        // Megabytes/min on the CLI; 0 = unthrottled.
        egress_bytes_per_min: args.get_f64("egress-mb-min", 0.0)? * 1.0e6,
        measure_delta: !args.flag("no-delta"),
    };
    println!(
        "cosim {}: {} project(s) × ({} trainer nodes × {} iters, T={}s) + {} request \
         clients/project at {:.1} rps (project 0: {:.1}) over {} shard(s); publish every {} \
         iter(s), delta {} (hysteresis {}), retain {retain}, egress {} MB/min",
        spec.name,
        projects,
        nodes,
        iters,
        train.master.iter_duration_s,
        clients,
        rate,
        hot_rate,
        cfg.serve.router.shards,
        publish.every,
        publish.min_improvement,
        publish.hysteresis,
        if cfg.egress_bytes_per_min > 0.0 {
            format!("{:.1}", cfg.egress_bytes_per_min / 1.0e6)
        } else {
            "∞".into()
        },
    );

    // Training runs on the drifting modeled backend (parameters actually
    // move, so snapshot staleness is measurable); serving and the probe
    // share the deterministic modeled predictor.
    let mut train_computes: Vec<DriftingCompute> = (0..projects)
        .map(|_| DriftingCompute { param_count: spec.param_count })
        .collect();
    let train_refs: Vec<&mut dyn Compute> = train_computes
        .iter_mut()
        .map(|c| c as &mut dyn Compute)
        .collect();
    let mut serve_compute = ModeledCompute { param_count: spec.param_count };
    let trace = trace_for(args)?;
    let checkpoint_every = args.get_u64("checkpoint-every", 25)?;
    let kill_at = args.get_u64("kill-at", 0)?;
    let durability = args.get("data-dir").map(|dir| CosimDurability {
        data_dir: std::path::PathBuf::from(dir),
        checkpoint_every,
        resume: args.flag("resume"),
        kill_at,
        kill_mid: args.flag("kill-mid"),
    });
    let report = run_cosim_durable(
        &cfg,
        durability.as_ref(),
        train_refs,
        &mut serve_compute,
        trace.clone(),
    )
    .map_err(|e| e.to_string())?;
    finish_trace(args, &trace)?;
    if report.replayed.iter().any(|&r| r > 0) {
        for (i, &r) in report.replayed.iter().enumerate() {
            if r > 0 {
                println!("recovery p{i}: replayed {r} iteration(s) from the last checkpoint");
            }
        }
    }

    let mut pub_table = mlitb::metrics::Table::new(
        "publications",
        &[
            "version", "iteration", "t (s)", "trigger", "kb", "active (s)", "act iter",
            "gc evicted",
        ],
    );
    for p in &report.publications {
        pub_table.row(vec![
            p.version.to_string(),
            p.iteration.to_string(),
            format!("{:.1}", p.t_ms / 1000.0),
            p.trigger.name().to_string(),
            format!("{:.1}", p.bytes as f64 / 1000.0),
            format!("{:.1}", p.activated_ms / 1000.0),
            p.activated_iteration.to_string(),
            if p.evicted.is_empty() {
                "-".into()
            } else {
                p.evicted
                    .iter()
                    .map(ToString::to_string)
                    .collect::<Vec<_>>()
                    .join(" ")
            },
        ]);
    }
    pub_table.print();

    let fmt = |v: f64| if v.is_finite() { format!("{v:.2}") } else { "n/a".into() };
    let mut table = mlitb::metrics::Table::new(
        "cosim results — per-project staleness beside latency",
        &["metric", "p50", "p95", "p99", "mean"],
    );
    for project in (0..projects).map(|i| ProjectId::new(i as u32)) {
        let stale = report.staleness.for_project(project);
        let age_iters = stale.age_iters_summary();
        let age_ms = stale.age_ms_summary();
        table.row(vec![
            format!("{project} snapshot age (iters)"),
            fmt(age_iters.median()),
            fmt(age_iters.p95()),
            fmt(age_iters.quantile(0.99)),
            fmt(age_iters.mean()),
        ]);
        table.row(vec![
            format!("{project} snapshot age (ms)"),
            fmt(age_ms.median()),
            fmt(age_ms.p95()),
            fmt(age_ms.quantile(0.99)),
            fmt(age_ms.mean()),
        ]);
        if cfg.measure_delta {
            let delta = stale.delta_summary();
            table.row(vec![
                format!("{project} prediction delta (L1)"),
                fmt(delta.median()),
                fmt(delta.p95()),
                fmt(delta.quantile(0.99)),
                fmt(delta.mean()),
            ]);
        }
    }
    let lat = report.serve.latency();
    table.row(vec![
        "latency, all projects (ms)".into(),
        fmt(lat.median()),
        fmt(lat.p95()),
        fmt(lat.quantile(0.99)),
        fmt(lat.mean()),
    ]);
    table.print();

    let mut per_project = mlitb::metrics::Table::new(
        "per-project serving",
        &["project", "offered", "completed", "shed", "shed rate", "p50 ms"],
    );
    for stats in &report.serve.per_project {
        let lat = &report.serve.latency_by_project[stats.project.index()];
        per_project.row(vec![
            stats.project.to_string(),
            stats.offered.to_string(),
            stats.completed.to_string(),
            stats.rejected.to_string(),
            format!("{:.3}", stats.shed_rate()),
            fmt(lat.median()),
        ]);
    }
    per_project.print();

    let mut counts = mlitb::metrics::Table::new("traffic by version", &["version", "requests"]);
    for (version, n) in report.staleness.by_version() {
        counts.row(vec![version.to_string(), n.to_string()]);
    }
    counts.print();

    if cfg.measure_delta {
        println!(
            "stale-class rate: {:.4} (served argmax the live master would flip)",
            report.staleness.stale_class_rate()
        );
    }
    for (i, train_report) in report.train.iter().enumerate() {
        println!("train p{i}: {}", train_report.summary());
    }
    println!("serve: {}", report.serve.summary());
    println!("done:  {}", report.summary());

    if let Some(path) = args.get("csv") {
        std::fs::write(path, report.staleness.to_csv()).map_err(|e| e.to_string())?;
        println!("wrote staleness log to {path}");
        let req_path = format!("{path}.requests");
        std::fs::write(&req_path, report.serve.log.to_csv()).map_err(|e| e.to_string())?;
        println!("wrote request log to {req_path}");
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<(), String> {
    let manifest = Manifest::load_default()?;
    println!("artifacts: {}", manifest.dir.display());
    for (name, spec) in &manifest.models {
        if let Some(only) = args.get("model") {
            if only != name {
                continue;
            }
        }
        println!(
            "model {name}: {} params, batch {}, input {:?}, {} classes",
            spec.param_count, spec.batch_size, spec.input, spec.classes
        );
        for t in &spec.tensors {
            println!("    {:<16} shape {:?} offset {}", t.name, t.shape, t.offset);
        }
        for (kind, file) in &spec.artifacts {
            println!("    artifact {kind}: {file}");
        }
    }
    Ok(())
}

fn cmd_closure(args: &Args) -> Result<(), String> {
    let model = args.get_or("model", "mnist_conv").to_string();
    let out = args.get_or("out", "/tmp/mlitb_closure.json").to_string();
    let manifest = Manifest::load_default()?;
    let spec = manifest.model(&model)?.clone();
    let params = init_params(&spec, args.get_u64("seed", 1)?);
    let closure = ResearchClosure::new(&spec, &params);
    closure.save(std::path::Path::new(&out))?;
    let back = ResearchClosure::load(std::path::Path::new(&out))?;
    back.check_compatible(&spec)?;
    println!(
        "closure round-trip OK: {} ({} params) -> {out}",
        back.model_name, back.param_count
    );
    Ok(())
}

/// `mlitb lint [paths...]` — run the determinism analyzer and exit
/// nonzero on any unsuppressed finding, so CI can gate on it.
fn cmd_lint(args: &Args) -> Result<(), String> {
    let positional = args.positional();
    let given: Vec<String> = positional[1..].to_vec();
    let paths = if given.is_empty() {
        // Default to the crate sources whichever directory we run from.
        let root = if std::path::Path::new("rust/src").is_dir() {
            "rust/src"
        } else {
            "src"
        };
        vec![root.to_string()]
    } else {
        given
    };
    let mut report = mlitb::analysis::Report::default();
    for p in &paths {
        mlitb::analysis::analyze_tree(std::path::Path::new(p), &mut report)
            .map_err(|e| format!("lint {p}: {e}"))?;
    }
    print!("{}", report.render());
    if report.is_clean() {
        println!(
            "lint: {} path(s) clean ({} suppression(s) carry reasons)",
            paths.len(),
            report.suppressed_count()
        );
        Ok(())
    } else {
        Err(format!("{} unsuppressed determinism finding(s)", report.unsuppressed_count()))
    }
}
