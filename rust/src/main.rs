//! `mlitb` — leader entrypoint for the MLitB reproduction.
//!
//! Subcommands:
//!   train     run a distributed-SGD training simulation (real gradients)
//!   scale     run the Fig-4 style coordination sweep (modeled compute)
//!   inspect   print manifest/model info
//!   closure   save/load round-trip check on a research closure
//!
//! Example:
//!   mlitb train --model mnist_conv --nodes 4 --iters 50 --track-every 10

use mlitb::cli::Args;
use mlitb::client::DeviceClass;
use mlitb::coordinator::ReducePolicy;
use mlitb::model::{init_params, Manifest, ResearchClosure};
use mlitb::params::OptimizerKind;
use mlitb::runtime::{Engine, ModeledCompute};
use mlitb::sim::{SimConfig, Simulation};

fn main() {
    let args = Args::from_env();
    let cmd = args
        .positional()
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "scale" => cmd_scale(&args),
        "inspect" => cmd_inspect(&args),
        "closure" => cmd_closure(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "mlitb {} — Machine Learning in the Browser, reproduced in Rust+JAX\n\n\
         USAGE: mlitb <train|scale|inspect|closure> [options]\n\n\
         train:   --model <name> --nodes N --iters N --t-secs F --lr F\n\
                  --optimizer sgd|momentum|adagrad|rmsprop --policy sync|async|partial:<f>\n\
                  --track-every N --train-size N --test-size N --power-scale F\n\
                  --capacity N --seed N --save-closure <path> --csv <path>\n\
         scale:   --nodes-list 1,2,4,...  --iters N  (modeled compute)\n\
         inspect: [--model <name>]\n\
         closure: --model <name> --out <path>",
        mlitb::VERSION
    );
}

fn build_sim_config(args: &Args, spec: &mlitb::model::ModelSpec) -> Result<SimConfig, String> {
    let nodes = args.get_usize("nodes", 4)?;
    let mut cfg = SimConfig::paper_scaling(nodes, spec);
    cfg.iterations = args.get_u64("iters", 50)?;
    cfg.train_size = args.get_usize("train-size", 60_000)?;
    cfg.test_size = args.get_usize("test-size", 2_000)?;
    cfg.track_every = args.get_u64("track-every", 0)?;
    cfg.power_scale = args.get_f64("power-scale", 1.0)?;
    cfg.seed = args.get_u64("seed", 1)?;
    cfg.master.iter_duration_s = args.get_f64("t-secs", 4.0)?;
    cfg.master.learning_rate = args.get_f64("lr", 0.01)? as f32;
    cfg.master.capacity = args.get_usize("capacity", 3000)?;
    cfg.master.optimizer = OptimizerKind::parse(args.get_or("optimizer", "adagrad"))?;
    cfg.master.policy = ReducePolicy::parse(args.get_or("policy", "sync"))?;
    cfg.master.master_model.processes = args.get_usize("master-processes", 1)?;
    let device = DeviceClass::parse(args.get_or("device", "workstation"))?;
    cfg.fleet = vec![device; nodes];
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let model = args.get_or("model", "mnist_conv").to_string();
    let mut engine = Engine::from_default_artifacts().map_err(|e| e.to_string())?;
    engine.load_model(&model).map_err(|e| e.to_string())?;
    let spec = engine.spec(&model).map_err(|e| e.to_string())?.clone();
    let cfg = build_sim_config(args, &spec)?;
    println!(
        "training {model}: {} nodes, {} iters, T={}s, {} params, policy={}",
        cfg.fleet.len(),
        cfg.iterations,
        cfg.master.iter_duration_s,
        spec.param_count,
        cfg.master.policy.name()
    );
    let mut sim = Simulation::new(cfg, spec.clone(), &mut engine);
    let report = sim.run().map_err(|e| e.to_string())?;
    for r in report.timeline.records() {
        if r.iteration % 10 == 0 || r.test_error.is_some() {
            println!(
                "  iter {:>4}  loss={}  vectors={}  latency={:.1} ms{}",
                r.iteration,
                r.loss.map_or("-".into(), |l| format!("{l:.4}")),
                r.vectors,
                r.mean_latency_ms,
                r.test_error
                    .map_or(String::new(), |e| format!("  test_err={e:.4}"))
            );
        }
    }
    println!("done: {}", report.summary());
    if let Some(path) = args.get("csv") {
        std::fs::write(path, report.timeline.to_csv()).map_err(|e| e.to_string())?;
        println!("wrote timeline to {path}");
    }
    if let Some(path) = args.get("save-closure") {
        let mut closure = ResearchClosure::new(&spec, sim.master().params());
        closure.iteration = sim.master().iteration();
        closure.optimizer = sim.master().config().optimizer_name();
        closure.learning_rate = sim.master().config().learning_rate;
        closure.iter_duration_s = sim.master().config().iter_duration_s;
        closure.notes = format!("mlitb train, {} nodes", report.workers);
        closure.save(std::path::Path::new(path))?;
        println!("saved research closure to {path}");
    }
    Ok(())
}

fn cmd_scale(args: &Args) -> Result<(), String> {
    let model = args.get_or("model", "mnist_conv").to_string();
    let manifest = Manifest::load_default()?;
    let spec = manifest.model(&model)?.clone();
    let nodes_list = args.get_usize_list("nodes-list", &[1, 2, 4, 8, 16, 32, 64, 96])?;
    let iters = args.get_u64("iters", 20)?;
    let mut table = mlitb::metrics::Table::new(
        "scaling (modeled compute)",
        &["nodes", "power vec/s", "latency ms", "wall s/iter"],
    );
    for &n in &nodes_list {
        let mut cfg = SimConfig::paper_scaling(n, &spec);
        cfg.iterations = iters;
        cfg.seed = args.get_u64("seed", 1)?;
        let mut compute = ModeledCompute {
            param_count: spec.param_count,
        };
        let mut sim = Simulation::new(cfg, spec.clone(), &mut compute);
        let report = sim.run().map_err(|e| e.to_string())?;
        table.row(vec![
            n.to_string(),
            format!("{:.0}", report.power_vps),
            format!("{:.1}", report.mean_latency_ms),
            format!("{:.2}", report.virtual_secs / iters as f64),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<(), String> {
    let manifest = Manifest::load_default()?;
    println!("artifacts: {}", manifest.dir.display());
    for (name, spec) in &manifest.models {
        if let Some(only) = args.get("model") {
            if only != name {
                continue;
            }
        }
        println!(
            "model {name}: {} params, batch {}, input {:?}, {} classes",
            spec.param_count, spec.batch_size, spec.input, spec.classes
        );
        for t in &spec.tensors {
            println!("    {:<16} shape {:?} offset {}", t.name, t.shape, t.offset);
        }
        for (kind, file) in &spec.artifacts {
            println!("    artifact {kind}: {file}");
        }
    }
    Ok(())
}

fn cmd_closure(args: &Args) -> Result<(), String> {
    let model = args.get_or("model", "mnist_conv").to_string();
    let out = args.get_or("out", "/tmp/mlitb_closure.json").to_string();
    let manifest = Manifest::load_default()?;
    let spec = manifest.model(&model)?.clone();
    let params = init_params(&spec, args.get_u64("seed", 1)?);
    let closure = ResearchClosure::new(&spec, &params);
    closure.save(std::path::Path::new(&out))?;
    let back = ResearchClosure::load(std::path::Path::new(&out))?;
    back.check_compatible(&spec)?;
    println!(
        "closure round-trip OK: {} ({} params) -> {out}",
        back.model_name, back.param_count
    );
    Ok(())
}
