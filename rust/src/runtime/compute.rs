//! The compute abstraction behind the simulated fleet.
//!
//! `Engine` executes the *real* AOT-compiled gradients (Fig 5/8 need true
//! convergence).  `ModeledCompute` skips the numerics and only accounts
//! work — the Fig 4 power/latency sweep to 96 nodes is about coordination
//! throughput, where gradient *values* are irrelevant; this mirrors how
//! the paper separates its "power" metric (vectors/s) from correctness
//! (test error).

use anyhow::{bail, Result};

use super::{Engine, EvalResult, GradResult};

/// Gradient/eval/predict execution for one microbatch of an explicit
/// compiled batch size (`batch` must be one of the model's
/// `micro_batches`).
pub trait Compute {
    fn grad_batch(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<GradResult>;

    fn eval_batch(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<EvalResult>;

    /// Class-probability inference for one microbatch → row-major
    /// probabilities `[batch × classes]`.  The serving subsystem's
    /// micro-batch executor runs on this; implementations must be
    /// per-example pure so batch composition cannot change predictions.
    fn predict_batch(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        classes: usize,
    ) -> Result<Vec<f32>>;

    /// True when gradients are real (trainable); false for modeled compute.
    fn is_real(&self) -> bool;
}

impl Compute for Engine {
    fn grad_batch(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<GradResult> {
        self.grad_b(model, batch, params, images, labels)
    }

    fn eval_batch(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<EvalResult> {
        self.eval_b(model, batch, params, images, labels)
    }

    fn predict_batch(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        classes: usize,
    ) -> Result<Vec<f32>> {
        let expect = self.spec(model)?.classes;
        if expect != classes {
            bail!("model {model} has {expect} classes, caller expected {classes}");
        }
        self.predict_b(model, batch, params, images)
    }

    fn is_real(&self) -> bool {
        true
    }
}

/// Work-accounting stand-in: zero gradients, fixed per-example loss.
///
/// Prediction, unlike grad/eval, is *input-dependent* even in modeled
/// mode: a deterministic linear scorer + softmax over the actual pixels
/// and parameter vector.  Serving experiments need outputs that change
/// with the input (cache keys, batching-invariance checks) without
/// requiring the PJRT feature; the scorer is per-example pure, so
/// batched and unbatched execution produce bit-identical probabilities.
#[derive(Debug, Clone)]
pub struct ModeledCompute {
    pub param_count: usize,
}

impl Compute for ModeledCompute {
    fn grad_batch(
        &mut self,
        _model: &str,
        _batch: usize,
        _params: &[f32],
        _images: &[f32],
        labels: &[i32],
    ) -> Result<GradResult> {
        Ok(GradResult {
            grads: vec![0.0; self.param_count],
            loss_sum: 2.30 * labels.len() as f32, // ln(10): init-level loss
            correct: labels.len() as f32 * 0.1,
        })
    }

    fn eval_batch(
        &mut self,
        _model: &str,
        _batch: usize,
        _params: &[f32],
        _images: &[f32],
        labels: &[i32],
    ) -> Result<EvalResult> {
        Ok(EvalResult {
            loss_sum: 2.30 * labels.len() as f32,
            correct: labels.len() as f32 * 0.1,
        })
    }

    fn predict_batch(
        &mut self,
        _model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        classes: usize,
    ) -> Result<Vec<f32>> {
        modeled_predict(batch, params, images, classes)
    }

    fn is_real(&self) -> bool {
        false
    }
}

/// The deterministic linear-softmax predictor both modeled backends
/// share.  Per-example pure, so batch composition cannot change a row.
pub fn modeled_predict(
    batch: usize,
    params: &[f32],
    images: &[f32],
    classes: usize,
) -> Result<Vec<f32>> {
    if batch == 0 || classes == 0 {
        return Ok(Vec::new());
    }
    if images.len() % batch != 0 {
        bail!("images len {} not divisible by batch {batch}", images.len());
    }
    let input_len = images.len() / batch;
    let mut out = Vec::with_capacity(batch * classes);
    for example in images.chunks_exact(input_len) {
        // Per-class score: dot of the pixels with a class-strided view
        // of the parameter vector — cheap, deterministic, and distinct
        // per (input, snapshot) pair.
        let mut scores = vec![0.0f64; classes];
        if !params.is_empty() {
            for (c, s) in scores.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for (i, &x) in example.iter().enumerate() {
                    acc += x as f64 * params[(i + c * 131) % params.len()] as f64;
                }
                *s = acc;
            }
        }
        let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = scores.iter().map(|s| (s - max).exp()).collect();
        let z: f64 = exps.iter().sum();
        out.extend(exps.iter().map(|&e| (e / z) as f32));
    }
    Ok(out)
}

/// Modeled compute whose gradients *move the parameters*: each call
/// reports the gradient of ½‖p − h‖² toward a fixed pseudo-random target
/// vector `h`, so the master's optimizer produces a deterministic
/// parameter trajectory and a decreasing test error.
///
/// [`ModeledCompute`] returns zero gradients — right for coordination
/// sweeps, useless for the co-simulation, whose whole point is that the
/// live master *drifts away* from published snapshots.  Training against
/// this backend makes snapshot staleness measurable (prediction deltas,
/// error-triggered publication) without the PJRT feature; the trajectory
/// is seedless and identical across runs, keeping cosim byte-determinism.
#[derive(Debug, Clone)]
pub struct DriftingCompute {
    pub param_count: usize,
}

impl DriftingCompute {
    /// The fixed target for parameter index `i`, in [-0.5, 0.5] —
    /// FNV-mixed so neighboring indices decorrelate.
    fn target(i: usize) -> f32 {
        let mut h = 0xcbf29ce484222325u64;
        for b in (i as u64).to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        (h >> 40) as f32 / (1u64 << 24) as f32 - 0.5
    }

    /// Mean |p − h| over the vector — the drift "loss" (and error proxy).
    fn mean_gap(&self, params: &[f32]) -> f64 {
        if params.is_empty() {
            return 0.0;
        }
        params
            .iter()
            .enumerate()
            .map(|(i, &p)| (p - Self::target(i)).abs() as f64)
            .sum::<f64>()
            / params.len() as f64
    }
}

impl Compute for DriftingCompute {
    fn grad_batch(
        &mut self,
        _model: &str,
        _batch: usize,
        params: &[f32],
        _images: &[f32],
        labels: &[i32],
    ) -> Result<GradResult> {
        let n = labels.len() as f32;
        let grads: Vec<f32> = params
            .iter()
            .enumerate()
            .map(|(i, &p)| n * (p - Self::target(i)))
            .collect();
        Ok(GradResult {
            grads,
            loss_sum: self.mean_gap(params) as f32 * n,
            correct: n * (1.0 - self.mean_gap(params).min(1.0)) as f32,
        })
    }

    fn eval_batch(
        &mut self,
        _model: &str,
        _batch: usize,
        params: &[f32],
        _images: &[f32],
        labels: &[i32],
    ) -> Result<EvalResult> {
        // Accuracy rises as the parameters approach the target, so the
        // tracker's test error *decreases* over the run — exercising the
        // cosim's error-triggered publication path.
        let n = labels.len() as f32;
        let gap = self.mean_gap(params).min(1.0);
        Ok(EvalResult {
            loss_sum: self.mean_gap(params) as f32 * n,
            correct: n * (1.0 - gap) as f32,
        })
    }

    fn predict_batch(
        &mut self,
        _model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        classes: usize,
    ) -> Result<Vec<f32>> {
        modeled_predict(batch, params, images, classes)
    }

    fn is_real(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_compute_accounts_without_values() {
        let mut c = ModeledCompute { param_count: 8 };
        let g = c
            .grad_batch("any", 2, &[0.0; 8], &[0.0; 4], &[0, 1])
            .unwrap();
        assert_eq!(g.grads.len(), 8);
        assert!(g.grads.iter().all(|&x| x == 0.0));
        assert!((g.loss_sum - 4.6).abs() < 1e-5);
        assert!(!c.is_real());
    }

    #[test]
    fn modeled_predict_is_normalized_and_input_dependent() {
        let mut c = ModeledCompute { param_count: 8 };
        let params: Vec<f32> = (0..8).map(|i| (i as f32 - 3.5) * 0.1).collect();
        let images = vec![0.1, 0.9, 0.4, 0.2, 0.8, 0.3]; // 2 examples × 3 px
        let probs = c.predict_batch("m", 2, &params, &images, 4).unwrap();
        assert_eq!(probs.len(), 8);
        for row in probs.chunks(4) {
            let z: f32 = row.iter().sum();
            assert!((z - 1.0).abs() < 1e-5, "{row:?}");
            assert!(row.iter().all(|p| *p > 0.0));
        }
        assert_ne!(probs[..4], probs[4..], "distinct inputs, distinct probs");
    }

    #[test]
    fn drifting_compute_moves_parameters_toward_its_target() {
        let mut c = DriftingCompute { param_count: 4 };
        let params = vec![0.0f32; 4];
        let g = c.grad_batch("m", 2, &params, &[0.0; 4], &[0, 1]).unwrap();
        assert_eq!(g.grads.len(), 4);
        assert!(g.grads.iter().any(|&x| x != 0.0), "drift must be nonzero");
        // One SGD step down the reported gradient shrinks the gap, and
        // the eval error tracks it.
        let stepped: Vec<f32> = params
            .iter()
            .zip(&g.grads)
            .map(|(&p, &gr)| p - 0.1 * gr / 2.0)
            .collect();
        let e0 = c.eval_batch("m", 2, &params, &[0.0; 4], &[0, 1]).unwrap();
        let e1 = c.eval_batch("m", 2, &stepped, &[0.0; 4], &[0, 1]).unwrap();
        assert!(e1.correct > e0.correct, "error must decrease as params drift");
        assert!(!c.is_real());
        // Deterministic: same call, same gradient.
        let g2 = c.grad_batch("m", 2, &params, &[0.0; 4], &[0, 1]).unwrap();
        assert_eq!(g.grads, g2.grads);
    }

    #[test]
    fn drifting_and_modeled_predict_agree() {
        // Both modeled backends share one scorer: serving through either
        // gives identical probability rows for identical params.
        let params: Vec<f32> = (0..12).map(|i| (i as f32 - 6.0) * 0.1).collect();
        let images = vec![0.3f32, 0.7, 0.1, 0.9, 0.2, 0.5];
        let mut a = ModeledCompute { param_count: 12 };
        let mut b = DriftingCompute { param_count: 12 };
        let pa = a.predict_batch("m", 2, &params, &images, 4).unwrap();
        let pb = b.predict_batch("m", 2, &params, &images, 4).unwrap();
        assert_eq!(pa, pb);
    }

    #[test]
    fn modeled_predict_batching_invariant() {
        // The serving acceptance criterion at the compute level: executing
        // two examples together or separately yields identical rows.
        let mut c = ModeledCompute { param_count: 16 };
        let params: Vec<f32> = (0..16).map(|i| ((i * 7 % 5) as f32 - 2.0) * 0.3).collect();
        let a = vec![0.25f32; 6];
        let b: Vec<f32> = (0..6).map(|i| i as f32 / 6.0).collect();
        let together = {
            let mut images = a.clone();
            images.extend_from_slice(&b);
            c.predict_batch("m", 2, &params, &images, 10).unwrap()
        };
        let alone_a = c.predict_batch("m", 1, &params, &a, 10).unwrap();
        let alone_b = c.predict_batch("m", 1, &params, &b, 10).unwrap();
        assert_eq!(together[..10], alone_a[..]);
        assert_eq!(together[10..], alone_b[..]);
    }
}
