//! The compute abstraction behind the simulated fleet.
//!
//! `Engine` executes the *real* AOT-compiled gradients (Fig 5/8 need true
//! convergence).  `ModeledCompute` skips the numerics and only accounts
//! work — the Fig 4 power/latency sweep to 96 nodes is about coordination
//! throughput, where gradient *values* are irrelevant; this mirrors how
//! the paper separates its "power" metric (vectors/s) from correctness
//! (test error).

use anyhow::Result;

use super::{Engine, EvalResult, GradResult};

/// Gradient/eval execution for one microbatch of an explicit compiled
/// batch size (`batch` must be one of the model's `micro_batches`).
pub trait Compute {
    fn grad_batch(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<GradResult>;

    fn eval_batch(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<EvalResult>;

    /// True when gradients are real (trainable); false for modeled compute.
    fn is_real(&self) -> bool;
}

impl Compute for Engine {
    fn grad_batch(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<GradResult> {
        self.grad_b(model, batch, params, images, labels)
    }

    fn eval_batch(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<EvalResult> {
        self.eval_b(model, batch, params, images, labels)
    }

    fn is_real(&self) -> bool {
        true
    }
}

/// Work-accounting stand-in: zero gradients, fixed per-example loss.
#[derive(Debug, Clone)]
pub struct ModeledCompute {
    pub param_count: usize,
}

impl Compute for ModeledCompute {
    fn grad_batch(
        &mut self,
        _model: &str,
        _batch: usize,
        _params: &[f32],
        _images: &[f32],
        labels: &[i32],
    ) -> Result<GradResult> {
        Ok(GradResult {
            grads: vec![0.0; self.param_count],
            loss_sum: 2.30 * labels.len() as f32, // ln(10): init-level loss
            correct: labels.len() as f32 * 0.1,
        })
    }

    fn eval_batch(
        &mut self,
        _model: &str,
        _batch: usize,
        _params: &[f32],
        _images: &[f32],
        labels: &[i32],
    ) -> Result<EvalResult> {
        Ok(EvalResult {
            loss_sum: 2.30 * labels.len() as f32,
            correct: labels.len() as f32 * 0.1,
        })
    }

    fn is_real(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_compute_accounts_without_values() {
        let mut c = ModeledCompute { param_count: 8 };
        let g = c
            .grad_batch("any", 2, &[0.0; 8], &[0.0; 4], &[0, 1])
            .unwrap();
        assert_eq!(g.grads.len(), 8);
        assert!(g.grads.iter().all(|&x| x == 0.0));
        assert!((g.loss_sum - 4.6).abs() < 1e-5);
        assert!(!c.is_real());
    }
}
