//! API-compatible `Engine` stub for builds without the `pjrt` feature.
//!
//! Manifest handling (specs, artifact paths) works normally so CLI
//! commands like `inspect` and the serving registry stay usable; anything
//! that would execute a compiled artifact returns an error directing the
//! user to rebuild with `--features pjrt`.  Keeping the API identical lets
//! every call site (simulation, benches, examples) compile unchanged.

use anyhow::{anyhow, Result};

use super::{EvalResult, GradResult};
use crate::model::{Manifest, ModelSpec};

/// Compiled-executable registry — stubbed: holds the manifest only.
pub struct Engine {
    manifest: Manifest,
    exec_count: u64,
}

fn unavailable(what: &str) -> anyhow::Error {
    anyhow!(
        "{what} needs the PJRT runtime, but mlitb was built without the \
         `pjrt` feature (rebuild with `cargo build --features pjrt`)"
    )
}

impl Engine {
    /// Create an engine over a manifest (no PJRT client in stub builds).
    pub fn new(manifest: Manifest) -> Result<Self> {
        Ok(Self {
            manifest,
            exec_count: 0,
        })
    }

    /// Convenience: engine over the default artifacts directory.
    pub fn from_default_artifacts() -> Result<Self> {
        let manifest = Manifest::load_default().map_err(|e| anyhow!(e))?;
        Self::new(manifest)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn spec(&self, model: &str) -> Result<&ModelSpec> {
        self.manifest.model(model).map_err(|e| anyhow!(e))
    }

    pub fn executions(&self) -> u64 {
        self.exec_count
    }

    /// Compiling artifacts requires PJRT; fail early with a clear message.
    pub fn load_model(&mut self, model: &str) -> Result<()> {
        // Validate the manifest entry first so unknown-model errors read
        // the same as in real builds.
        self.manifest.model(model).map_err(|e| anyhow!(e))?;
        Err(unavailable(&format!("loading model '{model}'")))
    }

    pub fn grad(
        &mut self,
        model: &str,
        _params: &[f32],
        _images: &[f32],
        _labels: &[i32],
    ) -> Result<GradResult> {
        Err(unavailable(&format!("grad on '{model}'")))
    }

    pub fn grad_b(
        &mut self,
        model: &str,
        _batch: usize,
        _params: &[f32],
        _images: &[f32],
        _labels: &[i32],
    ) -> Result<GradResult> {
        Err(unavailable(&format!("grad on '{model}'")))
    }

    pub fn eval(
        &mut self,
        model: &str,
        _params: &[f32],
        _images: &[f32],
        _labels: &[i32],
    ) -> Result<EvalResult> {
        Err(unavailable(&format!("eval on '{model}'")))
    }

    pub fn eval_b(
        &mut self,
        model: &str,
        _batch: usize,
        _params: &[f32],
        _images: &[f32],
        _labels: &[i32],
    ) -> Result<EvalResult> {
        Err(unavailable(&format!("eval on '{model}'")))
    }

    pub fn predict(&mut self, model: &str, _params: &[f32], _images: &[f32]) -> Result<Vec<f32>> {
        Err(unavailable(&format!("predict on '{model}'")))
    }

    pub fn predict_b(
        &mut self,
        model: &str,
        _batch: usize,
        _params: &[f32],
        _images: &[f32],
    ) -> Result<Vec<f32>> {
        Err(unavailable(&format!("predict on '{model}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;
    use std::path::Path;

    fn manifest() -> Manifest {
        let doc = parse(
            r#"{"batch_size": 2, "models": {"toy": {
                "param_count": 2, "batch_size": 2, "input": [1], "classes": 2,
                "tensors": [{"name": "w", "shape": [2], "offset": 0, "size": 2, "fan_in": 1}],
                "artifacts": {"grad": {"file": "g.hlo.txt"}}
            }}}"#,
        )
        .unwrap();
        Manifest::from_value(Path::new("/tmp"), &doc).unwrap()
    }

    #[test]
    fn manifest_paths_work_without_pjrt() {
        let engine = Engine::new(manifest()).unwrap();
        assert_eq!(engine.spec("toy").unwrap().param_count, 2);
        assert!(engine.spec("nope").is_err());
        assert_eq!(engine.executions(), 0);
    }

    #[test]
    fn execution_paths_error_with_guidance() {
        let mut engine = Engine::new(manifest()).unwrap();
        let err = engine.load_model("toy").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
        assert!(engine.grad("toy", &[], &[], &[]).is_err());
        assert!(engine.predict_b("toy", 2, &[], &[]).is_err());
    }
}
