//! PJRT runtime: load AOT HLO-text artifacts, compile once per model
//! variant, execute grad/eval/predict from the coordinator's hot path.
//!
//! This replaces the paper's in-browser JavaScript NN execution: the same
//! compute the ConvNetJS trainer did per client is done here by XLA CPU
//! executables produced from the JAX/Pallas L2/L1 layers.  Python never
//! runs at this point — artifacts are plain text files on disk.
//!
//! The PJRT bindings are gated behind the `pjrt` cargo feature: the `xla`
//! crate needs the XLA C library at build time, which offline/CI
//! environments lack.  Without the feature [`Engine`] is an API-compatible
//! stub that loads manifests but fails at execution time; coordination,
//! serving and the modeled benches are unaffected (they run on
//! [`ModeledCompute`]).
//!
//! Note: `PjRtClient` is `Rc`-backed (not `Send`); the engine lives on the
//! simulation thread and all client compute is serialized through it —
//! which is also what makes simulated-fleet runs deterministic.

mod batch;
mod compute;
#[cfg(feature = "pjrt")]
mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
mod engine;

pub use batch::BatchBuilder;
pub use compute::{modeled_predict, Compute, DriftingCompute, ModeledCompute};
pub use engine::Engine;

/// Output of one gradient microbatch (sums over the batch — see L2 docs).
#[derive(Debug, Clone)]
pub struct GradResult {
    pub grads: Vec<f32>,
    pub loss_sum: f32,
    pub correct: f32,
}

/// Output of one eval microbatch.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub loss_sum: f32,
    pub correct: f32,
}
