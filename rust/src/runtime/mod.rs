//! PJRT runtime: load AOT HLO-text artifacts, compile once per model
//! variant, execute grad/eval/predict from the coordinator's hot path.
//!
//! This replaces the paper's in-browser JavaScript NN execution: the same
//! compute the ConvNetJS trainer did per client is done here by XLA CPU
//! executables produced from the JAX/Pallas L2/L1 layers.  Python never
//! runs at this point — artifacts are plain text files on disk.
//!
//! Note: `PjRtClient` is `Rc`-backed (not `Send`); the engine lives on the
//! simulation thread and all client compute is serialized through it —
//! which is also what makes simulated-fleet runs deterministic.

mod batch;
mod compute;
mod engine;

pub use batch::BatchBuilder;
pub use compute::{Compute, ModeledCompute};
pub use engine::{Engine, EvalResult, GradResult};
