//! The PJRT engine: HLO text → compiled executables → typed execution.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::{EvalResult, GradResult};
use crate::model::{Manifest, ModelSpec};

/// Compiled-executable registry over one PJRT CPU client.
///
/// Each model variant compiles every artifact in its manifest entry —
/// including the `grad_b8`/`grad_b1` microbatch variants weak devices use
/// (§3.3d).  Executables are keyed by (model, artifact key).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Determinism audit: point access only (contains_key/insert/get);
    /// compile order comes from the manifest's `BTreeMap` keys.
    execs: HashMap<(String, String), xla::PjRtLoadedExecutable>,
    /// Cumulative executions, for metrics/EXPERIMENTS.md.
    exec_count: u64,
}

impl Engine {
    /// Create a CPU engine over a manifest (does not compile anything yet).
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            execs: HashMap::new(),
            exec_count: 0,
        })
    }

    /// Convenience: engine over the default artifacts directory.
    pub fn from_default_artifacts() -> Result<Self> {
        let manifest = Manifest::load_default().map_err(|e| anyhow!(e))?;
        Self::new(manifest)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn spec(&self, model: &str) -> Result<&ModelSpec> {
        self.manifest.model(model).map_err(|e| anyhow!(e))
    }

    pub fn executions(&self) -> u64 {
        self.exec_count
    }

    /// Compile all artifacts for `model` (idempotent).
    pub fn load_model(&mut self, model: &str) -> Result<()> {
        let spec = self.manifest.model(model).map_err(|e| anyhow!(e))?.clone();
        for kind in spec.artifacts.keys() {
            if self.execs.contains_key(&(model.to_string(), kind.clone())) {
                continue;
            }
            let path = self
                .manifest
                .artifact_path(&spec, kind)
                .map_err(|e| anyhow!(e))?;
            let exe = self.compile_artifact(&path)?;
            self.execs.insert((model.to_string(), kind.clone()), exe);
        }
        Ok(())
    }

    fn compile_artifact(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
    }

    fn exec(&self, model: &str, key: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.execs
            .get(&(model.to_string(), key.to_string()))
            .ok_or_else(|| {
                anyhow!("model '{model}' artifact '{key}' not loaded — call load_model first")
            })
    }

    fn check_batch_inputs(
        spec: &ModelSpec,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: Option<&[i32]>,
    ) -> Result<()> {
        if params.len() != spec.param_count {
            bail!(
                "params len {} != {} for model {}",
                params.len(),
                spec.param_count,
                spec.name
            );
        }
        let expect = batch * spec.input_len();
        if images.len() != expect {
            bail!("images len {} != {expect} (batch {batch})", images.len());
        }
        if let Some(labels) = labels {
            if labels.len() != batch {
                bail!("labels len {} != {batch}", labels.len());
            }
            if let Some(&bad) = labels.iter().find(|&&l| l < 0 || l as usize >= spec.classes) {
                bail!("label {bad} out of range 0..{}", spec.classes);
            }
        }
        Ok(())
    }

    fn image_literal(&self, spec: &ModelSpec, batch: usize, images: &[f32]) -> Result<xla::Literal> {
        let dims: Vec<i64> = std::iter::once(batch as i64)
            .chain(spec.input.iter().map(|&d| d as i64))
            .collect();
        xla::Literal::vec1(images)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape images: {e:?}"))
    }

    /// Gradient microbatch at the default batch size.
    pub fn grad(
        &mut self,
        model: &str,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<GradResult> {
        let b = self.spec(model)?.batch_size;
        self.grad_b(model, b, params, images, labels)
    }

    /// Gradient microbatch at an explicit compiled batch size:
    /// (params, images[b·HWC], labels[b]) → (Σgrads, Σloss, #correct).
    pub fn grad_b(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<GradResult> {
        let spec = self.spec(model)?.clone();
        Self::check_batch_inputs(&spec, batch, params, images, Some(labels))?;
        let key = spec.artifact_key("grad", batch);
        let p = xla::Literal::vec1(params);
        let x = self.image_literal(&spec, batch, images)?;
        let y = xla::Literal::vec1(labels);
        let exe = self.exec(model, &key)?;
        let result = exe
            .execute::<xla::Literal>(&[p, x, y])
            .map_err(|e| anyhow!("execute {key}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {key} result: {e:?}"))?;
        self.exec_count += 1;
        let (g, loss, correct) = result
            .to_tuple3()
            .map_err(|e| anyhow!("{key} output tuple: {e:?}"))?;
        Ok(GradResult {
            grads: g.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            loss_sum: scalar_f32(&loss)?,
            correct: scalar_f32(&correct)?,
        })
    }

    /// Eval microbatch at the default batch size.
    pub fn eval(
        &mut self,
        model: &str,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<EvalResult> {
        let b = self.spec(model)?.batch_size;
        self.eval_b(model, b, params, images, labels)
    }

    /// Eval microbatch at an explicit compiled batch size → (Σloss, #correct).
    pub fn eval_b(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<EvalResult> {
        let spec = self.spec(model)?.clone();
        Self::check_batch_inputs(&spec, batch, params, images, Some(labels))?;
        let key = spec.artifact_key("eval", batch);
        let p = xla::Literal::vec1(params);
        let x = self.image_literal(&spec, batch, images)?;
        let y = xla::Literal::vec1(labels);
        let exe = self.exec(model, &key)?;
        let result = exe
            .execute::<xla::Literal>(&[p, x, y])
            .map_err(|e| anyhow!("execute {key}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {key} result: {e:?}"))?;
        self.exec_count += 1;
        let (loss, correct) = result
            .to_tuple2()
            .map_err(|e| anyhow!("{key} output tuple: {e:?}"))?;
        Ok(EvalResult {
            loss_sum: scalar_f32(&loss)?,
            correct: scalar_f32(&correct)?,
        })
    }

    /// Predict microbatch (default batch size) → probabilities [B×classes].
    pub fn predict(&mut self, model: &str, params: &[f32], images: &[f32]) -> Result<Vec<f32>> {
        let b = self.spec(model)?.batch_size;
        self.predict_b(model, b, params, images)
    }

    /// Predict at an explicit compiled batch size → probabilities
    /// [b×classes] — the serving path's micro-batch executor uses the
    /// `predict_b{n}` artifact variants the same way training uses
    /// `grad_b{n}`.  Artifact sets built before the AOT layer emitted
    /// those variants fall back transparently: pad up to the default
    /// compiled batch and slice the real rows back out.
    pub fn predict_b(
        &mut self,
        model: &str,
        batch: usize,
        params: &[f32],
        images: &[f32],
    ) -> Result<Vec<f32>> {
        let spec = self.spec(model)?.clone();
        Self::check_batch_inputs(&spec, batch, params, images, None)?;
        let key = spec.artifact_key("predict", batch);
        if !spec.artifacts.contains_key(&key) && batch > 0 && batch < spec.batch_size {
            let input_len = spec.input_len();
            let mut padded = Vec::with_capacity(spec.batch_size * input_len);
            padded.extend_from_slice(images);
            for _ in batch..spec.batch_size {
                padded.extend_from_slice(&images[..input_len]);
            }
            let full = self.predict_b(model, spec.batch_size, params, &padded)?;
            return Ok(full[..batch * spec.classes].to_vec());
        }
        let p = xla::Literal::vec1(params);
        let x = self.image_literal(&spec, batch, images)?;
        let exe = self.exec(model, &key)?;
        let result = exe
            .execute::<xla::Literal>(&[p, x])
            .map_err(|e| anyhow!("execute predict: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch predict result: {e:?}"))?;
        self.exec_count += 1;
        let probs = result
            .to_tuple1()
            .map_err(|e| anyhow!("predict output tuple: {e:?}"))?;
        probs
            .to_vec::<f32>()
            .map_err(|e| anyhow!("predict to_vec: {e:?}"))
            .context("predict output")
    }
}

fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>().map_err(|e| anyhow!("scalar: {e:?}"))?;
    v.first()
        .copied()
        .ok_or_else(|| anyhow!("empty scalar literal"))
}
