//! Microbatch assembly from cached samples.
//!
//! Artifacts are compiled for a fixed batch size B; trainers cycle through
//! their allocated ids producing full batches (the paper's client "performs
//! as many gradient computations as possible within the iteration duration
//! T", §3.6 — there is no data-defined batch size).

use crate::data::SharedSample;

/// Reusable flat buffers for one model's batch shape (zero allocation per
/// microbatch on the hot path).
#[derive(Debug, Clone)]
pub struct BatchBuilder {
    batch_size: usize,
    input_len: usize,
    images: Vec<f32>,
    labels: Vec<i32>,
}

impl BatchBuilder {
    pub fn new(batch_size: usize, input_len: usize) -> Self {
        Self {
            batch_size,
            input_len,
            images: vec![0.0; batch_size * input_len],
            labels: vec![0; batch_size],
        }
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Fill from `samples`, starting at `cursor`, wrapping around.  Returns
    /// the advanced cursor.  Panics if `samples` is empty or a sample has
    /// the wrong pixel count.
    pub fn fill_cyclic(&mut self, samples: &[SharedSample], mut cursor: usize) -> usize {
        assert!(!samples.is_empty(), "cannot batch from empty sample set");
        for slot in 0..self.batch_size {
            let s = &samples[cursor % samples.len()];
            assert_eq!(
                s.pixels.len(),
                self.input_len,
                "sample pixel count mismatch"
            );
            self.images[slot * self.input_len..(slot + 1) * self.input_len]
                .copy_from_slice(&s.pixels);
            self.labels[slot] = s.label as i32;
            cursor += 1;
        }
        cursor
    }

    pub fn images(&self) -> &[f32] {
        &self.images
    }

    pub fn labels(&self) -> &[i32] {
        &self.labels
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Sample;
    use std::sync::Arc;

    fn samples(n: usize, input_len: usize) -> Vec<SharedSample> {
        (0..n)
            .map(|i| {
                Arc::new(Sample {
                    label: (i % 10) as u8,
                    pixels: vec![i as f32; input_len],
                })
            })
            .collect()
    }

    #[test]
    fn fills_in_order_and_wraps() {
        let mut b = BatchBuilder::new(4, 2);
        let ss = samples(3, 2);
        let cursor = b.fill_cyclic(&ss, 0);
        assert_eq!(cursor, 4);
        assert_eq!(b.labels(), &[0, 1, 2, 0]);
        assert_eq!(b.images(), &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 0.0, 0.0]);
        // continue from the cursor
        b.fill_cyclic(&ss, cursor);
        assert_eq!(b.labels(), &[1, 2, 0, 1]);
    }

    #[test]
    #[should_panic(expected = "empty sample set")]
    fn empty_samples_panics() {
        BatchBuilder::new(2, 2).fill_cyclic(&[], 0);
    }

    #[test]
    #[should_panic(expected = "pixel count mismatch")]
    fn wrong_shape_panics() {
        let mut b = BatchBuilder::new(1, 3);
        b.fill_cyclic(&samples(1, 2), 0);
    }
}
