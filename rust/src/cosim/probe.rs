//! The staleness probe — a [`ServeObserver`] that measures how far each
//! served answer lags its own project's live master.
//!
//! At every response it records the snapshot's age (iterations and
//! virtual ms behind the owning project's master).  With `measure_delta`
//! on, it also re-predicts the same input against that master's *current*
//! parameters and records the L1 probability delta and whether the argmax
//! class flipped — the "how wrong was the stale answer" axis of
//! `fig_cosim`.  Fresh predictions are memoized per (project, input,
//! master window): pool inputs are shared `Arc`s, so pointer identity
//! keys the memo and the probe costs one extra execution per *distinct*
//! input per iteration, not per request.  Each project keeps its own
//! master state and memo — interleaved multi-project traffic never
//! cross-contaminates (the `StalenessLog` isolation property).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::metrics::{RequestRecord, StalenessLog, StalenessRecord};
use crate::model::ModelSpec;
use crate::runtime::Compute;
use crate::serve::{Prediction, ProjectId, ServeObserver, SnapshotMeta};

/// One project's live-master mirror inside the probe.
struct ProjectProbe {
    spec: ModelSpec,
    master_iteration: u64,
    master_params: Vec<f32>,
    /// input-Arc pointer → (fresh probability row, fresh argmax); cleared
    /// whenever this project's master window advances.  Determinism
    /// audit: point access only (get/insert/clear) — never iterated.
    memo: HashMap<usize, (Vec<f32>, u32)>,
    /// Smallest compiled micro-batch — the probe's execution shape
    /// (padded by repeating the input).
    probe_batch: usize,
}

/// Observer wiring staleness measurement into the serving engine, one
/// master mirror per hosted project.
pub struct StalenessProbe {
    projects: Vec<ProjectProbe>,
    measure_delta: bool,
    log: StalenessLog,
    scratch: Vec<f32>,
}

impl StalenessProbe {
    /// `specs` in project-id order (one per registered project).
    pub fn new(specs: &[ModelSpec], measure_delta: bool) -> Self {
        let projects = specs
            .iter()
            .map(|spec| ProjectProbe {
                probe_batch: spec.micro_batches.iter().copied().min().unwrap_or(1).max(1),
                spec: spec.clone(),
                master_iteration: 0,
                master_params: Vec::new(),
                memo: HashMap::new(),
            })
            .collect();
        Self {
            projects,
            measure_delta,
            log: StalenessLog::new(),
            scratch: Vec::new(),
        }
    }

    /// Install one project's parameters live for its upcoming serving
    /// window (the ones broadcast at the window's opening iteration
    /// boundary).  The copy is skipped when the delta probe is off — age
    /// bookkeeping only needs the iteration number.
    pub fn set_master(&mut self, project: ProjectId, iteration: u64, params: &[f32]) {
        let p = &mut self.projects[project.index()];
        p.master_iteration = iteration;
        if self.measure_delta {
            p.master_params.clear();
            p.master_params.extend_from_slice(params);
        }
        p.memo.clear();
    }

    pub fn log(&self) -> &StalenessLog {
        &self.log
    }

    pub fn into_log(self) -> StalenessLog {
        self.log
    }

    /// Fresh prediction for `input` under one project's live master
    /// parameters, memoized per master window.
    fn fresh(
        &mut self,
        pi: usize,
        input: &Arc<Vec<f32>>,
        compute: &mut dyn Compute,
    ) -> Result<(Vec<f32>, u32)> {
        let key = Arc::as_ptr(input) as usize;
        if let Some(hit) = self.projects[pi].memo.get(&key) {
            return Ok(hit.clone());
        }
        let probe_batch = self.projects[pi].probe_batch;
        let classes = self.projects[pi].spec.classes;
        self.scratch.clear();
        for _ in 0..probe_batch {
            self.scratch.extend_from_slice(input);
        }
        let probs = compute.predict_batch(
            &self.projects[pi].spec.name,
            probe_batch,
            &self.projects[pi].master_params,
            &self.scratch,
            classes,
        )?;
        let row = probs[..classes].to_vec();
        let class = Prediction::from_row(&row).class as u32;
        let out = (row, class);
        self.projects[pi].memo.insert(key, out.clone());
        Ok(out)
    }
}

impl ServeObserver for StalenessProbe {
    fn on_response(
        &mut self,
        record: &RequestRecord,
        input: &Arc<Vec<f32>>,
        served: &Prediction,
        snapshot: SnapshotMeta,
        compute: &mut dyn Compute,
    ) -> Result<()> {
        let pi = snapshot.version.project.index();
        let (delta, fresh_class) = if self.measure_delta {
            let (fresh_row, fresh_class) = self.fresh(pi, input, compute)?;
            let delta: f64 = fresh_row
                .iter()
                .zip(&served.probs)
                .map(|(f, s)| (f - s).abs() as f64)
                .sum();
            (Some(delta), Some(fresh_class))
        } else {
            (None, None)
        };
        self.log.push(StalenessRecord {
            id: record.id,
            client: record.client,
            done_ms: record.done_ms,
            version: snapshot.version,
            snapshot_iteration: snapshot.iteration,
            master_iteration: self.projects[pi].master_iteration,
            age_ms: (record.done_ms - snapshot.published_ms).max(0.0),
            delta,
            fresh_class,
            class: record.class,
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;
    use crate::runtime::ModeledCompute;
    use crate::serve::ModelVersion;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 12,
            batch_size: 4,
            micro_batches: vec![4, 2],
            input: vec![3, 1, 1],
            classes: 4,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![12],
                offset: 0,
                size: 12,
                fan_in: 3,
            }],
            artifacts: Default::default(),
        }
    }

    const P0: ProjectId = ProjectId::new(0);

    fn v(project: u32, version: u64) -> ModelVersion {
        ModelVersion {
            project: ProjectId::new(project),
            version,
        }
    }

    fn record(id: u64, class: u32) -> RequestRecord {
        RequestRecord {
            id,
            client: 0,
            sent_ms: 0.0,
            done_ms: 10.0,
            latency_ms: 10.0,
            shard: 0,
            version: v(0, 1),
            batch_size: 1,
            cache_hit: false,
            coalesced: false,
            class,
        }
    }

    fn meta() -> SnapshotMeta {
        meta_p(0)
    }

    fn meta_p(project: u32) -> SnapshotMeta {
        SnapshotMeta {
            version: v(project, 1),
            iteration: 2,
            published_ms: 4.0,
        }
    }

    #[test]
    fn identical_params_give_zero_delta() {
        let mut compute = ModeledCompute { param_count: 12 };
        let params: Vec<f32> = (0..12).map(|i| (i as f32 - 6.0) * 0.1).collect();
        let mut probe = StalenessProbe::new(&[spec()], true);
        probe.set_master(P0, 5, &params);
        let input = Arc::new(vec![0.3f32, 0.7, 0.1]);
        // Serve the same answer the live params would give.
        let row = crate::runtime::modeled_predict(1, &params, &input, 4).unwrap();
        let served = Prediction::from_row(&row);
        probe
            .on_response(&record(1, served.class as u32), &input, &served, meta(), &mut compute)
            .unwrap();
        let log = probe.into_log();
        assert_eq!(log.len(), 1);
        let r = &log.records()[0];
        assert_eq!(r.age_iters(), 3);
        assert_eq!(r.age_ms, 6.0);
        assert!(r.delta.unwrap() < 1e-6, "same params, same probs");
        assert_eq!(r.class_changed(), Some(false));
    }

    #[test]
    fn diverged_params_show_a_delta() {
        let mut compute = ModeledCompute { param_count: 12 };
        let stale: Vec<f32> = (0..12).map(|i| (i as f32 - 6.0) * 0.1).collect();
        let live: Vec<f32> = stale.iter().map(|p| -p).collect();
        let mut probe = StalenessProbe::new(&[spec()], true);
        probe.set_master(P0, 9, &live);
        let input = Arc::new(vec![0.9f32, 0.2, 0.4]);
        let row = crate::runtime::modeled_predict(1, &stale, &input, 4).unwrap();
        let served = Prediction::from_row(&row);
        probe
            .on_response(&record(1, served.class as u32), &input, &served, meta(), &mut compute)
            .unwrap();
        let r = &probe.log().records()[0];
        assert!(r.delta.unwrap() > 1e-3, "sign-flipped params must diverge");
    }

    #[test]
    fn probe_disabled_records_ages_only() {
        let mut compute = ModeledCompute { param_count: 12 };
        let mut probe = StalenessProbe::new(&[spec()], false);
        probe.set_master(P0, 4, &[0.0; 12]);
        let input = Arc::new(vec![0.1f32, 0.2, 0.3]);
        let served = Prediction {
            class: 1,
            confidence: 1.0,
            probs: vec![0.0, 1.0, 0.0, 0.0],
        };
        probe
            .on_response(&record(7, 1), &input, &served, meta(), &mut compute)
            .unwrap();
        let r = &probe.log().records()[0];
        assert_eq!(r.delta, None);
        assert_eq!(r.fresh_class, None);
        assert_eq!(r.age_iters(), 2);
    }

    #[test]
    fn memo_resets_when_the_master_window_advances() {
        let mut compute = ModeledCompute { param_count: 12 };
        let p1: Vec<f32> = (0..12).map(|i| i as f32 * 0.1).collect();
        let p2: Vec<f32> = (0..12).map(|i| -(i as f32) * 0.1).collect();
        let mut probe = StalenessProbe::new(&[spec()], true);
        let input = Arc::new(vec![0.5f32, 0.5, 0.5]);
        let served = {
            let row = crate::runtime::modeled_predict(1, &p1, &input, 4).unwrap();
            Prediction::from_row(&row)
        };
        probe.set_master(P0, 1, &p1);
        probe
            .on_response(&record(1, served.class as u32), &input, &served, meta(), &mut compute)
            .unwrap();
        assert!(probe.log().records()[0].delta.unwrap() < 1e-6);
        // New window with different live params: the memo must not serve
        // the old fresh row.
        probe.set_master(P0, 2, &p2);
        probe
            .on_response(&record(2, served.class as u32), &input, &served, meta(), &mut compute)
            .unwrap();
        assert!(probe.log().records()[1].delta.unwrap() > 1e-3);
    }

    #[test]
    fn projects_keep_independent_masters_and_memos() {
        // Two projects, same input Arc, opposite master parameters: each
        // project's delta must be computed against its *own* master, and
        // advancing one project's window must not clear the other's memo.
        let mut compute = ModeledCompute { param_count: 12 };
        let pa: Vec<f32> = (0..12).map(|i| i as f32 * 0.1).collect();
        let pb: Vec<f32> = pa.iter().map(|x| -x).collect();
        let mut probe = StalenessProbe::new(&[spec(), spec()], true);
        probe.set_master(P0, 3, &pa);
        probe.set_master(ProjectId::new(1), 8, &pb);
        let input = Arc::new(vec![0.5f32, 0.2, 0.8]);
        // Serve project 0's live answer through both projects.
        let row = crate::runtime::modeled_predict(1, &pa, &input, 4).unwrap();
        let served = Prediction::from_row(&row);
        let mut rec0 = record(1, served.class as u32);
        rec0.version = v(0, 1);
        probe
            .on_response(&rec0, &input, &served, meta_p(0), &mut compute)
            .unwrap();
        let mut rec1 = record(2, served.class as u32);
        rec1.version = v(1, 1);
        probe
            .on_response(&rec1, &input, &served, meta_p(1), &mut compute)
            .unwrap();
        let r0 = &probe.log().records()[0];
        let r1 = &probe.log().records()[1];
        assert!(r0.delta.unwrap() < 1e-6, "matches project 0's master");
        assert!(r1.delta.unwrap() > 1e-3, "diverges from project 1's master");
        assert_eq!(r0.master_iteration, 3);
        assert_eq!(r1.master_iteration, 8);
        // Advancing project 1's window leaves project 0's memo warm: the
        // same input re-probed under project 0 still matches.
        probe.set_master(ProjectId::new(1), 9, &pb);
        let mut rec2 = record(3, served.class as u32);
        rec2.version = v(0, 1);
        probe
            .on_response(&rec2, &input, &served, meta_p(0), &mut compute)
            .unwrap();
        assert!(probe.log().records()[2].delta.unwrap() < 1e-6);
    }
}
