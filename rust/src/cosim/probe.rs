//! The staleness probe — a [`ServeObserver`] that measures how far each
//! served answer lags the live master.
//!
//! At every response it records the snapshot's age (iterations and
//! virtual ms behind the master).  With `measure_delta` on, it also
//! re-predicts the same input against the master's *current* parameters
//! and records the L1 probability delta and whether the argmax class
//! flipped — the "how wrong was the stale answer" axis of `fig_cosim`.
//! Fresh predictions are memoized per (input, master window): pool inputs
//! are shared `Arc`s, so pointer identity keys the memo and the probe
//! costs one extra execution per *distinct* input per iteration, not per
//! request.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::metrics::{RequestRecord, StalenessLog, StalenessRecord};
use crate::model::ModelSpec;
use crate::runtime::Compute;
use crate::serve::{Prediction, ServeObserver, SnapshotMeta};

/// Observer wiring staleness measurement into the serving engine.
pub struct StalenessProbe {
    spec: ModelSpec,
    measure_delta: bool,
    master_iteration: u64,
    master_params: Vec<f32>,
    log: StalenessLog,
    /// input-Arc pointer → (fresh probability row, fresh argmax); cleared
    /// whenever the master window advances.
    memo: HashMap<usize, (Vec<f32>, u32)>,
    /// Smallest compiled micro-batch — the probe's execution shape
    /// (padded by repeating the input).
    probe_batch: usize,
    scratch: Vec<f32>,
}

impl StalenessProbe {
    pub fn new(spec: ModelSpec, measure_delta: bool) -> Self {
        let probe_batch = spec.micro_batches.iter().copied().min().unwrap_or(1).max(1);
        Self {
            spec,
            measure_delta,
            master_iteration: 0,
            master_params: Vec::new(),
            log: StalenessLog::new(),
            memo: HashMap::new(),
            probe_batch,
            scratch: Vec::new(),
        }
    }

    /// Install the parameters live for the upcoming serving window (the
    /// ones broadcast at the window's opening iteration boundary).  The
    /// copy is skipped when the delta probe is off — age bookkeeping only
    /// needs the iteration number.
    pub fn set_master(&mut self, iteration: u64, params: &[f32]) {
        self.master_iteration = iteration;
        if self.measure_delta {
            self.master_params.clear();
            self.master_params.extend_from_slice(params);
        }
        self.memo.clear();
    }

    pub fn log(&self) -> &StalenessLog {
        &self.log
    }

    pub fn into_log(self) -> StalenessLog {
        self.log
    }

    /// Fresh prediction for `input` under the live master parameters,
    /// memoized per master window.
    fn fresh(
        &mut self,
        input: &Arc<Vec<f32>>,
        compute: &mut dyn Compute,
    ) -> Result<(Vec<f32>, u32)> {
        let key = Arc::as_ptr(input) as usize;
        if let Some(hit) = self.memo.get(&key) {
            return Ok(hit.clone());
        }
        self.scratch.clear();
        for _ in 0..self.probe_batch {
            self.scratch.extend_from_slice(input);
        }
        let probs = compute.predict_batch(
            &self.spec.name,
            self.probe_batch,
            &self.master_params,
            &self.scratch,
            self.spec.classes,
        )?;
        let row = probs[..self.spec.classes].to_vec();
        let class = Prediction::from_row(&row).class as u32;
        let out = (row, class);
        self.memo.insert(key, out.clone());
        Ok(out)
    }
}

impl ServeObserver for StalenessProbe {
    fn on_response(
        &mut self,
        record: &RequestRecord,
        input: &Arc<Vec<f32>>,
        served: &Prediction,
        snapshot: SnapshotMeta,
        compute: &mut dyn Compute,
    ) -> Result<()> {
        let (delta, fresh_class) = if self.measure_delta {
            let (fresh_row, fresh_class) = self.fresh(input, compute)?;
            let delta: f64 = fresh_row
                .iter()
                .zip(&served.probs)
                .map(|(f, s)| (f - s).abs() as f64)
                .sum();
            (Some(delta), Some(fresh_class))
        } else {
            (None, None)
        };
        self.log.push(StalenessRecord {
            id: record.id,
            client: record.client,
            done_ms: record.done_ms,
            snapshot: snapshot.id,
            snapshot_iteration: snapshot.iteration,
            master_iteration: self.master_iteration,
            age_ms: (record.done_ms - snapshot.published_ms).max(0.0),
            delta,
            fresh_class,
            class: record.class,
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;
    use crate::runtime::ModeledCompute;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 12,
            batch_size: 4,
            micro_batches: vec![4, 2],
            input: vec![3, 1, 1],
            classes: 4,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![12],
                offset: 0,
                size: 12,
                fan_in: 3,
            }],
            artifacts: Default::default(),
        }
    }

    fn record(id: u64, class: u32) -> RequestRecord {
        RequestRecord {
            id,
            client: 0,
            sent_ms: 0.0,
            done_ms: 10.0,
            latency_ms: 10.0,
            shard: 0,
            snapshot: 1,
            batch_size: 1,
            cache_hit: false,
            coalesced: false,
            class,
        }
    }

    fn meta() -> SnapshotMeta {
        SnapshotMeta {
            id: 1,
            iteration: 2,
            published_ms: 4.0,
        }
    }

    #[test]
    fn identical_params_give_zero_delta() {
        let mut compute = ModeledCompute { param_count: 12 };
        let params: Vec<f32> = (0..12).map(|i| (i as f32 - 6.0) * 0.1).collect();
        let mut probe = StalenessProbe::new(spec(), true);
        probe.set_master(5, &params);
        let input = Arc::new(vec![0.3f32, 0.7, 0.1]);
        // Serve the same answer the live params would give.
        let row = crate::runtime::modeled_predict(1, &params, &input, 4).unwrap();
        let served = Prediction::from_row(&row);
        probe
            .on_response(&record(1, served.class as u32), &input, &served, meta(), &mut compute)
            .unwrap();
        let log = probe.into_log();
        assert_eq!(log.len(), 1);
        let r = &log.records()[0];
        assert_eq!(r.age_iters(), 3);
        assert_eq!(r.age_ms, 6.0);
        assert!(r.delta.unwrap() < 1e-6, "same params, same probs");
        assert_eq!(r.class_changed(), Some(false));
    }

    #[test]
    fn diverged_params_show_a_delta() {
        let mut compute = ModeledCompute { param_count: 12 };
        let stale: Vec<f32> = (0..12).map(|i| (i as f32 - 6.0) * 0.1).collect();
        let live: Vec<f32> = stale.iter().map(|p| -p).collect();
        let mut probe = StalenessProbe::new(spec(), true);
        probe.set_master(9, &live);
        let input = Arc::new(vec![0.9f32, 0.2, 0.4]);
        let row = crate::runtime::modeled_predict(1, &stale, &input, 4).unwrap();
        let served = Prediction::from_row(&row);
        probe
            .on_response(&record(1, served.class as u32), &input, &served, meta(), &mut compute)
            .unwrap();
        let r = &probe.log().records()[0];
        assert!(r.delta.unwrap() > 1e-3, "sign-flipped params must diverge");
    }

    #[test]
    fn probe_disabled_records_ages_only() {
        let mut compute = ModeledCompute { param_count: 12 };
        let mut probe = StalenessProbe::new(spec(), false);
        probe.set_master(4, &[0.0; 12]);
        let input = Arc::new(vec![0.1f32, 0.2, 0.3]);
        let served = Prediction {
            class: 1,
            confidence: 1.0,
            probs: vec![0.0, 1.0, 0.0, 0.0],
        };
        probe
            .on_response(&record(7, 1), &input, &served, meta(), &mut compute)
            .unwrap();
        let r = &probe.log().records()[0];
        assert_eq!(r.delta, None);
        assert_eq!(r.fresh_class, None);
        assert_eq!(r.age_iters(), 2);
    }

    #[test]
    fn memo_resets_when_the_master_window_advances() {
        let mut compute = ModeledCompute { param_count: 12 };
        let p1: Vec<f32> = (0..12).map(|i| i as f32 * 0.1).collect();
        let p2: Vec<f32> = (0..12).map(|i| -(i as f32) * 0.1).collect();
        let mut probe = StalenessProbe::new(spec(), true);
        let input = Arc::new(vec![0.5f32, 0.5, 0.5]);
        let served = {
            let row = crate::runtime::modeled_predict(1, &p1, &input, 4).unwrap();
            Prediction::from_row(&row)
        };
        probe.set_master(1, &p1);
        probe
            .on_response(&record(1, served.class as u32), &input, &served, meta(), &mut compute)
            .unwrap();
        assert!(probe.log().records()[0].delta.unwrap() < 1e-6);
        // New window with different live params: the memo must not serve
        // the old fresh row.
        probe.set_master(2, &p2);
        probe
            .on_response(&record(2, served.class as u32), &input, &served, meta(), &mut compute)
            .unwrap();
        assert!(probe.log().records()[1].delta.unwrap() > 1e-3);
    }
}
