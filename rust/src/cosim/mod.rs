//! Serve × train co-simulation — MLitB's two pillars on one clock, for
//! the paper's multi-tenant master (§3.1: one master hosts *several
//! projects*, each with its own model, data and clients).
//!
//! The paper's deployment story is *one* system: masters train with
//! their volunteer fleets **while** the public queries the current
//! models (§2.3's "prediction to the public at large" is served by the
//! same master that runs §3.3's event loop).  This repo grew those
//! pillars as two discrete-event simulations — [`crate::sim`] for
//! training, [`crate::serve`] for prediction.  This module couples them:
//!
//! * [`run_cosim`] drives N project masters and one shared serving tier
//!   on one **shared virtual clock**: each master's training iteration
//!   advances its own boundary by its wall time; the driver processes
//!   boundaries in global time order and the serving engine
//!   ([`crate::serve::ServeEngine`]) pumps every request arrival and
//!   batch flush between them.
//! * At its own boundaries each project's [`PublicationPolicy`] decides
//!   whether to publish the live parameters — every k iterations, and/or
//!   when the tracked test error improves by δ for m consecutive
//!   evaluations (hysteresis: eval noise cannot flap versions).
//!   Publication is **byte-accounted**: the snapshot stages, its
//!   `param_count × 4` bytes queue on the shared [`EgressBudget`], and
//!   the version activates only when the transfer completes — concurrent
//!   publishers serialize, and a large model visibly delays its own
//!   activation.  Hot swaps keep the answer-consistency guarantees:
//!   a request is computed entirely against the typed `ModelVersion`
//!   it was admitted under (version-stamped requests,
//!   version-pure — and so project-pure — batches, per-version registry
//!   reader pins), and traffic-driven GC reclaims versions only once
//!   retention, zero in-flight readers *and* no staged transfer agree.
//! * A [`StalenessProbe`] tags every served answer with the age of the
//!   snapshot that produced it relative to **its own project's** master
//!   (iterations + virtual ms) and, when enabled, the prediction delta
//!   against that master's live parameters — the
//!   [`crate::metrics::StalenessLog`] behind the `fig_cosim`
//!   staleness-vs-latency frontier and the `fig_multitenant` tables.
//!
//! Entry points: `mlitb cosim [--projects N]`, `benches/fig_cosim.rs`,
//! `benches/fig_multitenant.rs`, `examples/cosim.rs`,
//! `tests/integration_cosim.rs`.

mod driver;
mod probe;
mod publish;

pub use driver::{
    run_cosim, run_cosim_durable, run_cosim_traced, CosimConfig, CosimDurability, CosimProject,
    CosimReport,
};
pub use probe::StalenessProbe;
pub use publish::{
    EgressBudget, PublicationPolicy, PublicationRecord, PublicationState, PublishTrigger,
};
