//! Serve × train co-simulation — MLitB's two pillars on one clock.
//!
//! The paper's deployment story is *one* system: the master trains with
//! its volunteer fleet **while** the public queries the current model
//! (§2.3's "prediction to the public at large" is served by the same
//! master that runs §3.3's event loop).  This repo grew those pillars as
//! two disconnected discrete-event simulations — [`crate::sim`] for
//! training, [`crate::serve`] for prediction.  This module couples them:
//!
//! * [`run_cosim`] drives both on one **shared virtual clock**: each
//!   training iteration advances the clock by its wall time, then the
//!   serving engine ([`crate::serve::ServeEngine`]) pumps every request
//!   arrival and batch flush inside that window.
//! * At iteration boundaries a [`PublicationPolicy`] decides whether the
//!   master publishes its live parameters into the serving registry —
//!   every k iterations, and/or when the tracked test error improves by
//!   δ.  Publication **hot-swaps** the active version mid-traffic with
//!   answer-consistency guarantees: a request is computed entirely
//!   against the snapshot it was admitted under (version-stamped
//!   requests, version-pure batches, per-version registry reader pins),
//!   and traffic-driven GC reclaims versions only once retention *and*
//!   zero in-flight readers agree.
//! * A [`StalenessProbe`] tags every served answer with the age of the
//!   snapshot that produced it (iterations + virtual ms) and, when
//!   enabled, the prediction delta against the live master parameters —
//!   the [`crate::metrics::StalenessLog`] behind the `fig_cosim`
//!   staleness-vs-latency frontier.
//!
//! Entry points: `mlitb cosim`, `benches/fig_cosim.rs`,
//! `examples/cosim.rs`, `tests/integration_cosim.rs`.

mod driver;
mod probe;
mod publish;

pub use driver::{run_cosim, CosimConfig, CosimReport};
pub use probe::StalenessProbe;
pub use publish::{PublicationPolicy, PublicationRecord, PublishTrigger};
