//! When does the live master push a snapshot to the serving tier?
//!
//! Two triggers, combinable: a fixed cadence (every k iterations — the
//! predictable freshness floor), and an error-improvement trigger (the
//! tracker's test error beat the best-yet-published model by δ — publish
//! good models early, skip publishing plateau noise).  The cadence is
//! checked first so a run with both configured attributes each
//! publication to one deterministic cause.

use crate::serve::SnapshotId;

/// Why a snapshot was published.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PublishTrigger {
    /// The iteration-0 parameters the run starts serving from.
    Initial,
    /// The every-k-iterations cadence came due.
    Cadence,
    /// Tracked test error improved on the best published model by ≥ δ.
    ErrorImprovement,
}

impl PublishTrigger {
    pub fn name(self) -> &'static str {
        match self {
            Self::Initial => "initial",
            Self::Cadence => "cadence",
            Self::ErrorImprovement => "error",
        }
    }
}

/// Publication decision knobs.
#[derive(Debug, Clone, Copy)]
pub struct PublicationPolicy {
    /// Publish every k iterations (0 disables the cadence trigger).
    pub every: u64,
    /// Publish when the tracked test error improves on the best published
    /// model by at least this much (0.0 disables; requires the training
    /// run to track test error at all).
    pub min_improvement: f64,
}

impl PublicationPolicy {
    /// Cadence-only policy (the common `--publish-every k` shape).
    pub fn every(k: u64) -> Self {
        Self {
            every: k,
            min_improvement: 0.0,
        }
    }

    /// Decide at an iteration boundary.  `best_published_error` is the
    /// lowest tracked error among published snapshots so far (`None`
    /// until an error-triggered or error-observed publication happened —
    /// the first tracked error then always counts as an improvement).
    pub fn decide(
        &self,
        iteration: u64,
        last_published_iteration: u64,
        test_error: Option<f64>,
        best_published_error: Option<f64>,
    ) -> Option<PublishTrigger> {
        if self.every > 0 && iteration.saturating_sub(last_published_iteration) >= self.every {
            return Some(PublishTrigger::Cadence);
        }
        if self.min_improvement > 0.0 {
            if let Some(err) = test_error {
                let improved = match best_published_error {
                    Some(best) => best - err >= self.min_improvement,
                    None => true,
                };
                if improved {
                    return Some(PublishTrigger::ErrorImprovement);
                }
            }
        }
        None
    }
}

/// One publication event in a co-simulation run.
#[derive(Debug, Clone, PartialEq)]
pub struct PublicationRecord {
    /// Version assigned by the registry.
    pub snapshot: SnapshotId,
    /// Training iteration the parameters captured.
    pub iteration: u64,
    /// Virtual publish time (ms).
    pub t_ms: f64,
    pub trigger: PublishTrigger,
    /// Versions traffic-driven GC reclaimed at this publication.
    pub evicted: Vec<SnapshotId>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cadence_fires_every_k() {
        let p = PublicationPolicy::every(5);
        assert_eq!(p.decide(4, 0, None, None), None);
        assert_eq!(p.decide(5, 0, None, None), Some(PublishTrigger::Cadence));
        assert_eq!(p.decide(9, 5, None, None), None);
        assert_eq!(p.decide(10, 5, None, None), Some(PublishTrigger::Cadence));
    }

    #[test]
    fn zero_cadence_never_fires() {
        let p = PublicationPolicy::every(0);
        assert_eq!(p.decide(1_000, 0, None, None), None);
    }

    #[test]
    fn error_trigger_requires_delta_improvement() {
        let p = PublicationPolicy {
            every: 0,
            min_improvement: 0.05,
        };
        // No tracked error → nothing to trigger on.
        assert_eq!(p.decide(3, 0, None, None), None);
        // First tracked error beats "nothing published yet".
        assert_eq!(
            p.decide(3, 0, Some(0.9), None),
            Some(PublishTrigger::ErrorImprovement)
        );
        // 0.9 → 0.87 is under δ; 0.9 → 0.8 clears it.
        assert_eq!(p.decide(4, 3, Some(0.87), Some(0.9)), None);
        assert_eq!(
            p.decide(5, 3, Some(0.8), Some(0.9)),
            Some(PublishTrigger::ErrorImprovement)
        );
    }

    #[test]
    fn cadence_wins_attribution_when_both_fire() {
        let p = PublicationPolicy {
            every: 2,
            min_improvement: 0.01,
        };
        assert_eq!(
            p.decide(2, 0, Some(0.5), Some(0.9)),
            Some(PublishTrigger::Cadence)
        );
    }
}
