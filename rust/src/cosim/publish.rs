//! When — and at what byte cost — does a live master push a snapshot to
//! the serving tier?
//!
//! **Triggers**, combinable per project: a fixed cadence (every k
//! iterations — the predictable freshness floor), and an
//! error-improvement trigger (the tracker's test error beat the best-yet
//! published model by δ — publish good models early, skip publishing
//! plateau noise).  The cadence is checked first so a run with both
//! configured attributes each publication to one deterministic cause.
//! The error trigger carries **hysteresis**: the improvement must
//! persist for m consecutive evaluations before a publish fires, so
//! eval-error noise cannot flap versions (ROADMAP throttling item).
//!
//! **Cost** ([`EgressBudget`]): a snapshot is `param_count × 4` bytes
//! that must cross the master-egress link before activation.  The budget
//! is shared across every publishing project (the paper's one master
//! hosts several projects, §3.1): transfers serialize at `bytes_per_min`,
//! so two projects publishing in the same window queue behind each other
//! and a 100 MB-param model visibly delays its own activation.

use crate::serve::{ModelVersion, ProjectId};

/// Why a snapshot was published.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PublishTrigger {
    /// The iteration-0 parameters the run starts serving from.
    Initial,
    /// The every-k-iterations cadence came due.
    Cadence,
    /// Tracked test error improved on the best published model by ≥ δ,
    /// for the policy's hysteresis streak.
    ErrorImprovement,
}

impl PublishTrigger {
    pub fn name(self) -> &'static str {
        match self {
            Self::Initial => "initial",
            Self::Cadence => "cadence",
            Self::ErrorImprovement => "error",
        }
    }
}

/// Publication decision knobs (per project).
#[derive(Debug, Clone, Copy)]
pub struct PublicationPolicy {
    /// Publish every k iterations (0 disables the cadence trigger).
    pub every: u64,
    /// Publish when the tracked test error improves on the best published
    /// model by at least this much (0.0 disables; requires the training
    /// run to track test error at all).
    pub min_improvement: f64,
    /// Hysteresis: the δ improvement must persist for this many
    /// *consecutive evaluations* before the error trigger fires (0 and 1
    /// both mean "publish on the first improved evaluation").  Untracked
    /// iterations neither extend nor break the streak.
    pub hysteresis: u64,
}

impl PublicationPolicy {
    /// Cadence-only policy (the common `--publish-every k` shape).
    pub fn every(k: u64) -> Self {
        Self {
            every: k,
            min_improvement: 0.0,
            hysteresis: 0,
        }
    }
}

/// Mutable per-project decision state the policy folds over: last
/// publication, best published error, and the hysteresis streak.
#[derive(Debug, Clone, Default)]
pub struct PublicationState {
    last_published_iteration: u64,
    best_published_error: Option<f64>,
    /// Lowest tracked error seen so far — the improvement reference while
    /// nothing has been published yet (without it, every pre-publish
    /// evaluation would count as "improved" and a regression could not
    /// break the streak).
    best_seen_error: Option<f64>,
    /// Consecutive evaluations that cleared the δ bar since the last
    /// publication (or last regression).
    streak: u64,
}

impl PublicationState {
    pub fn last_published_iteration(&self) -> u64 {
        self.last_published_iteration
    }

    pub fn best_published_error(&self) -> Option<f64> {
        self.best_published_error
    }

    pub fn streak(&self) -> u64 {
        self.streak
    }
}

impl PublicationPolicy {
    /// Decide at an iteration boundary, folding the observation into
    /// `state`.  When a trigger fires, `state` is updated as-published
    /// (streak reset, best error absorbed) — the caller just stages the
    /// snapshot.
    pub fn decide(
        &self,
        state: &mut PublicationState,
        iteration: u64,
        test_error: Option<f64>,
    ) -> Option<PublishTrigger> {
        // Hysteresis bookkeeping happens on every *evaluation*, whatever
        // ends up triggering: an improved eval extends the streak, a
        // regressed one breaks it.  The improvement reference is the best
        // *published* error once something shipped, and the best error
        // *seen* before that (the very first evaluation always counts).
        if self.min_improvement > 0.0 {
            if let Some(err) = test_error {
                let reference = state.best_published_error.or(state.best_seen_error);
                let improved = reference.is_none_or(|best| best - err >= self.min_improvement);
                if improved {
                    state.streak += 1;
                } else {
                    state.streak = 0;
                }
                state.best_seen_error =
                    Some(state.best_seen_error.map_or(err, |b| b.min(err)));
            }
        }
        let cadence_due = self.every > 0
            && iteration.saturating_sub(state.last_published_iteration) >= self.every;
        let error_due = self.min_improvement > 0.0
            && test_error.is_some()
            && state.streak >= self.hysteresis.max(1);
        let trigger = if cadence_due {
            Some(PublishTrigger::Cadence)
        } else if error_due {
            Some(PublishTrigger::ErrorImprovement)
        } else {
            None
        };
        if trigger.is_some() {
            state.last_published_iteration = iteration;
            if let Some(err) = test_error {
                state.best_published_error =
                    Some(state.best_published_error.map_or(err, |b| b.min(err)));
            }
            state.streak = 0;
        }
        trigger
    }
}

/// The shared master-egress budget: snapshot transfers serialize at
/// `bytes_per_min` across every publishing project.  `bytes_per_min ≤ 0`
/// means unthrottled (transfers complete instantly) — bytes are still
/// accounted.
#[derive(Debug, Clone)]
pub struct EgressBudget {
    bytes_per_min: f64,
    free_at_ms: f64,
    bytes_sent: u64,
}

impl EgressBudget {
    pub fn new(bytes_per_min: f64) -> Self {
        Self {
            bytes_per_min,
            free_at_ms: 0.0,
            bytes_sent: 0,
        }
    }

    /// Schedule a transfer of `bytes` requested at `now_ms`; returns its
    /// completion (= activation) time.  Transfers queue: a second
    /// publisher starts only when the link frees up.
    pub fn schedule(&mut self, now_ms: f64, bytes: u64) -> f64 {
        self.bytes_sent += bytes;
        let start = self.free_at_ms.max(now_ms);
        let done = if self.bytes_per_min <= 0.0 {
            start
        } else {
            start + bytes as f64 * 60_000.0 / self.bytes_per_min
        };
        self.free_at_ms = done;
        done
    }

    /// Master-egress bytes charged so far.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    /// How far behind the link is at `now_ms`: milliseconds of queued
    /// transfer still to drain (0 when idle) — the `publish/egress`
    /// counter's occupancy series.
    pub fn backlog_ms(&self, now_ms: f64) -> f64 {
        (self.free_at_ms - now_ms).max(0.0)
    }
}

/// One publication event in a co-simulation run.
#[derive(Debug, Clone, PartialEq)]
pub struct PublicationRecord {
    /// Typed version handle the registry assigned (names the project).
    pub version: ModelVersion,
    /// Training iteration the parameters captured (publication decision).
    pub iteration: u64,
    /// Virtual publish-decision time (ms) — when the transfer was queued.
    pub t_ms: f64,
    /// Snapshot bytes charged to master egress (`param_count × 4`; 0 for
    /// the free initial publication).
    pub bytes: u64,
    /// Transfer completion = activation time (== `t_ms` when the budget
    /// is unthrottled and the link idle).
    pub activated_ms: f64,
    /// The owning project's master iteration when activation landed —
    /// strictly greater than `iteration` when the transfer outlived the
    /// publication window.
    pub activated_iteration: u64,
    pub trigger: PublishTrigger,
    /// Versions traffic-driven GC reclaimed at this publication.
    pub evicted: Vec<ModelVersion>,
}

impl PublicationRecord {
    pub fn project(&self) -> ProjectId {
        self.version.project
    }

    /// How long the snapshot spent on the egress link (ms).
    pub fn transfer_ms(&self) -> f64 {
        self.activated_ms - self.t_ms
    }

    /// Iterations between the publication decision and activation.
    pub fn activation_lag_iters(&self) -> u64 {
        self.activated_iteration.saturating_sub(self.iteration)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decide_seq(
        policy: &PublicationPolicy,
        observations: &[(u64, Option<f64>)],
    ) -> Vec<Option<PublishTrigger>> {
        let mut state = PublicationState::default();
        observations
            .iter()
            .map(|&(iter, err)| policy.decide(&mut state, iter, err))
            .collect()
    }

    #[test]
    fn cadence_fires_every_k() {
        let p = PublicationPolicy::every(5);
        let fired = decide_seq(
            &p,
            &[(4, None), (5, None), (9, None), (10, None)],
        );
        assert_eq!(
            fired,
            vec![
                None,
                Some(PublishTrigger::Cadence),
                None,
                Some(PublishTrigger::Cadence)
            ]
        );
    }

    #[test]
    fn zero_cadence_never_fires() {
        let p = PublicationPolicy::every(0);
        let mut state = PublicationState::default();
        assert_eq!(p.decide(&mut state, 1_000, None), None);
    }

    #[test]
    fn error_trigger_requires_delta_improvement() {
        let p = PublicationPolicy {
            every: 0,
            min_improvement: 0.05,
            hysteresis: 0,
        };
        let mut state = PublicationState::default();
        // No tracked error → nothing to trigger on.
        assert_eq!(p.decide(&mut state, 3, None), None);
        // First tracked error beats "nothing published yet".
        assert_eq!(
            p.decide(&mut state, 3, Some(0.9)),
            Some(PublishTrigger::ErrorImprovement)
        );
        assert_eq!(state.best_published_error(), Some(0.9));
        // 0.9 → 0.87 is under δ; 0.9 → 0.8 clears it.
        assert_eq!(p.decide(&mut state, 4, Some(0.87)), None);
        assert_eq!(
            p.decide(&mut state, 5, Some(0.8)),
            Some(PublishTrigger::ErrorImprovement)
        );
        assert_eq!(state.best_published_error(), Some(0.8));
        assert_eq!(state.last_published_iteration(), 5);
    }

    #[test]
    fn cadence_wins_attribution_when_both_fire() {
        let p = PublicationPolicy {
            every: 2,
            min_improvement: 0.01,
            hysteresis: 0,
        };
        let mut state = PublicationState::default();
        assert_eq!(
            p.decide(&mut state, 2, Some(0.5)),
            Some(PublishTrigger::Cadence)
        );
        // The cadence publish still absorbed the error as best-published.
        assert_eq!(state.best_published_error(), Some(0.5));
    }

    #[test]
    fn hysteresis_requires_persistent_improvement() {
        // m = 3: three consecutive improved evaluations before a publish.
        let p = PublicationPolicy {
            every: 0,
            min_improvement: 0.05,
            hysteresis: 3,
        };
        let mut state = PublicationState::default();
        assert_eq!(p.decide(&mut state, 1, Some(0.9)), None);
        assert_eq!(state.streak(), 1);
        // Untracked iterations neither extend nor break the streak.
        assert_eq!(p.decide(&mut state, 2, None), None);
        assert_eq!(state.streak(), 1);
        assert_eq!(p.decide(&mut state, 3, Some(0.85)), None);
        assert_eq!(
            p.decide(&mut state, 4, Some(0.8)),
            Some(PublishTrigger::ErrorImprovement)
        );
        assert_eq!(state.streak(), 0, "publish resets the streak");
        // A regression mid-streak starts the count over.
        assert_eq!(p.decide(&mut state, 5, Some(0.7)), None); // streak 1
        assert_eq!(p.decide(&mut state, 6, Some(0.9)), None); // regressed: 0
        assert_eq!(p.decide(&mut state, 7, Some(0.7)), None); // streak 1
        assert_eq!(p.decide(&mut state, 8, Some(0.65)), None); // streak 2
        assert_eq!(
            p.decide(&mut state, 9, Some(0.6)),
            Some(PublishTrigger::ErrorImprovement)
        );
    }

    #[test]
    fn hysteresis_stops_version_flapping() {
        // The flap-count regression: a noisily descending error — every
        // even eval dips below the best by ≥ δ, every odd eval spikes
        // back up.  With m ≤ 1 each dip publishes (versions flap on eval
        // noise); with m = 2 the improvement never *persists* two evals
        // in a row, so nothing publishes.
        let noisy: Vec<(u64, Option<f64>)> = (0u64..20)
            .map(|i| {
                let err = if i % 2 == 0 {
                    0.40 - 0.06 * (i / 2) as f64
                } else {
                    0.9
                };
                (i, Some(err))
            })
            .collect();
        let flappy = PublicationPolicy {
            every: 0,
            min_improvement: 0.05,
            hysteresis: 1,
        };
        let steady = PublicationPolicy {
            every: 0,
            min_improvement: 0.05,
            hysteresis: 2,
        };
        let flaps = decide_seq(&flappy, &noisy)
            .iter()
            .filter(|t| t.is_some())
            .count();
        let publishes = decide_seq(&steady, &noisy)
            .iter()
            .filter(|t| t.is_some())
            .count();
        assert!(flaps >= 5, "noise must flap the no-hysteresis policy: {flaps}");
        assert_eq!(publishes, 0, "hysteresis 2 must ride out alternating noise");
    }

    #[test]
    fn egress_budget_serializes_concurrent_transfers() {
        // 600 KB/min = 10 KB/s.  Two 20 KB snapshots queued at t=0: the
        // first takes 2 s, the second starts only when the link frees.
        let mut budget = EgressBudget::new(600_000.0);
        let first = budget.schedule(0.0, 20_000);
        assert!((first - 2_000.0).abs() < 1e-6, "{first}");
        let second = budget.schedule(0.0, 20_000);
        assert!((second - 4_000.0).abs() < 1e-6, "{second}");
        // A later request on an idle link starts at its own time.
        let third = budget.schedule(10_000.0, 10_000);
        assert!((third - 11_000.0).abs() < 1e-6, "{third}");
        assert_eq!(budget.bytes_sent(), 50_000);
        // Backlog drains linearly and clamps at 0 once the link idles.
        assert!((budget.backlog_ms(10_500.0) - 500.0).abs() < 1e-6);
        assert_eq!(budget.backlog_ms(11_000.0), 0.0);
        assert_eq!(budget.backlog_ms(20_000.0), 0.0);
    }

    #[test]
    fn unthrottled_budget_is_instant_but_accounted() {
        let mut budget = EgressBudget::new(0.0);
        assert_eq!(budget.schedule(5.0, 1_000_000), 5.0);
        assert_eq!(budget.schedule(7.0, 1_000_000), 7.0);
        assert_eq!(budget.bytes_sent(), 2_000_000);
    }

    #[test]
    fn publication_record_lag_helpers() {
        let rec = PublicationRecord {
            version: ModelVersion {
                project: ProjectId::new(1),
                version: 3,
            },
            iteration: 4,
            t_ms: 8_000.0,
            bytes: 50_920,
            activated_ms: 14_000.0,
            activated_iteration: 7,
            trigger: PublishTrigger::Cadence,
            evicted: Vec::new(),
        };
        assert_eq!(rec.project(), ProjectId::new(1));
        assert_eq!(rec.transfer_ms(), 6_000.0);
        assert_eq!(rec.activation_lag_iters(), 3);
    }
}
