//! The co-simulation driver: training master and serving tier stepping
//! one shared virtual clock.
//!
//! Loop shape (one training iteration = one serving window):
//!
//! 1. Capture the master's live parameters — they are what the fleet's
//!    broadcast installed at the window's opening boundary, and what the
//!    staleness probe compares served answers against.
//! 2. `Simulation::step()` advances the clock to the next iteration
//!    boundary (`wall_ms` includes the sync barrier's slowest-worker
//!    wait, so serving load sees the *real* cadence, stragglers and all).
//! 3. `ServeEngine::pump(Some(boundary))` serves every request arrival
//!    and batch flush inside the window against the registry as-is.
//! 4. At the boundary, the [`PublicationPolicy`] may publish the freshly
//!    reduced parameters — a hot swap for all subsequent admissions —
//!    and traffic-driven GC reclaims unpinned stale versions.
//!
//! After the last iteration a final unbounded pump drains the remaining
//! schedule (open-loop arrivals may outlast training).

use anyhow::{anyhow, Result};

use crate::metrics::StalenessLog;
use crate::model::ModelSpec;
use crate::runtime::Compute;
use crate::serve::{ServeConfig, ServeEngine, ServeReport, SnapshotRegistry};
use crate::sim::{RunReport, SimConfig, Simulation};

use super::probe::StalenessProbe;
use super::publish::{PublicationPolicy, PublicationRecord, PublishTrigger};

/// Everything one co-simulation run needs besides the compute backends.
#[derive(Debug, Clone)]
pub struct CosimConfig {
    pub train: SimConfig,
    pub serve: ServeConfig,
    pub publish: PublicationPolicy,
    /// Registry retention: keep the newest N versions (the active version
    /// and pinned versions always survive).
    pub retain: usize,
    /// Re-predict each served answer against the live master parameters
    /// (prediction delta + class flips).  Costs one extra execution per
    /// distinct input per iteration.
    pub measure_delta: bool,
}

/// Outcome of one co-simulation run.
#[derive(Debug, Clone)]
pub struct CosimReport {
    pub train: RunReport,
    pub serve: ServeReport,
    pub staleness: StalenessLog,
    /// Every publication, in order (index 0 is the initial snapshot).
    pub publications: Vec<PublicationRecord>,
    /// Versions reclaimed by traffic-driven GC over the run.
    pub evicted: u64,
    /// Versions resident in the registry at end of run.
    pub resident: usize,
}

impl CosimReport {
    /// One-line human summary: staleness beside latency.  Quantiles and
    /// the probe's delta print as `-` when unmeasured (empty run, or the
    /// delta probe disabled).
    pub fn summary(&self) -> String {
        let age = self.staleness.age_iters_summary();
        let lat = self.serve.latency();
        let ms = |v: f64| {
            if v.is_finite() {
                format!("{v:.1}")
            } else {
                "-".into()
            }
        };
        let delta = self.staleness.delta_summary();
        let delta_mean = if delta.is_empty() {
            "-".to_string()
        } else {
            format!("{:.4}", delta.mean())
        };
        format!(
            "pubs={} evicted={} resident={} age_iters p50={} p99={} \
             delta_mean={delta_mean} stale_class={:.3} latency p50={}ms p99={}ms completed={}",
            self.publications.len(),
            self.evicted,
            self.resident,
            ms(age.median()),
            ms(age.quantile(0.99)),
            self.staleness.stale_class_rate(),
            ms(lat.median()),
            ms(lat.quantile(0.99)),
            self.serve.completed,
        )
    }
}

/// Run the co-simulation to completion.  `train_compute` backs the
/// master's gradient/eval work, `serve_compute` the prediction tier (two
/// backends because each side holds its own mutable borrow for the whole
/// run; modeled runs pass two instances of the same scorer).
pub fn run_cosim(
    cfg: &CosimConfig,
    spec: &ModelSpec,
    train_compute: &mut dyn Compute,
    serve_compute: &mut dyn Compute,
) -> Result<CosimReport> {
    let mut sim = Simulation::new(cfg.train.clone(), spec.clone(), train_compute);
    let mut registry = SnapshotRegistry::new(spec.clone());
    let mut engine = ServeEngine::new(&cfg.serve, spec);
    let mut probe = StalenessProbe::new(spec.clone(), cfg.measure_delta);
    let retain = cfg.retain.max(1);

    // The run starts serving the iteration-0 parameters.
    let v0 = registry
        .publish_params(
            sim.master().params().to_vec(),
            0,
            "cosim: initial".into(),
            0.0,
        )
        .map_err(|e| anyhow!(e))?;
    let mut publications = vec![PublicationRecord {
        snapshot: v0,
        iteration: 0,
        t_ms: 0.0,
        trigger: PublishTrigger::Initial,
        evicted: Vec::new(),
    }];
    let mut last_pub_iter = 0u64;
    let mut best_pub_error: Option<f64> = None;
    let mut evicted_total = 0u64;

    for _ in 0..cfg.train.iterations {
        // Live parameters for the upcoming window: what the boundary
        // broadcast installed (training recomputes *during* the window
        // and applies at its close).
        probe.set_master(sim.master().iteration(), sim.master().params());
        sim.step()?;
        let boundary_ms = sim.master().now_ms();
        engine.pump(Some(boundary_ms), &mut registry, serve_compute, &mut probe)?;

        let iteration = sim.master().iteration();
        let test_error = sim.master().timeline().last().and_then(|r| r.test_error);
        if let Some(trigger) =
            cfg.publish
                .decide(iteration, last_pub_iter, test_error, best_pub_error)
        {
            let id = registry
                .publish_params(
                    sim.master().params().to_vec(),
                    iteration,
                    format!("cosim: {} @ iter {iteration}", trigger.name()),
                    boundary_ms,
                )
                .map_err(|e| anyhow!(e))?;
            last_pub_iter = iteration;
            if let Some(err) = test_error {
                best_pub_error = Some(best_pub_error.map_or(err, |b| b.min(err)));
            }
            // Traffic-driven GC: retention and reader refcounts must both
            // agree before a version goes.
            let evicted = registry.gc_keep_latest(retain);
            evicted_total += evicted.len() as u64;
            publications.push(PublicationRecord {
                snapshot: id,
                iteration,
                t_ms: boundary_ms,
                trigger,
                evicted,
            });
        }
    }

    // Drain the serving tail: arrivals after the last boundary plus any
    // batches still queued, against the final published state.
    probe.set_master(sim.master().iteration(), sim.master().params());
    engine.pump(None, &mut registry, serve_compute, &mut probe)?;
    debug_assert_eq!(
        registry.total_readers(),
        0,
        "drained run must release every reader pin"
    );

    let train = RunReport::from_timeline(sim.master().timeline().clone(), sim.n_clients());
    Ok(CosimReport {
        train,
        serve: engine.into_report(),
        staleness: probe.into_log(),
        publications,
        evicted: evicted_total,
        resident: registry.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::DeviceClass;
    use crate::metrics::StalenessRecord;
    use crate::model::TensorSpec;
    use crate::netsim::LinkProfile;
    use crate::runtime::ModeledCompute;
    use crate::serve::{
        BatchPolicy, ClientSpec, FleetConfig, RouterConfig, ServerProfile,
    };

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 8,
            batch_size: 16,
            micro_batches: vec![16, 4, 1],
            input: vec![28, 28, 1],
            classes: 10,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![8],
                offset: 0,
                size: 8,
                fan_in: 4,
            }],
            artifacts: Default::default(),
        }
    }

    fn cfg(iterations: u64, publish_every: u64) -> CosimConfig {
        let spec = spec();
        let mut train = SimConfig::paper_scaling(2, &spec);
        train.train_size = 300;
        train.test_size = 32;
        train.iterations = iterations;
        train.master.capacity = 100;
        train.track_every = 2;
        let serve = ServeConfig {
            fleet: FleetConfig {
                groups: vec![ClientSpec {
                    link: LinkProfile::Lan,
                    rate_rps: 5.0,
                    count: 3,
                }],
                duration_s: iterations as f64 * 4.0,
                input_pool: 8,
                seed: 13,
            },
            policy: BatchPolicy {
                max_batch: 16,
                max_wait_ms: 5.0,
                queue_depth: 256,
            },
            server: ServerProfile::default(),
            router: RouterConfig::single(),
            shard_profiles: Vec::new(),
            drained_shards: Vec::new(),
            cache_capacity: 0,
            response_bytes: 256,
        };
        CosimConfig {
            train,
            serve,
            publish: PublicationPolicy::every(publish_every),
            retain: 2,
            measure_delta: true,
        }
    }

    fn run(cfg: &CosimConfig) -> CosimReport {
        let mut train_compute = ModeledCompute { param_count: 8 };
        let mut serve_compute = ModeledCompute { param_count: 8 };
        run_cosim(cfg, &spec(), &mut train_compute, &mut serve_compute).unwrap()
    }

    #[test]
    fn cosim_reconciles_and_publishes_on_cadence() {
        let report = run(&cfg(6, 2));
        // Serving accounting holds under the shared clock.
        assert!(report.serve.offered > 0);
        assert_eq!(
            report.serve.completed + report.serve.rejected,
            report.serve.offered
        );
        // One staleness record per completed request.
        assert_eq!(report.staleness.len() as u64, report.serve.completed);
        // Initial + cadence at iterations 2, 4, 6.
        assert_eq!(report.publications.len(), 4);
        assert_eq!(report.publications[0].trigger, PublishTrigger::Initial);
        assert_eq!(
            report
                .publications
                .iter()
                .skip(1)
                .map(|p| p.iteration)
                .collect::<Vec<_>>(),
            vec![2, 4, 6]
        );
        // Training really ran on the same clock.
        assert_eq!(report.train.timeline.len(), 6);
        assert!(report.train.virtual_secs >= 24.0);
        // Retention (2) bounds the registry; pins all released.
        assert!(report.resident <= 2);
        assert_eq!(report.evicted, 2, "4 published − 2 retained");
        // Every served request names a published version, and its age in
        // iterations is bounded by the run.
        let published: Vec<u64> = report.publications.iter().map(|p| p.snapshot).collect();
        for r in report.staleness.records() {
            assert!(published.contains(&r.snapshot), "{r:?}");
            assert!(r.age_iters() <= 6, "{r:?}");
            assert!(r.age_ms >= 0.0);
        }
    }

    #[test]
    fn cosim_is_deterministic() {
        let a = run(&cfg(4, 2));
        let b = run(&cfg(4, 2));
        assert_eq!(a.staleness.to_csv(), b.staleness.to_csv());
        assert_eq!(a.serve.log.to_csv(), b.serve.log.to_csv());
        assert_eq!(a.summary(), b.summary());
    }

    #[test]
    fn publish_every_iteration_keeps_answers_fresh() {
        let report = run(&cfg(6, 1));
        // With a snapshot at every boundary, no served answer can lag by
        // more than the one-iteration publication pipeline.
        let max_age = report
            .staleness
            .records()
            .iter()
            .map(StalenessRecord::age_iters)
            .max()
            .unwrap_or(0);
        assert!(max_age <= 1, "cadence-1 run saw age {max_age}");
        // ModeledCompute training never moves the parameters, so stale
        // answers equal fresh ones exactly.
        assert!(report.staleness.delta_summary().max() < 1e-9);
        assert_eq!(report.staleness.stale_class_rate(), 0.0);
    }

    #[test]
    fn publish_never_means_growing_staleness() {
        let report = run(&cfg(6, 0));
        assert_eq!(report.publications.len(), 1, "initial only");
        assert_eq!(report.evicted, 0);
        // Ages grow with the master: late responses lag by many
        // iterations.
        let max_age = report
            .staleness
            .records()
            .iter()
            .map(StalenessRecord::age_iters)
            .max()
            .unwrap_or(0);
        assert!(max_age >= 4, "never-publish run saw max age {max_age}");
    }

    #[test]
    fn churn_and_cosim_compose() {
        // The shared clock must survive fleet churn mid-run.
        let mut config = cfg(5, 2);
        config
            .train
            .churn
            .insert(2, vec![crate::sim::ChurnEvent::Join(DeviceClass::Mobile)]);
        let report = run(&config);
        assert_eq!(report.train.timeline.len(), 5);
        assert!(report.serve.completed > 0);
    }
}
