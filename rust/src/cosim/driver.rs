//! The co-simulation driver: N training masters (one per hosted project)
//! and one shared serving tier stepping a single virtual clock.
//!
//! Per project, the loop shape is unchanged from the single-tenant
//! driver (one training iteration = one serving window):
//!
//! 1. Capture the project master's live parameters — they are what the
//!    fleet's broadcast installed at the window's opening boundary, and
//!    what the staleness probe compares served answers against.
//! 2. `Simulation::step()` advances that master to its next iteration
//!    boundary (`wall_ms` includes the sync barrier's slowest-worker
//!    wait, so serving load sees the *real* cadence, stragglers and all).
//! 3. The serving engine pumps every request arrival and batch flush up
//!    to the boundary against the control plane as-is.
//! 4. At the boundary, the project's [`PublicationPolicy`] may publish
//!    the freshly reduced parameters.
//!
//! Across projects the boundaries interleave: the driver processes them
//! in global time order (each master has its own iteration wall time), so
//! one project's publications land exactly between the serving windows
//! they belong to — never retroactively.
//!
//! **Byte-accounted publication.**  A publication *stages* the snapshot
//! (`param_count × 4` bytes) and queues its transfer on the shared
//! [`EgressBudget`]; the version activates only when the transfer
//! completes — the engine is pumped exactly to each completion instant,
//! so requests arriving mid-transfer still serve the previous version.
//! Concurrent publishers (several projects, or one fast-publishing
//! project) serialize on the link, and a large model visibly delays its
//! own activation (`activated_iteration > iteration`).
//!
//! After the last boundary a final unbounded pump drains the remaining
//! schedule and any in-flight transfers (open-loop arrivals may outlast
//! training).

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use crate::metrics::StalenessLog;
use crate::model::ModelSpec;
use crate::runtime::Compute;
use crate::serve::{ControlPlane, ModelVersion, ProjectId, ServeConfig, ServeEngine, ServeReport};
use crate::sim::{RunReport, SimConfig, Simulation};
use crate::storage::{recover, RecoverMode, RunStore};
use crate::trace::{ArgValue, TraceHandle, Track};

use super::probe::StalenessProbe;
use super::publish::{
    EgressBudget, PublicationPolicy, PublicationRecord, PublicationState, PublishTrigger,
};

/// One hosted project's side of the co-simulation: its model, training
/// run, publication policy and serving weight.  The project's request
/// fleet lives in `CosimConfig::serve.fleets` at the same index.
#[derive(Debug, Clone)]
pub struct CosimProject {
    pub spec: ModelSpec,
    pub train: SimConfig,
    pub publish: PublicationPolicy,
    /// Registry retention: keep the newest N versions (active, pinned and
    /// staged versions always survive).
    pub retain: usize,
    /// Fair-share admission weight on the shared serving tier.
    pub weight: f64,
}

/// Everything one co-simulation run needs besides the compute backends.
#[derive(Debug, Clone)]
pub struct CosimConfig {
    /// The hosted projects (index = `ProjectId`).
    pub projects: Vec<CosimProject>,
    /// The shared serving tier; `serve.fleets[i]` is project i's fleet.
    pub serve: ServeConfig,
    /// Shared master-egress budget for snapshot publication (bytes/min;
    /// ≤ 0 = unthrottled, transfers are instant but still accounted).
    pub egress_bytes_per_min: f64,
    /// Re-predict each served answer against its project's live master
    /// parameters (prediction delta + class flips).  Costs one extra
    /// execution per distinct input per iteration per project.
    pub measure_delta: bool,
}

/// Durable-state options for a co-simulation run (see
/// [`crate::storage`]).  Project `i`'s training WAL and checkpoints land
/// under `data_dir/p{i}/train`; its snapshot registry segments under
/// `data_dir/p{i}`.
#[derive(Debug, Clone)]
pub struct CosimDurability {
    pub data_dir: PathBuf,
    /// Checkpoint every N training iterations per project (0 = WAL only).
    pub checkpoint_every: u64,
    /// Warm-start from `data_dir`: replay each project's training log and
    /// restore the persisted registries instead of publishing fresh
    /// initial snapshots.
    pub resume: bool,
    /// Fault injection: abort the run (leaving `data_dir` as a crash
    /// would) once project 0 completes this iteration (0 = never).
    pub kill_at: u64,
    /// With `kill_at`, die *mid-window*: pump the serving tier only
    /// partway into the final window (between serve pumps, mid-traffic)
    /// instead of cleanly at the iteration boundary.  Exercises the
    /// crash surface PR-9's boundary-aligned kill could never reach.
    pub kill_mid: bool,
}

/// Outcome of one co-simulation run.
#[derive(Debug, Clone)]
pub struct CosimReport {
    /// One training report per project (index = `ProjectId`).
    pub train: Vec<RunReport>,
    pub serve: ServeReport,
    pub staleness: StalenessLog,
    /// Every publication across every project, in decision order (the
    /// first `projects.len()` entries are the initial snapshots).
    pub publications: Vec<PublicationRecord>,
    /// Master-egress bytes charged for snapshot transfers.
    pub egress_bytes: u64,
    /// Versions reclaimed by traffic-driven GC over the run.
    pub evicted: u64,
    /// Versions resident across every registry at end of run.
    pub resident: usize,
    /// Recovery cost per project when the run resumed from a data dir:
    /// iterations recomputed from the last checkpoint to the WAL tip
    /// (the durable plane's "recovery time" in virtual-work units).
    /// All zeros for fresh or non-durable runs.
    pub replayed: Vec<u64>,
}

impl CosimReport {
    /// Publications of one project, decision order.
    pub fn publications_for(&self, project: ProjectId) -> Vec<&PublicationRecord> {
        self.publications
            .iter()
            .filter(|p| p.project() == project)
            .collect()
    }

    /// One-line human summary: staleness beside latency.  Quantiles and
    /// the probe's delta print as `-` when unmeasured (empty run, or the
    /// delta probe disabled).
    pub fn summary(&self) -> String {
        let age = self.staleness.age_iters_summary();
        let lat = self.serve.latency();
        let ms = |v: f64| {
            if v.is_finite() {
                format!("{v:.1}")
            } else {
                "-".into()
            }
        };
        let delta = self.staleness.delta_summary();
        let delta_mean = if delta.is_empty() {
            "-".to_string()
        } else {
            format!("{:.4}", delta.mean())
        };
        format!(
            "projects={} pubs={} egress_mb={:.1} evicted={} resident={} age_iters p50={} \
             p99={} delta_mean={delta_mean} stale_class={:.3} latency p50={}ms p99={}ms \
             completed={}",
            self.train.len(),
            self.publications.len(),
            self.egress_bytes as f64 / 1.0e6,
            self.evicted,
            self.resident,
            ms(age.median()),
            ms(age.quantile(0.99)),
            self.staleness.stale_class_rate(),
            ms(lat.median()),
            ms(lat.quantile(0.99)),
            self.serve.completed,
        )
    }
}

/// A staged snapshot whose bytes are still crossing the egress link.
#[derive(Debug, Clone, Copy)]
struct PendingTransfer {
    done_ms: f64,
    version: ModelVersion,
    /// Index into the publications vec (to stamp activation facts).
    record: usize,
}

/// Pump the serving engine to `horizon`, activating every staged
/// transfer that completes on the way — the engine is pumped exactly to
/// each completion instant first, so requests arriving mid-transfer
/// still serve the previous version.
#[allow(clippy::too_many_arguments)]
fn pump_through(
    engine: &mut ServeEngine,
    plane: &mut ControlPlane,
    pending: &mut Vec<PendingTransfer>,
    publications: &mut [PublicationRecord],
    live_iter: &[u64],
    horizon: Option<f64>,
    compute: &mut dyn Compute,
    probe: &mut StalenessProbe,
    trace: &TraceHandle,
) -> Result<()> {
    while pending
        .first()
        .is_some_and(|t| horizon.is_none_or(|h| t.done_ms <= h))
    {
        let t = pending.remove(0);
        engine.pump(Some(t.done_ms), plane, compute, probe)?;
        plane
            .registry_mut(t.version.project)
            .activate(t.version)
            .map_err(|e| anyhow!(e))?;
        publications[t.record].activated_ms = t.done_ms;
        publications[t.record].activated_iteration = live_iter[t.version.project.index()];
        // Activation instant + the causal flow arrow picked up by the
        // first batch served on this version (see ServeEngine's flush).
        let track = Track::publisher(t.version.project.as_u32());
        trace.instant(
            track,
            "publish",
            "activate",
            t.done_ms,
            &[("version", ArgValue::U64(t.version.version))],
        );
        trace.flow_start(track, "publish", "first-serve", t.version.flow_id(), t.done_ms);
    }
    engine.pump(horizon, plane, compute, probe)?;
    Ok(())
}

/// Earliest unprocessed iteration boundary: `(project index, time)`,
/// ties to the lowest index.  `None` when every master is done.
fn next_boundary(boundaries: &[Option<f64>]) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for (i, ob) in boundaries.iter().enumerate() {
        if let Some(b) = *ob {
            if best.is_none_or(|(_, bb)| b < bb) {
                best = Some((i, b));
            }
        }
    }
    best
}

/// Run the co-simulation to completion.  `train_computes` backs each
/// project master's gradient/eval work (one per project, id order);
/// `serve_compute` the shared prediction tier (separate backends because
/// each side holds its own mutable borrow for the whole run; modeled
/// runs pass instances of the same scorer).
pub fn run_cosim<'c>(
    cfg: &CosimConfig,
    train_computes: Vec<&'c mut dyn Compute>,
    serve_compute: &mut dyn Compute,
) -> Result<CosimReport> {
    run_cosim_traced(cfg, train_computes, serve_compute, TraceHandle::off())
}

/// [`run_cosim`] with a trace plane attached: every project's training
/// spans, the shared tier's request spans, and publication lifecycle
/// spans (stage → egress → activate, with a flow arrow to the first
/// batch served on the new version) land on one virtual-clock timeline.
pub fn run_cosim_traced<'c>(
    cfg: &CosimConfig,
    train_computes: Vec<&'c mut dyn Compute>,
    serve_compute: &mut dyn Compute,
    trace: TraceHandle,
) -> Result<CosimReport> {
    run_cosim_durable(cfg, None, train_computes, serve_compute, trace)
}

/// [`run_cosim_traced`] with an optional durable state plane: per-project
/// training WALs + checkpoints and persisted snapshot registries under
/// `durability.data_dir`.  With `resume`, each project's master is
/// recovered (checkpoint + deterministic replay, digest-verified) and the
/// serving tier warms from the persisted registries — the active version,
/// staged candidates and rollback history all survive the restart.
pub fn run_cosim_durable<'c>(
    cfg: &CosimConfig,
    durability: Option<&CosimDurability>,
    train_computes: Vec<&'c mut dyn Compute>,
    serve_compute: &mut dyn Compute,
    trace: TraceHandle,
) -> Result<CosimReport> {
    let n = cfg.projects.len();
    if n == 0 {
        bail!("cosim needs at least one project");
    }
    if train_computes.len() != n {
        bail!(
            "{} train compute backend(s) for {} project(s)",
            train_computes.len(),
            n
        );
    }
    if cfg.serve.fleets.len() != n {
        bail!(
            "{} serve fleet(s) for {} project(s)",
            cfg.serve.fleets.len(),
            n
        );
    }

    let mut plane = ControlPlane::new();
    let pids: Vec<ProjectId> = cfg
        .projects
        .iter()
        .map(|p| plane.register(p.spec.clone(), p.weight))
        .collect();
    let specs: Vec<ModelSpec> = cfg.projects.iter().map(|p| p.spec.clone()).collect();
    let mut engine = ServeEngine::new(&cfg.serve, &plane)?;
    let mut probe = StalenessProbe::new(&specs, cfg.measure_delta);
    let mut egress = EgressBudget::new(cfg.egress_bytes_per_min);

    let mut sims: Vec<Simulation> = cfg
        .projects
        .iter()
        .zip(train_computes)
        .map(|(p, compute)| Simulation::new(p.train.clone(), p.spec.clone(), compute))
        .collect();
    engine.set_trace(trace.clone());
    for (i, sim) in sims.iter_mut().enumerate() {
        sim.set_trace(trace.clone(), pids[i].as_u32());
    }

    // Durable plane: open each project's run store, recover on resume
    // (checkpoint + digest-verified replay through the ordinary step
    // path), then attach the WAL so every further iteration is logged.
    let mut stores: Vec<Option<RunStore>> = vec![None; n];
    let mut replayed: Vec<u64> = vec![0; n];
    // Projects whose registry warmed from persisted segments skip the
    // initial publication — their active version survived the restart.
    let mut warm: Vec<bool> = vec![false; n];
    if let Some(d) = durability {
        for i in 0..n {
            let dir = d.data_dir.join(format!("p{i}")).join("train");
            let store = RunStore::open_for_config(&dir, &cfg.projects[i].train)?;
            if d.resume {
                let rec = recover(
                    &mut sims[i],
                    &store,
                    RecoverMode::Resume,
                    &trace,
                    pids[i].as_u32(),
                )?;
                replayed[i] = rec.replayed;
            } else if store.wal_path().exists() {
                bail!(
                    "{} already holds a run — resume it instead of overwriting",
                    store.dir().display()
                );
            }
            let wal = store.open_wal_for_append()?;
            sims[i].master_mut().attach_wal(wal, store.identity().seed);
            stores[i] = Some(store);
        }
        if d.resume {
            plane.restore_registries(&d.data_dir)?;
            for (i, &pid) in pids.iter().enumerate() {
                warm[i] = !plane.registry(pid).is_empty();
            }
        }
    }
    let checkpoint_every = durability.map_or(0, |d| d.checkpoint_every);

    let mut states: Vec<PublicationState> = vec![PublicationState::default(); n];
    let mut publications: Vec<PublicationRecord> = Vec::new();
    let mut pending: Vec<PendingTransfer> = Vec::new();
    // The master iteration live for each project's current serving
    // window (what activation records stamp as their landing iteration).
    // Resumed masters open their window at the recovered tip.
    let mut live_iter: Vec<u64> = sims.iter().map(|s| s.master().iteration()).collect();
    let mut evicted_total = 0u64;

    // Initial snapshots: the run serves every project's iteration-0
    // parameters from t=0.  Free and instant — egress accounting begins
    // with the first live publication.  Warm-restored registries keep
    // serving their persisted active version instead.
    for (i, &pid) in pids.iter().enumerate() {
        probe.set_master(pid, live_iter[i], sims[i].master().params());
        if warm[i] {
            continue;
        }
        let version = plane
            .registry_mut(pid)
            .publish_params(
                sims[i].master().params().to_vec(),
                0,
                "cosim: initial".into(),
                0.0,
            )
            .map_err(|e| anyhow!(e))?;
        publications.push(PublicationRecord {
            version,
            iteration: 0,
            t_ms: 0.0,
            bytes: 0,
            activated_ms: 0.0,
            activated_iteration: 0,
            trigger: PublishTrigger::Initial,
            evicted: Vec::new(),
        });
        // Initial snapshots activate instantly at t = 0; they still get
        // a (zero-duration) publication span and a first-serve flow.
        let track = Track::publisher(pid.as_u32());
        trace.span(
            track,
            "publish",
            "publish",
            0.0,
            0.0,
            &[
                ("version", ArgValue::U64(version.version)),
                ("trigger", ArgValue::Str(PublishTrigger::Initial.name())),
            ],
        );
        trace.flow_start(track, "publish", "first-serve", version.flow_id(), 0.0);
    }

    // Seed: one step per project establishes its first boundary.  A
    // resumed project owes only the iterations past its recovered tip.
    let mut remaining: Vec<u64> = cfg
        .projects
        .iter()
        .zip(&live_iter)
        .map(|(p, &done)| p.train.iterations.saturating_sub(done))
        .collect();
    let mut boundaries: Vec<Option<f64>> = vec![None; n];
    for i in 0..n {
        if remaining[i] > 0 {
            sims[i].step()?;
            remaining[i] -= 1;
            checkpoint_after_step(&mut sims[i], stores[i].as_ref(), checkpoint_every)?;
            boundaries[i] = Some(sims[i].master().now_ms());
        }
    }

    // Process boundaries in global time order; each project's
    // publications land at its own boundaries, activations at their
    // transfer-completion instants.
    let mut pumped_ms = 0.0f64;
    while let Some((i, boundary_ms)) = next_boundary(&boundaries) {
        let kill_here = durability.is_some_and(|d| {
            d.kill_at > 0 && i == 0 && sims[i].master().iteration() >= d.kill_at
        });
        // Fault injection, mid-window flavor: pump the serving tier only
        // halfway from the last processed horizon to this boundary, then
        // die with the window's remaining traffic (and the boundary
        // itself) unprocessed — the crash lands between serve pumps, not
        // at the clean iteration edge the boundary-aligned kill hits.
        if kill_here && durability.is_some_and(|d| d.kill_mid) {
            let mid = pumped_ms + 0.5 * (boundary_ms - pumped_ms);
            pump_through(
                &mut engine,
                &mut plane,
                &mut pending,
                &mut publications,
                &live_iter,
                Some(mid),
                serve_compute,
                &mut probe,
                &trace,
            )?;
            let iteration = sims[i].master().iteration();
            bail!(
                "fault injection: cosim killed mid-window before project 0 iteration \
                 {iteration} boundary (data dir {} holds the crash state)",
                durability.expect("kill_mid requires durability").data_dir.display()
            );
        }
        pump_through(
            &mut engine,
            &mut plane,
            &mut pending,
            &mut publications,
            &live_iter,
            Some(boundary_ms),
            serve_compute,
            &mut probe,
            &trace,
        )?;
        pumped_ms = boundary_ms;
        boundaries[i] = None;
        let pid = pids[i];
        let iteration = sims[i].master().iteration();
        // Fault injection: die at this boundary exactly as a crash would —
        // checkpoints/WAL syncs through the cadence exist, nothing else.
        if kill_here {
            bail!(
                "fault injection: cosim killed at project 0 iteration {iteration} \
                 (data dir {} holds the crash state)",
                durability.expect("kill_at requires durability").data_dir.display()
            );
        }
        let test_error = sims[i].master().timeline().last().and_then(|r| r.test_error);
        if let Some(trigger) = cfg.projects[i].publish.decide(&mut states[i], iteration, test_error)
        {
            let bytes = (cfg.projects[i].spec.param_count * 4) as u64;
            let version = plane
                .registry_mut(pid)
                .stage_params(
                    sims[i].master().params().to_vec(),
                    iteration,
                    format!("cosim: {} @ iter {iteration}", trigger.name()),
                    boundary_ms,
                )
                .map_err(|e| anyhow!(e))?;
            let done_ms = egress.schedule(boundary_ms, bytes);
            // Egress gauge right after the charge: how far behind the
            // shared link is and the cumulative bytes it carried.  The
            // budget serializes across projects, so `backlog_ms` on any
            // one publisher track reads the *shared* queue depth.
            trace.counter(
                Track::publisher(pid.as_u32()),
                "publish/egress",
                boundary_ms,
                &[
                    ("backlog_ms", egress.backlog_ms(boundary_ms)),
                    ("bytes_sent", egress.bytes_sent() as f64),
                ],
            );
            // Traffic-driven GC at publication time: retention, reader
            // pins and staged-transfer immunity must all agree.
            let evicted = plane
                .registry_mut(pid)
                .gc_keep_latest(cfg.projects[i].retain.max(1));
            evicted_total += evicted.len() as u64;
            pending.push(PendingTransfer {
                done_ms,
                version,
                record: publications.len(),
            });
            pending.sort_by(|a, b| a.done_ms.total_cmp(&b.done_ms).then(a.version.cmp(&b.version)));
            // Publication span: staging decision through egress transfer
            // (activation is the instant pump_through emits at done_ms).
            trace.span(
                Track::publisher(pid.as_u32()),
                "publish",
                "publish",
                boundary_ms,
                done_ms,
                &[
                    ("version", ArgValue::U64(version.version)),
                    ("bytes", ArgValue::U64(bytes)),
                    ("iteration", ArgValue::U64(iteration)),
                    ("trigger", ArgValue::Str(trigger.name())),
                ],
            );
            publications.push(PublicationRecord {
                version,
                iteration,
                t_ms: boundary_ms,
                bytes,
                activated_ms: done_ms,
                activated_iteration: iteration,
                trigger,
                evicted,
            });
            // Registry durability rides publication boundaries: segments
            // are immutable, so each save only writes the new version
            // plus a fresh manifest (and sweeps what GC just evicted).
            if let Some(d) = durability {
                plane.persist(&d.data_dir)?;
            }
        }
        // Open the project's next window: its live params and iteration
        // for the traffic between this boundary and the next.
        live_iter[i] = iteration;
        probe.set_master(pid, iteration, sims[i].master().params());
        if remaining[i] > 0 {
            sims[i].step()?;
            remaining[i] -= 1;
            checkpoint_after_step(&mut sims[i], stores[i].as_ref(), checkpoint_every)?;
            boundaries[i] = Some(sims[i].master().now_ms());
        }
    }

    // Drain the serving tail: arrivals after the last boundary, batches
    // still queued, and transfers still in flight.
    pump_through(
        &mut engine,
        &mut plane,
        &mut pending,
        &mut publications,
        &live_iter,
        None,
        serve_compute,
        &mut probe,
        &trace,
    )?;
    debug_assert_eq!(
        plane.total_readers(),
        0,
        "drained run must release every reader pin"
    );

    // End-of-run durability: a final WAL sync per project and a last
    // registry persist (late activations from the drain land here).
    if let Some(d) = durability {
        for sim in &mut sims {
            if let Some(wal) = sim.master_mut().wal_mut() {
                wal.sync()?;
            }
        }
        plane.persist(&d.data_dir)?;
    }

    let train: Vec<RunReport> = sims
        .iter()
        .map(|s| RunReport::from_timeline(s.master().timeline().clone(), s.n_clients()))
        .collect();
    Ok(CosimReport {
        train,
        serve: engine.into_report(),
        staleness: probe.into_log(),
        publications,
        egress_bytes: egress.bytes_sent(),
        evicted: evicted_total,
        resident: plane.resident(),
        replayed,
    })
}

/// Durable-plane hook after one training step: at the checkpoint cadence,
/// snapshot the full deterministic state and fsync the WAL — the only
/// sync points; every other iteration is a buffered append.
fn checkpoint_after_step(
    sim: &mut Simulation<'_>,
    store: Option<&RunStore>,
    checkpoint_every: u64,
) -> Result<()> {
    let Some(store) = store else {
        return Ok(());
    };
    let iteration = sim.master().iteration();
    if checkpoint_every > 0 && iteration % checkpoint_every == 0 {
        store.write_checkpoint(&sim.capture_state())?;
        if let Some(wal) = sim.master_mut().wal_mut() {
            wal.sync()?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::DeviceClass;
    use crate::metrics::StalenessRecord;
    use crate::model::TensorSpec;
    use crate::netsim::LinkProfile;
    use crate::runtime::ModeledCompute;
    use crate::serve::{
        BatchPolicy, ClientSpec, FleetConfig, RouterConfig, ServerProfile,
    };

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 8,
            batch_size: 16,
            micro_batches: vec![16, 4, 1],
            input: vec![28, 28, 1],
            classes: 10,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![8],
                offset: 0,
                size: 8,
                fan_in: 4,
            }],
            artifacts: Default::default(),
        }
    }

    fn cfg(iterations: u64, publish_every: u64) -> CosimConfig {
        let spec = spec();
        let mut train = SimConfig::paper_scaling(2, &spec);
        train.train_size = 300;
        train.test_size = 32;
        train.iterations = iterations;
        train.master.capacity = 100;
        train.track_every = 2;
        let serve = ServeConfig {
            fleets: vec![FleetConfig {
                groups: vec![ClientSpec {
                    link: LinkProfile::Lan,
                    rate_rps: 5.0,
                    count: 3,
                }],
                duration_s: iterations as f64 * 4.0,
                input_pool: 8,
                seed: 13,
            }],
            policy: BatchPolicy {
                max_batch: 16,
                max_wait_ms: 5.0,
                queue_depth: 256,
            },
            server: ServerProfile::default(),
            router: RouterConfig::single(),
            shard_profiles: Vec::new(),
            drained_shards: Vec::new(),
            cache_capacity: 0,
            response_bytes: 256,
            keep_log: true,
        };
        CosimConfig {
            projects: vec![CosimProject {
                spec,
                train,
                publish: PublicationPolicy::every(publish_every),
                retain: 2,
                weight: 1.0,
            }],
            serve,
            egress_bytes_per_min: 0.0,
            measure_delta: true,
        }
    }

    fn run(cfg: &CosimConfig) -> CosimReport {
        let mut train_compute = ModeledCompute { param_count: 8 };
        let mut serve_compute = ModeledCompute { param_count: 8 };
        run_cosim(cfg, vec![&mut train_compute], &mut serve_compute).unwrap()
    }

    #[test]
    fn cosim_reconciles_and_publishes_on_cadence() {
        let report = run(&cfg(6, 2));
        // Serving accounting holds under the shared clock.
        assert!(report.serve.offered > 0);
        assert_eq!(
            report.serve.completed + report.serve.rejected,
            report.serve.offered
        );
        // One staleness record per completed request.
        assert_eq!(report.staleness.len() as u64, report.serve.completed);
        // Initial + cadence at iterations 2, 4, 6.
        assert_eq!(report.publications.len(), 4);
        assert_eq!(report.publications[0].trigger, PublishTrigger::Initial);
        assert_eq!(
            report
                .publications
                .iter()
                .skip(1)
                .map(|p| p.iteration)
                .collect::<Vec<_>>(),
            vec![2, 4, 6]
        );
        // Unthrottled egress: transfers are instant (no activation lag)
        // but the bytes are accounted (param_count × 4 per live publish).
        assert_eq!(report.egress_bytes, 3 * 8 * 4);
        for p in report.publications.iter().skip(1) {
            assert_eq!(p.bytes, 32);
            assert_eq!(p.activated_ms, p.t_ms);
            assert_eq!(p.activation_lag_iters(), 0);
        }
        // Training really ran on the same clock.
        assert_eq!(report.train.len(), 1);
        assert_eq!(report.train[0].timeline.len(), 6);
        assert!(report.train[0].virtual_secs >= 24.0);
        // Retention (2) bounds the registry; pins all released.
        assert!(report.resident <= 2);
        assert_eq!(report.evicted, 2, "4 published − 2 retained");
        // Every served request names a published version, and its age in
        // iterations is bounded by the run.
        let published: Vec<ModelVersion> =
            report.publications.iter().map(|p| p.version).collect();
        for r in report.staleness.records() {
            assert!(published.contains(&r.version), "{r:?}");
            assert!(r.age_iters() <= 6, "{r:?}");
            assert!(r.age_ms >= 0.0);
        }
    }

    #[test]
    fn cosim_is_deterministic() {
        let a = run(&cfg(4, 2));
        let b = run(&cfg(4, 2));
        assert_eq!(a.staleness.to_csv(), b.staleness.to_csv());
        assert_eq!(a.serve.log.to_csv(), b.serve.log.to_csv());
        assert_eq!(a.summary(), b.summary());
    }

    #[test]
    fn publish_every_iteration_keeps_answers_fresh() {
        let report = run(&cfg(6, 1));
        // With a snapshot at every boundary, no served answer can lag by
        // more than the one-iteration publication pipeline.
        let max_age = report
            .staleness
            .records()
            .iter()
            .map(StalenessRecord::age_iters)
            .max()
            .unwrap_or(0);
        assert!(max_age <= 1, "cadence-1 run saw age {max_age}");
        // ModeledCompute training never moves the parameters, so stale
        // answers equal fresh ones exactly.
        assert!(report.staleness.delta_summary().max() < 1e-9);
        assert_eq!(report.staleness.stale_class_rate(), 0.0);
    }

    #[test]
    fn publish_never_means_growing_staleness() {
        let report = run(&cfg(6, 0));
        assert_eq!(report.publications.len(), 1, "initial only");
        assert_eq!(report.evicted, 0);
        assert_eq!(report.egress_bytes, 0, "nothing crossed the link");
        // Ages grow with the master: late responses lag by many
        // iterations.
        let max_age = report
            .staleness
            .records()
            .iter()
            .map(StalenessRecord::age_iters)
            .max()
            .unwrap_or(0);
        assert!(max_age >= 4, "never-publish run saw max age {max_age}");
    }

    #[test]
    fn throttled_egress_delays_activation() {
        // 8 params × 4 B = 32 B per snapshot; at 120 bytes/min (2 B/s) a
        // transfer takes 16 s = 4 iteration windows (T = 4 s).  Cadence-2
        // publications must activate strictly after their decision
        // iteration, and the queued transfers serialize on the link.
        let mut config = cfg(6, 2);
        config.egress_bytes_per_min = 120.0;
        let report = run(&config);
        let live: Vec<&PublicationRecord> = report
            .publications
            .iter()
            .filter(|p| p.trigger != PublishTrigger::Initial)
            .collect();
        assert_eq!(live.len(), 3);
        assert!(report.egress_bytes >= 96);
        for p in &live {
            assert!(p.transfer_ms() >= 16_000.0 - 1e-6, "{p:?}");
        }
        // Transfers that complete while the master is still training land
        // iterations late (the last one finishes only in the tail drain,
        // where the master has already stopped at its final iteration, so
        // its *iteration* lag collapses even though its ms lag is huge).
        for p in &live[..2] {
            assert!(
                p.activated_iteration > p.iteration,
                "transfer must outlive the publication window: {p:?}"
            );
        }
        // Serialized: each queued transfer completes after its
        // predecessor.
        for w in live.windows(2) {
            assert!(w[1].activated_ms >= w[0].activated_ms + 16_000.0 - 1e-6);
        }
        // Requests arriving mid-transfer keep serving the previous
        // version: nothing may be served by a version before it
        // activated.
        let activated_at: std::collections::BTreeMap<ModelVersion, f64> = report
            .publications
            .iter()
            .map(|p| (p.version, p.activated_ms))
            .collect();
        for r in report.serve.log.records() {
            let act = activated_at.get(&r.version).copied().unwrap_or(0.0);
            assert!(
                r.done_ms >= act,
                "request finished before its version activated: {r:?}"
            );
        }
    }

    #[test]
    fn traced_cosim_links_publications_to_first_serve() {
        use crate::trace::EventKind;
        let config = cfg(4, 2);
        let mut train_compute = ModeledCompute { param_count: 8 };
        let mut serve_compute = ModeledCompute { param_count: 8 };
        let trace = TraceHandle::recording();
        let report = run_cosim_traced(
            &config,
            vec![&mut train_compute],
            &mut serve_compute,
            trace.clone(),
        )
        .unwrap();
        assert!(report.serve.completed > 0);
        let evs = trace.snapshot();
        // All three planes landed on the one timeline.
        assert!(evs.iter().any(|e| e.cat == "train" && e.name == "iteration"));
        assert!(evs.iter().any(|e| e.cat == "serve" && e.name == "request"));
        assert!(evs.iter().any(|e| e.cat == "publish" && e.name == "publish"));
        assert!(evs.iter().any(|e| e.name == "activate"));
        // Every flow arrow that started also finished (a batch really was
        // served on each published version), and each id fires once.
        let starts: Vec<u64> = evs
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::FlowStart { id } => Some(id),
                _ => None,
            })
            .collect();
        let finishes: Vec<u64> = evs
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::FlowFinish { id } => Some(id),
                _ => None,
            })
            .collect();
        assert!(!starts.is_empty());
        assert!(!finishes.is_empty(), "no batch picked up a publication flow");
        for id in &finishes {
            assert!(starts.contains(id), "finish without start: {id}");
        }
        // Request spans are balanced after the tail drain.
        assert_eq!(trace.open_async(), 0);
    }

    fn run_durable(cfg: &CosimConfig, d: Option<&CosimDurability>) -> Result<CosimReport> {
        // Drifting training compute: parameters actually move, so the
        // bitwise-resume assertions below are meaningful.
        let mut train_compute = crate::runtime::DriftingCompute { param_count: 8 };
        let mut serve_compute = ModeledCompute { param_count: 8 };
        run_cosim_durable(
            cfg,
            d,
            vec![&mut train_compute],
            &mut serve_compute,
            TraceHandle::off(),
        )
    }

    fn durable_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("mlitb-cosim-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn killed_cosim_resumes_bitwise_and_registry_warm() {
        let dir = durable_dir("kill-resume");
        let config = cfg(6, 2);
        // Uninterrupted reference on the same drifting backend.
        let reference = run_durable(&config, None).unwrap();

        // Cadence 3 with a kill at boundary 4: the crash state holds a
        // checkpoint at iteration 3 plus WAL records through 4.
        let killed = CosimDurability {
            data_dir: dir.clone(),
            checkpoint_every: 3,
            resume: false,
            kill_at: 4,
            kill_mid: false,
        };
        let err = run_durable(&config, Some(&killed)).unwrap_err();
        assert!(err.to_string().contains("fault injection"), "{err}");
        // A second fresh run must refuse the populated data dir.
        let refused = run_durable(&config, Some(&killed)).unwrap_err();
        assert!(refused.to_string().contains("already holds a run"), "{refused}");

        let resume = CosimDurability {
            data_dir: dir.clone(),
            checkpoint_every: 3,
            resume: true,
            kill_at: 0,
            kill_mid: false,
        };
        let resumed = run_durable(&config, Some(&resume)).unwrap();
        // Recovery cost: one iteration recomputed (checkpoint 3 → tip 4).
        assert_eq!(resumed.replayed, vec![1]);
        // The resumed training trajectory is the uninterrupted one.
        assert_eq!(
            resumed.train[0].timeline.to_csv(),
            reference.train[0].timeline.to_csv()
        );
        // The registry warmed from persisted segments: no fresh initial
        // publication, and the version counter continues where it left
        // off (v1 initial + v2 published pre-kill ⇒ next mint is v3).
        assert!(resumed
            .publications
            .iter()
            .all(|p| p.trigger != PublishTrigger::Initial));
        assert_eq!(resumed.publications[0].version.version, 3);
        assert!(resumed.serve.completed > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mid_window_kill_resumes_bitwise() {
        // The PR-9 follow-on: the kill must also be able to land *between*
        // serve pumps inside a window — the serving tier has processed part
        // of the window's traffic, the boundary publication never happened.
        // Durable training state is identical to the boundary-aligned crash
        // (serving progress is not persisted), so resume must still replay
        // to the uninterrupted trajectory.
        let dir = durable_dir("kill-mid-resume");
        let config = cfg(6, 2);
        let reference = run_durable(&config, None).unwrap();

        let killed = CosimDurability {
            data_dir: dir.clone(),
            checkpoint_every: 3,
            resume: false,
            kill_at: 4,
            kill_mid: true,
        };
        let err = run_durable(&config, Some(&killed)).unwrap_err();
        assert!(err.to_string().contains("fault injection"), "{err}");
        assert!(err.to_string().contains("mid-window"), "{err}");

        let resume = CosimDurability {
            data_dir: dir.clone(),
            checkpoint_every: 3,
            resume: true,
            kill_at: 0,
            kill_mid: false,
        };
        let resumed = run_durable(&config, Some(&resume)).unwrap();
        // Same durable crash state as the boundary-aligned kill: one
        // iteration recomputed (checkpoint 3 → WAL tip 4), bitwise-equal
        // resumed trajectory.
        assert_eq!(resumed.replayed, vec![1]);
        assert_eq!(
            resumed.train[0].timeline.to_csv(),
            reference.train[0].timeline.to_csv()
        );
        assert!(resumed.serve.completed > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn churn_and_cosim_compose() {
        // The shared clock must survive fleet churn mid-run.
        let mut config = cfg(5, 2);
        config.projects[0]
            .train
            .churn
            .insert(2, vec![crate::sim::ChurnEvent::Join(DeviceClass::Mobile)]);
        let report = run(&config);
        assert_eq!(report.train[0].timeline.len(), 5);
        assert!(report.serve.completed > 0);
    }
}
