//! Tiny argument-parsing substrate (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, typed
//! accessors with defaults, and a generated usage string.  Used by the
//! `mlitb` binary, the examples, and the bench harnesses.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    named: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest are positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.named.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.named.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1)).expect("argument parsing")
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.named.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected number, got '{v}'")),
        }
    }

    /// Comma-separated integer list (e.g. `--nodes 1,2,4,8`).
    pub fn get_usize_list(
        &self,
        name: &str,
        default: &[usize],
    ) -> Result<Vec<usize>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| format!("--{name}: bad element '{p}'"))
                })
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn named_and_flags() {
        let a = parse(&["--nodes", "8", "--fast", "--model=mlp", "train"]);
        assert_eq!(a.get("nodes"), Some("8"));
        assert_eq!(a.get("model"), Some("mlp"));
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
        assert_eq!(a.positional(), &["train".to_string()]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["--n", "42", "--lr", "0.5"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("lr", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse(&["--n", "x"]).get_usize("n", 0).is_err());
    }

    #[test]
    fn list_accessor() {
        let a = parse(&["--nodes", "1,2, 4"]);
        assert_eq!(a.get_usize_list("nodes", &[9]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_usize_list("other", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--x", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional(), &["--not-a-flag".to_string()]);
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse(&["--delta", "-3.5"]);
        assert_eq!(a.get_f64("delta", 0.0).unwrap(), -3.5);
    }
}
