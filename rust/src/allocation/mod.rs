//! Data-index allocation: balanced allocation + the paper's **pie-cutter**.
//!
//! The master "stores an allocated index (the worker that is allocated the
//! id) and a cached index (the worker that has cached the id)" and "ensures
//! that the data allocation is balanced amongst its clients" (§3.3a).  On
//! join with no unallocated data, "a pie-cutter algorithm is used to remove
//! allocated data from other clients and assign it to the new client. This
//! prevents unnecessary data transfers" (§3.3b).  On loss, orphaned indices
//! are re-allocated to the remaining clients "if possible, otherwise
//! marked as to-be-allocated" (§3.2).
//!
//! The per-worker capacity limit reproduces the scaling experiment's
//! "data allocation policy that limits the data vector capacity of each
//! node to 3000 vectors" (§3.5) — the policy that makes Fig 5's error
//! curve fall with node count until the full training set is covered.

mod pie;

pub use pie::{Allocator, AllocatorState, WorkerAllocState};

/// Worker identity within one project.
pub type WorkerId = u64;

/// Data-vector index within one project's dataset.
pub type DataId = u32;

/// Per-worker capacity used in the paper's scaling experiment (§3.5).
pub const PAPER_CAPACITY: usize = 3000;

/// What changed as the result of one allocation event; the coordinator
/// turns this into data-download instructions for the affected clients.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct Delta {
    /// (worker, ids newly assigned to it — worker must fetch any not cached)
    pub assigned: Vec<(WorkerId, Vec<DataId>)>,
    /// (worker, ids revoked from it — stop training on these)
    pub revoked: Vec<(WorkerId, Vec<DataId>)>,
}

impl Delta {
    pub fn is_empty(&self) -> bool {
        self.assigned.is_empty() && self.revoked.is_empty()
    }

    /// Total number of ids that must move (the transfer cost pie-cutting
    /// minimizes; `benches/ablations.rs` compares against naive).
    pub fn moved(&self) -> usize {
        self.assigned.iter().map(|(_, v)| v.len()).sum()
    }
}
