//! The allocator state machine and pie-cutter rebalancing.

use std::collections::BTreeMap;

use super::{DataId, Delta, WorkerId};

/// Index-level allocation state for one project.
///
/// Tracks, per data id, the owning worker (at most one — owners compute
/// gradients on the id) and, per worker, the owned set plus a *cached* set
/// (ids the client already holds locally; re-assigning a cached id costs no
/// transfer, which is what the pie-cutter exploits).
#[derive(Debug, Clone)]
pub struct Allocator {
    capacity: usize,
    owner: Vec<Option<WorkerId>>,
    workers: BTreeMap<WorkerId, WorkerState>,
    unallocated: Vec<DataId>,
    transfers: u64,
}

#[derive(Debug, Clone, Default)]
struct WorkerState {
    owned: Vec<DataId>,
    cached: Vec<bool>, // indexed by DataId; lazily grown
}

/// Serializable allocator snapshot — see [`Allocator::export_state`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllocatorState {
    pub capacity: usize,
    pub total_data: u64,
    pub workers: Vec<WorkerAllocState>,
    pub unallocated: Vec<DataId>,
    pub transfers: u64,
}

/// One worker's slice of the allocation (owned ids in allocation order,
/// cached ids ascending).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerAllocState {
    pub id: WorkerId,
    pub owned: Vec<DataId>,
    pub cached: Vec<DataId>,
}

impl WorkerState {
    fn is_cached(&self, id: DataId) -> bool {
        self.cached.get(id as usize).copied().unwrap_or(false)
    }
    fn set_cached(&mut self, id: DataId) {
        let idx = id as usize;
        if self.cached.len() <= idx {
            self.cached.resize(idx + 1, false);
        }
        self.cached[idx] = true;
    }
}

impl Allocator {
    /// New allocator with a per-worker capacity (paper: 3000).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            capacity,
            owner: Vec::new(),
            workers: BTreeMap::new(),
            unallocated: Vec::new(),
            transfers: 0,
        }
    }

    // ------------------------------------------------------------ queries

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn total_data(&self) -> usize {
        self.owner.len()
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn worker_ids(&self) -> Vec<WorkerId> {
        self.workers.keys().copied().collect()
    }

    pub fn owned_by(&self, w: WorkerId) -> &[DataId] {
        self.workers
            .get(&w)
            .map(|s| s.owned.as_slice())
            .unwrap_or(&[])
    }

    pub fn owner_of(&self, id: DataId) -> Option<WorkerId> {
        self.owner.get(id as usize).copied().flatten()
    }

    pub fn unallocated(&self) -> &[DataId] {
        &self.unallocated
    }

    /// Cumulative ids moved to workers that did not have them cached.
    pub fn transfer_count(&self) -> u64 {
        self.transfers
    }

    /// Number of allocated (owned) ids.
    pub fn allocated_count(&self) -> usize {
        self.owner.len() - self.unallocated.len()
    }

    // ------------------------------------------------------------- events

    /// §3.3a: a new dataset (or chunk) of `n` vectors is registered; ids are
    /// appended and balanced across existing workers up to capacity.
    pub fn add_data(&mut self, n: usize) -> Delta {
        let start = self.owner.len() as DataId;
        for i in 0..n {
            self.owner.push(None);
            self.unallocated.push(start + i as DataId);
        }
        self.fill_from_unallocated()
    }

    /// §3.3b: a new trainer joins.  Unallocated data first; if none (and
    /// the fleet holds more than the fair share), pie-cut from the largest
    /// holders.  Returns the ids the new worker must obtain.
    pub fn worker_join(&mut self, w: WorkerId) -> Delta {
        assert!(
            self.workers.insert(w, WorkerState::default()).is_none(),
            "worker {w} already joined"
        );
        let mut delta = self.fill_from_unallocated();
        // Pie-cutter: equalize toward the fair share without exceeding it.
        let fair = self.fair_share();
        let have = self.workers[&w].owned.len();
        if have < fair {
            let mut need = fair - have;
            let donors = self.donors_above(fair, w);
            let mut steal: Vec<(WorkerId, Vec<DataId>)> = Vec::new();
            for donor in donors {
                if need == 0 {
                    break;
                }
                let excess = self.workers[&donor].owned.len().saturating_sub(fair);
                let take = excess.min(need);
                if take == 0 {
                    continue;
                }
                let ids = self.take_from(donor, take);
                need -= ids.len();
                steal.push((donor, ids));
            }
            let mut got: Vec<DataId> = Vec::new();
            for (donor, ids) in steal {
                delta.revoked.push((donor, ids.clone()));
                got.extend(ids);
            }
            if !got.is_empty() {
                self.assign(w, &got);
                Self::push_assigned(&mut delta, w, got);
            }
        }
        delta
    }

    /// §3.2: a worker is lost (tab closed, device gone).  Its data is
    /// re-allocated to remaining workers if capacity allows, otherwise
    /// marked to-be-allocated.
    pub fn worker_leave(&mut self, w: WorkerId) -> Delta {
        let Some(state) = self.workers.remove(&w) else {
            return Delta::default();
        };
        for &id in &state.owned {
            self.owner[id as usize] = None;
        }
        self.unallocated.extend(state.owned.iter().copied());
        self.fill_from_unallocated()
    }

    /// §3.3d latency adaptation can also *shrink* a slow worker's share:
    /// revoke `n` ids (returned to the unallocated pool, then re-spread).
    pub fn shed_load(&mut self, w: WorkerId, n: usize) -> Delta {
        if !self.workers.contains_key(&w) || n == 0 {
            return Delta::default();
        }
        let ids = self.take_from(w, n);
        if ids.is_empty() {
            return Delta::default();
        }
        let mut delta = Delta {
            revoked: vec![(w, ids.clone())],
            ..Delta::default()
        };
        for &id in &ids {
            self.unallocated.push(id);
        }
        let spread = self.fill_from_unallocated_excluding(Some(w));
        delta.assigned.extend(spread.assigned);
        delta.revoked.extend(spread.revoked);
        delta
    }

    /// Mark an id as cached on a worker (client finished downloading it).
    pub fn mark_cached(&mut self, w: WorkerId, id: DataId) {
        if let Some(state) = self.workers.get_mut(&w) {
            state.set_cached(id);
        }
    }

    /// Naive alternative to pie-cutting used by `benches/ablations.rs`:
    /// revoke *everything* and deal round-robin from scratch.
    pub fn rebalance_naive(&mut self) -> Delta {
        let mut delta = Delta::default();
        let ids: Vec<WorkerId> = self.workers.keys().copied().collect();
        if ids.is_empty() {
            return delta;
        }
        // revoke all
        let mut all: Vec<DataId> = Vec::new();
        for w in &ids {
            let state = self.workers.get_mut(w).unwrap();
            if !state.owned.is_empty() {
                let owned = std::mem::take(&mut state.owned);
                for &id in &owned {
                    self.owner[id as usize] = None;
                }
                all.extend(owned.iter().copied());
                delta.revoked.push((*w, owned));
            }
        }
        all.extend(self.unallocated.drain(..));
        all.sort_unstable();
        // deal round-robin up to capacity
        let mut per: BTreeMap<WorkerId, Vec<DataId>> = BTreeMap::new();
        let mut wi = 0usize;
        for id in all {
            let mut placed = false;
            for _ in 0..ids.len() {
                let w = ids[wi % ids.len()];
                wi += 1;
                if self.workers[&w].owned.len() + per.get(&w).map_or(0, |v| v.len())
                    < self.capacity
                {
                    per.entry(w).or_default().push(id);
                    placed = true;
                    break;
                }
            }
            if !placed {
                self.unallocated.push(id);
            }
        }
        for (w, got) in per {
            self.assign(w, &got);
            Self::push_assigned(&mut delta, w, got);
        }
        delta
    }

    // ------------------------------------------------------------ helpers

    /// Fair share per worker given totals and capacity.
    fn fair_share(&self) -> usize {
        if self.workers.is_empty() {
            return 0;
        }
        let total = self.owner.len();
        (total / self.workers.len())
            .max(1)
            .min(self.capacity)
    }

    /// Workers (≠ `except`) sorted by owned count descending.
    fn donors_above(&self, threshold: usize, except: WorkerId) -> Vec<WorkerId> {
        let mut v: Vec<(usize, WorkerId)> = self
            .workers
            .iter()
            .filter(|(w, s)| **w != except && s.owned.len() > threshold)
            .map(|(w, s)| (s.owned.len(), *w))
            .collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v.into_iter().map(|(_, w)| w).collect()
    }

    /// Remove up to `n` ids from the tail of `w`'s owned list.
    fn take_from(&mut self, w: WorkerId, n: usize) -> Vec<DataId> {
        let state = self.workers.get_mut(&w).unwrap();
        let n = n.min(state.owned.len());
        let ids: Vec<DataId> = state.owned.split_off(state.owned.len() - n);
        for &id in &ids {
            self.owner[id as usize] = None;
        }
        ids
    }

    fn assign(&mut self, w: WorkerId, ids: &[DataId]) {
        let state = self.workers.get_mut(&w).unwrap();
        for &id in ids {
            debug_assert!(self.owner[id as usize].is_none());
            self.owner[id as usize] = Some(w);
            state.owned.push(id);
            if !state.is_cached(id) {
                self.transfers += 1;
            }
        }
        debug_assert!(state.owned.len() <= self.capacity);
    }

    fn push_assigned(delta: &mut Delta, w: WorkerId, ids: Vec<DataId>) {
        if let Some(slot) = delta.assigned.iter_mut().find(|(id, _)| *id == w) {
            slot.1.extend(ids);
        } else {
            delta.assigned.push((w, ids));
        }
    }

    fn fill_from_unallocated(&mut self) -> Delta {
        self.fill_from_unallocated_excluding(None)
    }

    /// Spread unallocated ids across workers, least-loaded first, up to
    /// capacity.  Balanced: repeatedly give to the minimum-loaded worker.
    fn fill_from_unallocated_excluding(&mut self, except: Option<WorkerId>) -> Delta {
        let mut delta = Delta::default();
        if self.workers.is_empty() || self.unallocated.is_empty() {
            return delta;
        }
        // load heap emulated with a sorted vec (fleet sizes are ≤ hundreds)
        let mut loads: Vec<(usize, WorkerId)> = self
            .workers
            .iter()
            .filter(|(w, _)| Some(**w) != except)
            .map(|(w, s)| (s.owned.len(), *w))
            .collect();
        if loads.is_empty() {
            return delta;
        }
        let mut grants: BTreeMap<WorkerId, Vec<DataId>> = BTreeMap::new();
        while let Some(id) = self.unallocated.pop() {
            loads.sort_unstable();
            let Some(slot) = loads.iter_mut().find(|(load, _)| *load < self.capacity)
            else {
                self.unallocated.push(id);
                break;
            };
            grants.entry(slot.1).or_default().push(id);
            slot.0 += 1;
        }
        for (w, ids) in grants {
            self.assign(w, &ids);
            Self::push_assigned(&mut delta, w, ids);
        }
        delta
    }

    // ------------------------------------------------------- checkpointing

    /// Full allocation state in worker-id order — for checkpointing.
    /// The `owner` map is derivable from the owned lists, so it is not
    /// exported; `cached` flags are exported as id lists (they survive
    /// revokes, so they are *not* derivable from current ownership).
    pub fn export_state(&self) -> AllocatorState {
        AllocatorState {
            capacity: self.capacity,
            total_data: self.owner.len() as u64,
            workers: self
                .workers
                .iter()
                .map(|(&id, s)| WorkerAllocState {
                    id,
                    owned: s.owned.clone(),
                    cached: (0..s.cached.len() as DataId)
                        .filter(|&i| s.cached[i as usize])
                        .collect(),
                })
                .collect(),
            unallocated: self.unallocated.clone(),
            transfers: self.transfers,
        }
    }

    /// Rebuild an allocator from a captured export; panics (via the
    /// invariant check) on structurally inconsistent state rather than
    /// training on a corrupt allocation.
    pub fn from_state(state: &AllocatorState) -> Self {
        let mut alloc = Self::new(state.capacity);
        alloc.owner = vec![None; state.total_data as usize];
        alloc.transfers = state.transfers;
        alloc.unallocated = state.unallocated.clone();
        for w in &state.workers {
            let mut ws = WorkerState {
                owned: w.owned.clone(),
                cached: Vec::new(),
            };
            for &id in &w.cached {
                ws.set_cached(id);
            }
            for &id in &w.owned {
                alloc.owner[id as usize] = Some(w.id);
            }
            alloc.workers.insert(w.id, ws);
        }
        if let Err(e) = alloc.check_invariants() {
            panic!("restored allocator state is inconsistent: {e}");
        }
        alloc
    }

    // --------------------------------------------------------- invariants

    /// Structural invariants — called by tests after every event.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.owner.len()];
        for (w, state) in &self.workers {
            if state.owned.len() > self.capacity {
                return Err(format!("worker {w} over capacity: {}", state.owned.len()));
            }
            for &id in &state.owned {
                if self.owner.get(id as usize).copied().flatten() != Some(*w) {
                    return Err(format!("id {id} owner map disagrees for worker {w}"));
                }
                if seen[id as usize] {
                    return Err(format!("id {id} owned twice"));
                }
                seen[id as usize] = true;
            }
        }
        for &id in &self.unallocated {
            if self.owner[id as usize].is_some() {
                return Err(format!("id {id} both unallocated and owned"));
            }
            if seen[id as usize] {
                return Err(format!("id {id} duplicated in unallocated"));
            }
            seen[id as usize] = true;
        }
        if let Some(missing) = seen.iter().position(|s| !s) {
            return Err(format!("id {missing} neither owned nor unallocated"));
        }
        Ok(())
    }

    /// Balance metric: max-owned − min-owned over workers.
    pub fn imbalance(&self) -> usize {
        let counts: Vec<usize> = self.workers.values().map(|s| s.owned.len()).collect();
        match (counts.iter().max(), counts.iter().min()) {
            (Some(mx), Some(mn)) => mx - mn,
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn checked(alloc: &Allocator) {
        alloc.check_invariants().unwrap();
    }

    #[test]
    fn data_then_workers() {
        let mut a = Allocator::new(3000);
        a.add_data(100);
        checked(&a);
        assert_eq!(a.unallocated().len(), 100);
        let d = a.worker_join(1);
        checked(&a);
        assert_eq!(d.assigned.len(), 1);
        assert_eq!(a.owned_by(1).len(), 100);
    }

    #[test]
    fn capacity_is_respected() {
        let mut a = Allocator::new(30);
        a.add_data(100);
        a.worker_join(1);
        checked(&a);
        assert_eq!(a.owned_by(1).len(), 30);
        assert_eq!(a.unallocated().len(), 70);
        a.worker_join(2);
        a.worker_join(3);
        a.worker_join(4);
        checked(&a);
        assert_eq!(a.allocated_count(), 100); // 30+30+30+10
    }

    #[test]
    fn paper_policy_one_node_gets_3000_of_60000() {
        // §3.5: "using only 1 slave node trains on 3/60 of the full set"
        let mut a = Allocator::new(3000);
        a.add_data(60_000);
        a.worker_join(1);
        assert_eq!(a.owned_by(1).len(), 3000);
        // "With 20 nodes, the network is training on the full dataset."
        for w in 2..=20 {
            a.worker_join(w);
        }
        checked(&a);
        assert_eq!(a.allocated_count(), 60_000);
        assert_eq!(a.unallocated().len(), 0);
    }

    #[test]
    fn pie_cutter_steals_from_largest() {
        let mut a = Allocator::new(3000);
        a.add_data(90);
        a.worker_join(1); // takes all 90
        let d = a.worker_join(2); // fair share 45: steal 45 from w1
        checked(&a);
        assert_eq!(a.owned_by(1).len(), 45);
        assert_eq!(a.owned_by(2).len(), 45);
        assert_eq!(d.revoked.len(), 1);
        assert_eq!(d.revoked[0].0, 1);
        assert_eq!(d.moved(), 45);
    }

    #[test]
    fn pie_cutter_transfers_bounded_by_fair_share() {
        // Joining the N-th worker moves only ~total/N ids, not O(total).
        let mut a = Allocator::new(3000);
        a.add_data(1000);
        for w in 1..=4 {
            a.worker_join(w);
        }
        let before = a.transfer_count();
        let d = a.worker_join(5);
        checked(&a);
        assert!(d.moved() <= 1000 / 5 + 4, "moved {}", d.moved());
        assert!(a.transfer_count() - before <= 204);
        assert!(a.imbalance() <= 1 + 4, "imbalance {}", a.imbalance());
    }

    #[test]
    fn leave_reallocates_to_survivors() {
        let mut a = Allocator::new(3000);
        a.add_data(100);
        a.worker_join(1);
        a.worker_join(2);
        let d = a.worker_leave(1);
        checked(&a);
        assert_eq!(a.owned_by(2).len(), 100);
        assert_eq!(d.assigned.len(), 1);
        assert!(a.unallocated().is_empty());
    }

    #[test]
    fn leave_with_no_survivors_marks_unallocated() {
        let mut a = Allocator::new(3000);
        a.add_data(50);
        a.worker_join(1);
        a.worker_leave(1);
        checked(&a);
        assert_eq!(a.unallocated().len(), 50);
    }

    #[test]
    fn leave_overflow_goes_unallocated() {
        let mut a = Allocator::new(60);
        a.add_data(100);
        a.worker_join(1);
        a.worker_join(2); // 50/50
        a.worker_leave(2); // w1 can only take 10 more
        checked(&a);
        assert_eq!(a.owned_by(1).len(), 60);
        assert_eq!(a.unallocated().len(), 40);
    }

    #[test]
    fn cached_ids_do_not_count_as_transfers() {
        let mut a = Allocator::new(3000);
        a.add_data(10);
        a.worker_join(1);
        let t0 = a.transfer_count();
        assert_eq!(t0, 10);
        for id in 0..10 {
            a.mark_cached(1, id);
        }
        // churn: leave and rejoin — all ids still cached on w1
        a.worker_leave(1);
        // (cache survives on the client; allocator forgets workers on leave,
        //  so a rejoin is a *new* worker id in this model)
        let mut a2 = a.clone();
        a2.worker_join(2); // uncached worker: 10 transfers
        assert_eq!(a2.transfer_count(), 20);
    }

    #[test]
    fn shed_load_moves_to_others() {
        let mut a = Allocator::new(3000);
        a.add_data(100);
        a.worker_join(1);
        a.worker_join(2);
        let d = a.shed_load(1, 20);
        checked(&a);
        assert_eq!(a.owned_by(1).len(), 30);
        assert_eq!(a.owned_by(2).len(), 70);
        assert_eq!(d.revoked[0], (1, d.revoked[0].1.clone()));
    }

    #[test]
    fn naive_rebalance_is_balanced_but_expensive() {
        let mut a = Allocator::new(3000);
        a.add_data(100);
        a.worker_join(1);
        let t_pie = {
            let mut b = a.clone();
            let d = b.worker_join(2);
            d.moved()
        };
        let d = {
            a.workers.insert(2, WorkerState::default());
            a.rebalance_naive()
        };
        a.check_invariants().unwrap();
        assert!(a.imbalance() <= 1);
        assert!(d.moved() >= t_pie, "naive {} < pie {}", d.moved(), t_pie);
    }

    #[test]
    fn export_from_state_roundtrip_preserves_behavior() {
        let mut a = Allocator::new(40);
        a.add_data(100);
        a.worker_join(1);
        a.worker_join(2);
        for id in 0..10 {
            a.mark_cached(1, id);
        }
        a.shed_load(1, 5);
        checked(&a);

        let state = a.export_state();
        let mut b = Allocator::from_state(&state);
        checked(&b);
        assert_eq!(b.export_state(), state);
        assert_eq!(b.transfer_count(), a.transfer_count());

        // Post-restore events make identical decisions (owned-list order
        // drives take_from/fair-share, so it must have survived exactly).
        let da = a.worker_join(3);
        let db = b.worker_join(3);
        assert_eq!(da, db);
        assert_eq!(a.export_state(), b.export_state());
        // Cached flags survived: re-assigning a cached id costs no transfer.
        let ta = a.transfer_count();
        assert_eq!(ta, b.transfer_count());
    }

    #[test]
    #[should_panic(expected = "inconsistent")]
    fn from_state_rejects_double_ownership() {
        let state = AllocatorState {
            capacity: 10,
            total_data: 2,
            workers: vec![
                WorkerAllocState {
                    id: 1,
                    owned: vec![0, 1],
                    cached: vec![],
                },
                WorkerAllocState {
                    id: 2,
                    owned: vec![1],
                    cached: vec![],
                },
            ],
            unallocated: vec![],
            transfers: 0,
        };
        Allocator::from_state(&state);
    }

    #[test]
    fn empty_allocator_events_are_safe() {
        let mut a = Allocator::new(10);
        assert!(a.worker_leave(99).is_empty());
        assert!(a.shed_load(1, 5).is_empty());
        let d = a.worker_join(1);
        assert!(d.is_empty());
        checked(&a);
    }
}
