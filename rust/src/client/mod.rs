//! Simulated client fleet — the browsers of the paper.
//!
//! Each client mirrors the paper's worker decomposition (§3.2, Fig 3): a
//! *boss* (UI worker) owning a data-download worker and slave workers
//! (trainer / tracker).  Here the boss is a state machine driven by the
//! discrete-event simulation: it manages the sample cache, the pending
//! download queue (training may start before the full allocation is
//! cached, §3.3a), and produces gradient submissions whose timing comes
//! from the device's power and link models.

mod device;
mod sim_client;

pub use device::{DeviceClass, DeviceProfile};
pub use sim_client::{ClientState, SimClient, TrainOutput};
