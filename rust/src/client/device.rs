//! Device heterogeneity profiles.
//!
//! The paper's fleet spans workstations on a LAN (the §3.5 experiment:
//! dual-core i3 desktops), laptops on wifi, and phones/tablets on cellular
//! links (§3.3d: "it is possible to have mobile devices that compute only
//! a few gradients per second and a powerful desktop machine that performs
//! hundreds or thousands").  A profile is (compute rate, link class);
//! rates are per-device samples around the class mean, so no two devices
//! are identical.

use crate::netsim::LinkProfile;
use crate::rng::{Normal, Pcg32};

/// Device class, defining compute-rate and link-class priors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    /// §3.5 grid workstation (LAN, the scaling experiment's node).
    Workstation,
    /// Volunteer desktop (LAN/ethernet).
    Desktop,
    /// Laptop on wifi.
    Laptop,
    /// Phone/tablet on cellular.
    Mobile,
}

impl DeviceClass {
    /// (mean vectors/sec on the reference conv model, std, link class).
    /// The workstation rate is calibrated so a 4-second iteration
    /// processes ~1000 vectors — the order the paper's Fig 4 implies
    /// (power ≈ 250·N vectors/s up to the knee).
    fn constants(self) -> (f64, f64, LinkProfile) {
        match self {
            // Identical grid SKUs (the paper's 32 i3 workstations): tight
            // spread so fleet power normalizes cleanly in Fig 4.
            DeviceClass::Workstation => (250.0, 6.0, LinkProfile::Lan),
            DeviceClass::Desktop => (180.0, 30.0, LinkProfile::Lan),
            DeviceClass::Laptop => (100.0, 25.0, LinkProfile::Wifi),
            DeviceClass::Mobile => (20.0, 8.0, LinkProfile::Cellular),
        }
    }

    /// Stable name — the inverse of [`parse`](Self::parse), used by the
    /// CLI tables and the checkpoint codec.
    pub fn name(self) -> &'static str {
        match self {
            Self::Workstation => "workstation",
            Self::Desktop => "desktop",
            Self::Laptop => "laptop",
            Self::Mobile => "mobile",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "workstation" => Ok(Self::Workstation),
            "desktop" => Ok(Self::Desktop),
            "laptop" => Ok(Self::Laptop),
            "mobile" => Ok(Self::Mobile),
            _ => Err(format!(
                "unknown device class '{s}' (workstation|desktop|laptop|mobile)"
            )),
        }
    }

    /// Sample a concrete device of this class.
    pub fn sample_profile(self, rng: &mut Pcg32) -> DeviceProfile {
        let (mean, std, link) = self.constants();
        let power = Normal::new(mean, std).sample(rng).max(mean * 0.2);
        DeviceProfile {
            class: self,
            power_vps: power,
            link,
        }
    }
}

/// A concrete simulated device.
#[derive(Debug, Clone, Copy)]
pub struct DeviceProfile {
    pub class: DeviceClass,
    /// Gradient-computation rate, data vectors per second, on the
    /// reference model (scaled by the model's relative cost at use sites).
    pub power_vps: f64,
    pub link: LinkProfile,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_are_ordered_by_power() {
        let mut rng = Pcg32::new(1);
        let mut mean = |class: DeviceClass| -> f64 {
            (0..50)
                .map(|_| class.sample_profile(&mut rng).power_vps)
                .sum::<f64>()
                / 50.0
        };
        let ws = mean(DeviceClass::Workstation);
        let mob = mean(DeviceClass::Mobile);
        assert!(ws > 5.0 * mob, "workstation {ws} vs mobile {mob}");
    }

    #[test]
    fn power_is_positive() {
        let mut rng = Pcg32::new(2);
        for _ in 0..200 {
            let p = DeviceClass::Mobile.sample_profile(&mut rng);
            assert!(p.power_vps > 0.0);
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(DeviceClass::parse("mobile").unwrap(), DeviceClass::Mobile);
        assert!(DeviceClass::parse("toaster").is_err());
    }
}
