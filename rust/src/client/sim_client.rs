//! The simulated client: boss + data worker + trainer in one state machine.

use std::collections::{HashMap, VecDeque};

use anyhow::Result;

use crate::allocation::{DataId, WorkerId};
use crate::data::{ClientCache, DataServer, SharedSample};
use crate::faults::FaultPlan;
use crate::model::ModelSpec;
use crate::netsim::LinkModel;
use crate::rng::Pcg32;
use crate::runtime::{BatchBuilder, Compute};

use super::DeviceProfile;

/// Result of one trainer map-step on this client.
#[derive(Debug, Clone)]
pub struct TrainOutput {
    /// Σ gradient over all processed examples.
    pub grad_sum: Vec<f32>,
    pub examples: u64,
    pub loss_sum: f64,
    /// Compute time actually consumed (ms) — may exceed the budget by up
    /// to one microbatch (the client only checks the clock between
    /// batches, like the paper's JS trainer between gradient steps).
    pub compute_ms: f64,
}

/// Serializable client snapshot — see [`SimClient::export_state`]. The
/// device profile is stored by (class, sampled power, link placement);
/// the link's jitter distribution is derivable from those, so restore
/// reconstructs a bitwise-identical [`LinkModel`].
#[derive(Debug, Clone, PartialEq)]
pub struct ClientState {
    pub id: WorkerId,
    pub class: super::DeviceClass,
    pub power_vps: f64,
    pub link_profile: crate::netsim::LinkProfile,
    pub link_base_ms: f64,
    pub rng_state: u64,
    pub rng_inc: u64,
    pub owned: Vec<DataId>,
    pub pending: Vec<DataId>,
    pub cursor: u64,
    pub cache: crate::data::CacheState,
}

/// One simulated browser client.
pub struct SimClient {
    pub id: WorkerId,
    pub profile: DeviceProfile,
    pub link: LinkModel,
    cache: ClientCache,
    /// Current allocation (ids this worker trains on).
    owned: Vec<DataId>,
    /// Allocated ids not yet downloaded (§3.3a background caching).
    pending: VecDeque<DataId>,
    cursor: usize,
    pub rng: Pcg32,
    /// Reused gradient-accumulation buffer.
    grad_buf: Vec<f32>,
    /// Batch builders per microbatch size (lazily created).
    /// Determinism audit: point access only (entry by size key) —
    /// never iterated, so map order cannot reach observable state.
    builders: HashMap<usize, BatchBuilder>,
}

impl SimClient {
    pub fn new(
        id: WorkerId,
        profile: DeviceProfile,
        cache_budget_bytes: u64,
        rng: &mut Pcg32,
    ) -> Self {
        let mut rng = rng.fork(id);
        let link = LinkModel::new(profile.link, &mut rng);
        Self {
            id,
            profile,
            link,
            cache: ClientCache::new(cache_budget_bytes),
            owned: Vec::new(),
            pending: VecDeque::new(),
            cursor: 0,
            rng,
            grad_buf: Vec::new(),
            builders: HashMap::new(),
        }
    }

    // ----------------------------------------------------- checkpointing

    /// Everything needed to rebuild this client bitwise: identity, the
    /// sampled device/link placement, the rng stream position, the
    /// allocation view and the cache structure. Sample pixels and the
    /// gradient/batch scratch buffers are rebuilt on restore (pixels from
    /// the deterministic corpus, scratch lazily on first use — neither
    /// affects observable behavior).
    pub fn export_state(&self) -> ClientState {
        let (rng_state, rng_inc) = self.rng.state();
        ClientState {
            id: self.id,
            class: self.profile.class,
            power_vps: self.profile.power_vps,
            link_profile: self.profile.link,
            link_base_ms: self.link.base_ms(),
            rng_state,
            rng_inc,
            owned: self.owned.clone(),
            pending: self.pending.iter().copied().collect(),
            cursor: self.cursor as u64,
            cache: self.cache.export_state(),
        }
    }

    /// Rebuild a client from a captured export, refetching cached sample
    /// bytes from the data server.
    pub fn from_state(state: &ClientState, cache_budget_bytes: u64, server: &DataServer) -> Self {
        Self {
            id: state.id,
            profile: DeviceProfile {
                class: state.class,
                power_vps: state.power_vps,
                link: state.link_profile,
            },
            link: LinkModel::from_base(state.link_profile, state.link_base_ms),
            cache: ClientCache::restore(cache_budget_bytes, &state.cache, |id| {
                SharedSample::clone(
                    server
                        .get(id)
                        .unwrap_or_else(|| panic!("cached id {id} missing from data server")),
                )
            }),
            owned: state.owned.clone(),
            pending: state.pending.iter().copied().collect(),
            cursor: state.cursor as usize,
            rng: Pcg32::from_state(state.rng_state, state.rng_inc),
            grad_buf: Vec::new(),
            builders: HashMap::new(),
        }
    }

    // -------------------------------------------------------- allocation

    /// Assign ids (enqueue downloads for anything not already cached).
    pub fn assign(&mut self, ids: &[DataId]) {
        for &id in ids {
            self.owned.push(id);
            if self.cache.contains(id) {
                self.cache.set_pinned(id, true);
            } else {
                self.pending.push_back(id);
            }
        }
    }

    /// Revoke ids (stop training on them; cached copies stay evictable —
    /// the paper's redundant cache makes a later re-assignment free).
    pub fn revoke(&mut self, ids: &[DataId]) {
        self.owned.retain(|id| !ids.contains(id));
        self.pending.retain(|id| !ids.contains(id));
        for &id in ids {
            self.cache.set_pinned(id, false);
        }
    }

    pub fn owned(&self) -> &[DataId] {
        &self.owned
    }

    pub fn cached_owned(&self) -> usize {
        self.owned.iter().filter(|&&id| self.cache.contains(id)).count()
    }

    pub fn pending_downloads(&self) -> usize {
        self.pending.len()
    }

    // ---------------------------------------------------------- data path

    /// Data-worker step: download pending ids, limited by a byte budget
    /// (one iteration of background XHR at the device's downlink rate).
    /// Returns (ids fetched, wire bytes).  The master should be told via
    /// `Allocator::mark_cached` for each returned id.
    pub fn download_step(
        &mut self,
        server: &DataServer,
        byte_budget: u64,
    ) -> (Vec<DataId>, u64) {
        let mut got = Vec::new();
        let mut bytes = 0u64;
        while let Some(&id) = self.pending.front() {
            let (samples, stats) = server.serve(&[id]);
            let Some((_, sample)) = samples.into_iter().next() else {
                // unknown id: drop it
                self.pending.pop_front();
                continue;
            };
            if bytes + stats.bytes > byte_budget && !got.is_empty() {
                break;
            }
            bytes += stats.bytes;
            self.cache.insert(id, sample, true);
            self.pending.pop_front();
            got.push(id);
            if bytes >= byte_budget {
                break;
            }
        }
        (got, bytes)
    }

    /// Samples this trainer can actually use right now (owned ∩ cached).
    fn usable_samples(&mut self) -> Vec<SharedSample> {
        let ids: Vec<DataId> = self
            .owned
            .iter()
            .copied()
            .filter(|&id| self.cache.contains(id))
            .collect();
        ids.iter().filter_map(|&id| self.cache.get(id)).collect()
    }

    // ------------------------------------------------------------ trainer

    /// Map step (§3.6): run as many gradient microbatches as fit in
    /// `budget_ms` at this device's rate, accumulating Σ-gradients.
    ///
    /// The work quantum adapts to the device: the largest compiled
    /// microbatch whose compute time fits the budget (weak devices drop
    /// to B=8 or B=1 — the paper's mobiles compute "only a few gradients
    /// per second", §3.3d).  Returns None when no usable data is cached.
    pub fn train(
        &mut self,
        compute: &mut dyn Compute,
        spec: &ModelSpec,
        params: &[f32],
        budget_ms: f64,
    ) -> Result<Option<TrainOutput>> {
        let samples = self.usable_samples();
        if samples.is_empty() {
            return Ok(None);
        }
        let bsz = spec.pick_micro_batch(self.profile.power_vps, budget_ms);
        let batch = self
            .builders
            .entry(bsz)
            .or_insert_with(|| BatchBuilder::new(bsz, spec.input_len()));
        let ms_per_batch = bsz as f64 / self.profile.power_vps * 1000.0;
        // At least one batch (the clock is only checked between batches).
        let n_batches = ((budget_ms / ms_per_batch).floor() as usize).max(1);

        self.grad_buf.clear();
        self.grad_buf.resize(params.len(), 0.0);
        let mut examples = 0u64;
        let mut loss_sum = 0.0f64;
        for _ in 0..n_batches {
            self.cursor = batch.fill_cyclic(&samples, self.cursor);
            let out =
                compute.grad_batch(&spec.name, bsz, params, batch.images(), batch.labels())?;
            crate::params::add_assign(&mut self.grad_buf, &out.grads);
            examples += bsz as u64;
            loss_sum += out.loss_sum as f64;
        }
        Ok(Some(TrainOutput {
            grad_sum: self.grad_buf.clone(),
            examples,
            loss_sum,
            compute_ms: n_batches as f64 * ms_per_batch,
        }))
    }

    // ------------------------------------------------------------- uplink

    /// Uplink delay for a gradient message of `bytes`, with fault-plane
    /// drop + retry/backoff: each lost attempt costs its wire time plus a
    /// seeded exponential backoff, and the client gives up once the next
    /// send would start past `deadline_ms` (the submission is lost —
    /// quorum/carryover at the master absorb the gap).  `start_ms` is the
    /// send start within the iteration (compute end).  With an inactive
    /// plan this draws exactly one jitter sample — bitwise-identical to
    /// the pre-fault-plane upload path.
    pub fn upload_ms(
        &mut self,
        bytes: u64,
        start_ms: f64,
        deadline_ms: f64,
        plan: &FaultPlan,
        iteration: u64,
    ) -> Option<f64> {
        let mut elapsed = 0.0;
        let mut attempt = 0u32;
        loop {
            let send = self.link.sample_latency_ms(&mut self.rng) + self.link.transmit_ms(bytes);
            if !plan.upload_dropped(self.id, iteration, attempt) {
                return Some(elapsed + send);
            }
            elapsed += send + self.link.retry_backoff_ms(attempt, &mut self.rng);
            attempt += 1;
            if start_ms + elapsed > deadline_ms {
                return None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::DeviceClass;
    use crate::data::{SynthSpec, Synthesizer};
    use crate::runtime::ModeledCompute;

    fn client(id: WorkerId) -> SimClient {
        let mut rng = Pcg32::new(9);
        let profile = DeviceClass::Workstation.sample_profile(&mut rng);
        SimClient::new(id, profile, 100 << 20, &mut rng)
    }

    fn server(n: usize) -> DataServer {
        let mut ds = DataServer::new();
        ds.upload_samples(Synthesizer::new(SynthSpec::mnist(1)).corpus(n));
        ds
    }

    fn spec(param_count: usize, batches: Vec<usize>) -> ModelSpec {
        ModelSpec {
            name: "m".into(),
            param_count,
            batch_size: batches[0],
            micro_batches: batches,
            input: vec![28, 28, 1],
            classes: 10,
            tensors: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn assign_download_train_cycle() {
        let mut c = client(1);
        let ds = server(50);
        c.assign(&(0..50).collect::<Vec<_>>());
        assert_eq!(c.pending_downloads(), 50);
        let (got, bytes) = c.download_step(&ds, u64::MAX);
        assert_eq!(got.len(), 50);
        assert!(bytes > 0);
        assert_eq!(c.cached_owned(), 50);

        let mut compute = ModeledCompute { param_count: 4 };
        let sp = spec(4, vec![8]);
        let out = c
            .train(&mut compute, &sp, &[0.0; 4], 1000.0)
            .unwrap()
            .unwrap();
        assert!(out.examples >= 8);
        assert!(out.compute_ms > 0.0);
        assert_eq!(out.grad_sum.len(), 4);
    }

    #[test]
    fn export_from_state_roundtrip_is_bitwise() {
        let mut c = client(11);
        let ds = server(40);
        c.assign(&(0..40).collect::<Vec<_>>());
        c.download_step(&ds, 50_000); // partial download: pending survives
        c.revoke(&[0, 1]);
        // consume some rng so the stream position is non-trivial
        c.link.sample_latency_ms(&mut c.rng);

        let state = c.export_state();
        let mut r = SimClient::from_state(&state, 100 << 20, &ds);
        assert_eq!(r.export_state(), state);

        // Behavior after restore is bitwise-identical: same downloads,
        // same training output bits, same jitter samples.
        let (got_a, bytes_a) = c.download_step(&ds, 20_000);
        let (got_b, bytes_b) = r.download_step(&ds, 20_000);
        assert_eq!(got_a, got_b);
        assert_eq!(bytes_a, bytes_b);
        let mut compute = ModeledCompute { param_count: 4 };
        let sp = spec(4, vec![8]);
        let out_a = c.train(&mut compute, &sp, &[0.1; 4], 800.0).unwrap().unwrap();
        let out_b = r.train(&mut compute, &sp, &[0.1; 4], 800.0).unwrap().unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&out_a.grad_sum), bits(&out_b.grad_sum));
        assert_eq!(out_a.examples, out_b.examples);
        assert_eq!(
            c.link.sample_latency_ms(&mut c.rng).to_bits(),
            r.link.sample_latency_ms(&mut r.rng).to_bits()
        );
    }

    #[test]
    fn upload_with_inactive_faults_is_the_plain_path() {
        // The fault-plane hook must be invisible when off: exactly one
        // jitter sample, bitwise-equal to latency + transmit.
        let mut a = client(9);
        let mut b = client(9);
        let plan = FaultPlan::new(crate::faults::FaultProfile::none(), 1);
        let up = a.upload_ms(4096, 100.0, 8000.0, &plan, 3).unwrap();
        let want = b.link.sample_latency_ms(&mut b.rng) + b.link.transmit_ms(4096);
        assert_eq!(up.to_bits(), want.to_bits());
        // And the rng streams stay aligned afterwards.
        assert_eq!(
            a.link.sample_latency_ms(&mut a.rng).to_bits(),
            b.link.sample_latency_ms(&mut b.rng).to_bits()
        );
    }

    #[test]
    fn dropped_uploads_retry_with_backoff_then_give_up() {
        let mut c = client(10);
        let mut profile = crate::faults::FaultProfile::parse("flaky").unwrap();
        profile.drop_prob = 1.0; // every attempt lost
        let plan = FaultPlan::new(profile, 5);
        assert!(
            c.upload_ms(4096, 0.0, 2000.0, &plan, 0).is_none(),
            "all-drop link must miss the deadline"
        );

        // With a moderate drop rate the retry loop eventually delivers,
        // and the delivered delay includes the lost attempts' backoff.
        let mut c2 = client(10);
        let mut some_retried = false;
        let mut profile = crate::faults::FaultProfile::parse("flaky").unwrap();
        profile.drop_prob = 0.5;
        let plan = FaultPlan::new(profile, 5);
        let plain = {
            let mut d = client(10);
            d.link.sample_latency_ms(&mut d.rng) + d.link.transmit_ms(4096)
        };
        for it in 0..32 {
            if let Some(up) = c2.upload_ms(4096, 0.0, 60_000.0, &plan, it) {
                if up > plain * 3.0 {
                    some_retried = true;
                }
            }
        }
        assert!(some_retried, "0.5 drop over 32 iterations never retried");
    }

    #[test]
    fn train_without_data_returns_none() {
        let mut c = client(2);
        let mut compute = ModeledCompute { param_count: 4 };
        let sp = spec(4, vec![8]);
        assert!(c
            .train(&mut compute, &sp, &[0.0; 4], 1000.0)
            .unwrap()
            .is_none());
    }

    #[test]
    fn byte_budget_limits_downloads() {
        let mut c = client(3);
        let ds = server(50);
        c.assign(&(0..50).collect::<Vec<_>>());
        // Each sample ~2.8 KB compressed; budget ~5 samples
        let (got, bytes) = c.download_step(&ds, 15_000);
        assert!(got.len() < 50, "{}", got.len());
        assert!(!got.is_empty());
        assert!(bytes <= 16_000);
        // rest still pending; next step continues
        let before = c.pending_downloads();
        c.download_step(&ds, 15_000);
        assert!(c.pending_downloads() < before);
    }

    #[test]
    fn training_starts_with_partial_cache() {
        // §3.3a: "allowing projects to start training almost immediately
        // while data gets cached in the background."
        let mut c = client(4);
        let ds = server(50);
        c.assign(&(0..50).collect::<Vec<_>>());
        c.download_step(&ds, 10_000); // only a few cached
        let mut compute = ModeledCompute { param_count: 2 };
        let sp = spec(2, vec![4]);
        let out = c.train(&mut compute, &sp, &[0.0; 2], 500.0).unwrap();
        assert!(out.is_some());
    }

    #[test]
    fn revoke_stops_training_on_ids_but_keeps_cache() {
        let mut c = client(5);
        let ds = server(10);
        c.assign(&(0..10).collect::<Vec<_>>());
        c.download_step(&ds, u64::MAX);
        c.revoke(&(0..5).collect::<Vec<_>>());
        assert_eq!(c.owned().len(), 5);
        assert_eq!(c.cached_owned(), 5);
        // re-assign is free (cache hit, no pending)
        c.assign(&[0, 1]);
        assert_eq!(c.pending_downloads(), 0);
    }

    #[test]
    fn budget_scales_batch_count() {
        let mut c = client(6);
        let ds = server(32);
        c.assign(&(0..32).collect::<Vec<_>>());
        c.download_step(&ds, u64::MAX);
        let mut compute = ModeledCompute { param_count: 2 };
        let sp = spec(2, vec![8]);
        let small = c
            .train(&mut compute, &sp, &[0.0; 2], 100.0)
            .unwrap()
            .unwrap();
        let large = c
            .train(&mut compute, &sp, &[0.0; 2], 4000.0)
            .unwrap()
            .unwrap();
        assert!(large.examples > small.examples);
    }

    #[test]
    fn weak_device_picks_small_quantum() {
        // A mobile at ~2 vec/s must drop to the B=1 artifact instead of
        // blowing the sync barrier with one 16-second B=32 batch (§3.3d).
        let mut rng = Pcg32::new(4);
        let mut profile = DeviceClass::Mobile.sample_profile(&mut rng);
        profile.power_vps = 2.0;
        let mut c = SimClient::new(7, profile, 100 << 20, &mut rng);
        let ds = server(10);
        c.assign(&(0..10).collect::<Vec<_>>());
        c.download_step(&ds, u64::MAX);
        let sp = spec(2, vec![32, 8, 1]);
        let mut compute = ModeledCompute { param_count: 2 };
        let out = c
            .train(&mut compute, &sp, &[0.0; 2], 3900.0)
            .unwrap()
            .unwrap();
        // 2 vec/s × 3.9 s budget → ~7 single-vector batches, ≤ budget+1
        assert!(out.examples <= 8, "{}", out.examples);
        assert!(
            out.compute_ms <= 4000.0,
            "compute {} ms blew the barrier",
            out.compute_ms
        );
    }

    #[test]
    fn strong_device_keeps_large_quantum() {
        let mut c = client(8); // workstation ~250 vps
        let ds = server(64);
        c.assign(&(0..64).collect::<Vec<_>>());
        c.download_step(&ds, u64::MAX);
        let sp = spec(2, vec![32, 8, 1]);
        let mut compute = ModeledCompute { param_count: 2 };
        let out = c
            .train(&mut compute, &sp, &[0.0; 2], 3900.0)
            .unwrap()
            .unwrap();
        // ~250 vec/s × 3.9 s ≈ 975 examples in B=32 quanta
        assert!(out.examples >= 800, "{}", out.examples);
        assert_eq!(out.examples % 32, 0, "should use the B=32 quantum");
    }
}
