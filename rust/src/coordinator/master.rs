//! The master state machine: projects, the five-step event loop, reduce.

use std::collections::BTreeMap;

use crate::allocation::{Allocator, AllocatorState, Delta, WorkerId};
use crate::metrics::{IterationRecord, Timeline};
use crate::netsim::MasterModel;
use crate::params::{AggregationMode, GradView, Optimizer, OptimizerKind, ShardedAccumulator};
use crate::storage::{digest_f32s, Fnv64, WalRecord, WalWriter};
use crate::trace::{ArgValue, TraceHandle, Track};

use super::{LatencyMonitor, Payload, ReducePolicy, Submission};

/// Master/project configuration (one project ≙ one NN being trained; the
/// paper's master hosts several — see `sim::Simulation` which can run
/// multiple masters).
#[derive(Debug, Clone)]
pub struct MasterConfig {
    pub param_count: usize,
    /// Iteration duration T in seconds (paper: 1–30 s, experiment: 4 s).
    pub iter_duration_s: f64,
    pub optimizer: OptimizerKind,
    pub learning_rate: f32,
    /// Per-worker data capacity (paper experiment: 3000).
    pub capacity: usize,
    pub policy: ReducePolicy,
    /// Master ingestion model (bandwidth, per-message cost, #processes).
    pub master_model: MasterModel,
    /// Latency fraction of T above which a worker sheds data (§3.3d).
    pub shed_threshold: f64,
    /// How merged gradients combine into the optimizer input.  `Mean` is
    /// the paper's weighted average through the bitwise-pinned
    /// [`ShardedAccumulator`] path; the robust modes defend against
    /// hostile submissions (see `params::robust`).
    pub aggregation: AggregationMode,
    /// Graceful degradation: with `quorum` ∈ (0, 1] under a synchronous
    /// policy, the barrier releases once ⌈quorum·workers⌉ fresh valid
    /// submissions have drained; stragglers flow into carryover.  0
    /// disables (strict barrier).
    pub quorum: f64,
    /// Quarantined (non-finite) submissions before a worker is evicted.
    pub strike_limit: u32,
}

impl MasterConfig {
    /// Optimizer name for closures/CLI output.
    pub fn optimizer_name(&self) -> String {
        match self.optimizer {
            OptimizerKind::Sgd => "sgd".into(),
            OptimizerKind::Momentum => "momentum".into(),
            OptimizerKind::AdaGrad => "adagrad".into(),
            OptimizerKind::RmsProp => "rmsprop".into(),
        }
    }
}

impl Default for MasterConfig {
    fn default() -> Self {
        Self {
            param_count: 0,
            iter_duration_s: 4.0,
            optimizer: OptimizerKind::AdaGrad,
            learning_rate: 0.01,
            capacity: crate::allocation::PAPER_CAPACITY,
            policy: ReducePolicy::Sync,
            master_model: MasterModel::default(),
            shed_threshold: 0.5,
            aggregation: AggregationMode::Mean,
            quorum: 0.0,
            strike_limit: 3,
        }
    }
}

/// What one master-loop iteration produced.
#[derive(Debug, Clone)]
pub struct IterationOutcome {
    /// Wall-clock the iteration consumed (≥ T·1000 under Sync).
    pub wall_ms: f64,
    /// Mean/max observed per-submission completion latency beyond the
    /// scheduled compute time (network + master queueing) — Fig 4's
    /// latency metric.
    pub mean_latency_ms: f64,
    pub max_latency_ms: f64,
    /// Vectors processed by merged submissions.
    pub vectors: u64,
    /// Allocation changes triggered by §3.3d shedding this iteration.
    pub shed_deltas: Vec<(WorkerId, Delta)>,
    /// Master ingress bytes this iteration.
    pub bytes_up: u64,
    /// Broadcast bytes (step e).
    pub bytes_down: u64,
    /// Weighted mean training loss of merged work (None if nothing came).
    pub mean_loss: Option<f64>,
    /// Submissions rejected by the sanitation gate this iteration
    /// (non-finite payloads + duplicate deliveries).
    pub quarantined: u64,
    /// Workers evicted for exceeding the strike limit, with the
    /// reallocation delta the sim must apply (like a forced leave).
    pub evicted: Vec<(WorkerId, Delta)>,
}

/// Serializable form of a carryover [`Submission`] payload.
#[derive(Debug, Clone, PartialEq)]
pub enum PayloadState {
    Dense(Vec<f32>),
    Sparse(Vec<(u32, f32)>),
}

/// Serializable form of a carryover [`Submission`] (async policy: gradients
/// that missed an iteration close survive a checkpoint/restore).
#[derive(Debug, Clone, PartialEq)]
pub struct SubmissionState {
    pub worker: WorkerId,
    pub payload: PayloadState,
    pub examples: u64,
    pub vectors: u64,
    pub loss_sum: f64,
    pub send_offset_ms: f64,
    pub bytes: u64,
}

impl SubmissionState {
    fn from_submission(s: &Submission) -> Self {
        Self {
            worker: s.worker,
            payload: match &s.payload {
                Payload::Dense(v) => PayloadState::Dense(v.to_vec()),
                Payload::Sparse(e) => PayloadState::Sparse(e.clone()),
            },
            examples: s.examples,
            vectors: s.vectors,
            loss_sum: s.loss_sum,
            send_offset_ms: s.send_offset_ms,
            bytes: s.bytes,
        }
    }

    fn into_submission(self) -> Submission {
        Submission {
            worker: self.worker,
            payload: match self.payload {
                PayloadState::Dense(v) => Payload::dense(v),
                PayloadState::Sparse(e) => Payload::Sparse(e),
            },
            examples: self.examples,
            vectors: self.vectors,
            loss_sum: self.loss_sum,
            send_offset_ms: self.send_offset_ms,
            bytes: self.bytes,
        }
    }
}

/// Complete deterministic training state of one master, as captured into a
/// checkpoint frame by the storage plane.  Everything `finish_iteration`
/// reads across iterations is here; transient per-iteration buffers
/// (accumulator shards, `avg_scratch`) are rebuilt empty on restore.
#[derive(Debug, Clone, PartialEq)]
pub struct MasterState {
    pub iteration: u64,
    pub t_virtual_ms: f64,
    pub params: Vec<f32>,
    /// Optimizer kind name — restore refuses a checkpoint taken under a
    /// different optimizer (its state vectors would be meaningless).
    pub optimizer: String,
    /// Flattened optimizer accumulators (AdaGrad/RmsProp history,
    /// momentum velocity); empty for stateless SGD.
    pub opt_state: Vec<f32>,
    pub allocator: AllocatorState,
    pub latency: Vec<(WorkerId, f64)>,
    pub timeline: Vec<IterationRecord>,
    pub carryover: Vec<SubmissionState>,
    pub pending_test_error: Option<f64>,
    /// Sanitation strike counters (sorted by worker id).
    pub strikes: Vec<(WorkerId, u32)>,
}

/// One training project's master state.
pub struct Master {
    cfg: MasterConfig,
    params: Vec<f32>,
    optimizer: Box<dyn Optimizer>,
    allocator: Allocator,
    /// Sharded across `cfg.master_model.reduce_mode.shards()` threads —
    /// the *real* merge matches what the ingestion model charges for.
    accumulator: ShardedAccumulator,
    /// Pooled weighted-average buffer (reused every iteration).
    avg_scratch: Vec<f32>,
    latency: LatencyMonitor,
    iteration: u64,
    t_virtual_ms: f64,
    timeline: Timeline,
    /// Async policy: submissions that missed this iteration's close.
    carryover: Vec<Submission>,
    /// Sanitation strikes per worker (non-finite payloads); reaching
    /// `cfg.strike_limit` evicts the worker.  Evicted workers keep their
    /// count so a duplicate late delivery cannot reset them.
    strikes: BTreeMap<WorkerId, u32>,
    /// Test error reported by trackers since the last iteration record.
    pending_test_error: Option<f64>,
    /// Trace plane (off by default); `trace_pid` keys this master's
    /// tracks — the cosim assigns each project its own pid.
    trace: TraceHandle,
    trace_pid: u32,
    /// Storage plane: when set, every `finish_iteration` fingerprints its
    /// reduce (worker set, averaged gradient, post-step params) into a
    /// [`WalRecord`] — replay runs digest-only (no writer) and verifies.
    wal_seed: Option<u64>,
    /// Durable iteration log (buffered appends; synced by the checkpoint
    /// cadence via [`Master::wal_mut`]).
    wal: Option<WalWriter>,
    last_record: Option<WalRecord>,
}

impl Master {
    pub fn new(cfg: MasterConfig, init_params: Vec<f32>) -> Self {
        assert_eq!(init_params.len(), cfg.param_count, "param dim mismatch");
        let optimizer = cfg.optimizer.build(cfg.param_count, cfg.learning_rate);
        Self {
            allocator: Allocator::new(cfg.capacity),
            accumulator: ShardedAccumulator::new(
                cfg.param_count,
                cfg.master_model.reduce_mode.shards(),
            ),
            avg_scratch: vec![0.0; cfg.param_count],
            latency: LatencyMonitor::new(),
            optimizer,
            params: init_params,
            iteration: 0,
            t_virtual_ms: 0.0,
            timeline: Timeline::new(),
            carryover: Vec::new(),
            strikes: BTreeMap::new(),
            pending_test_error: None,
            trace: TraceHandle::off(),
            trace_pid: 0,
            wal_seed: None,
            wal: None,
            last_record: None,
            cfg,
        }
    }

    /// Attach a trace handle; `pid` names this master's project on the
    /// shared timeline.
    pub fn set_trace(&mut self, trace: TraceHandle, pid: u32) {
        self.trace = trace;
        self.trace_pid = pid;
    }

    // ------------------------------------------------- storage plane

    /// Turn on per-iteration digest records without a durable log —
    /// recovery replays in this mode and checks each record against the
    /// WAL it read from disk.
    pub fn enable_wal_digests(&mut self, seed: u64) {
        self.wal_seed = Some(seed);
    }

    /// Attach a durable iteration log: digests on, every iteration
    /// appended (buffered).  The caller owns the sync cadence.
    pub fn attach_wal(&mut self, writer: WalWriter, seed: u64) {
        self.wal = Some(writer);
        self.wal_seed = Some(seed);
    }

    /// The record produced by the most recent `finish_iteration`
    /// (None until digests are enabled and an iteration closes).
    pub fn last_wal_record(&self) -> Option<&WalRecord> {
        self.last_record.as_ref()
    }

    /// Mutable handle on the attached log — checkpoint boundaries call
    /// `sync()` through this.
    pub fn wal_mut(&mut self) -> Option<&mut WalWriter> {
        self.wal.as_mut()
    }

    /// Capture the complete cross-iteration training state (checkpoint
    /// payload).  Restoring it with [`Master::import_state`] on a master
    /// built from the same config resumes bitwise-identically.
    pub fn export_state(&self) -> MasterState {
        MasterState {
            iteration: self.iteration,
            t_virtual_ms: self.t_virtual_ms,
            params: self.params.clone(),
            optimizer: self.cfg.optimizer_name(),
            opt_state: self.optimizer.state(),
            allocator: self.allocator.export_state(),
            latency: self.latency.export_state(),
            timeline: self.timeline.records().to_vec(),
            carryover: self
                .carryover
                .iter()
                .map(SubmissionState::from_submission)
                .collect(),
            pending_test_error: self.pending_test_error,
            strikes: self.strikes.iter().map(|(&w, &n)| (w, n)).collect(),
        }
    }

    /// Restore a state captured by [`Master::export_state`].  Panics on a
    /// checkpoint that cannot belong to this config (wrong parameter
    /// dimension or optimizer kind) — recovery treats that as corruption.
    pub fn import_state(&mut self, st: MasterState) {
        assert_eq!(
            st.params.len(),
            self.cfg.param_count,
            "checkpoint param dim mismatch"
        );
        assert_eq!(
            st.optimizer,
            self.cfg.optimizer_name(),
            "checkpoint optimizer kind mismatch"
        );
        self.params = st.params;
        self.optimizer = self
            .cfg
            .optimizer
            .build(self.cfg.param_count, self.cfg.learning_rate);
        self.optimizer.restore_state(&st.opt_state);
        self.allocator = Allocator::from_state(&st.allocator);
        self.latency.import_state(st.latency);
        self.timeline = Timeline::from_records(st.timeline);
        self.carryover = st
            .carryover
            .into_iter()
            .map(SubmissionState::into_submission)
            .collect();
        self.strikes = st.strikes.into_iter().collect();
        self.pending_test_error = st.pending_test_error;
        self.iteration = st.iteration;
        self.t_virtual_ms = st.t_virtual_ms;
        self.last_record = None;
    }

    // ------------------------------------------------------------ access

    pub fn config(&self) -> &MasterConfig {
        &self.cfg
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn set_params(&mut self, params: Vec<f32>) {
        assert_eq!(params.len(), self.cfg.param_count);
        self.params = params;
    }

    pub fn iteration(&self) -> u64 {
        self.iteration
    }

    pub fn now_ms(&self) -> f64 {
        self.t_virtual_ms
    }

    pub fn timeline(&self) -> &Timeline {
        &self.timeline
    }

    pub fn allocator(&self) -> &Allocator {
        &self.allocator
    }

    pub fn latency_monitor(&self) -> &LatencyMonitor {
        &self.latency
    }

    pub fn iter_ms(&self) -> f64 {
        self.cfg.iter_duration_s * 1000.0
    }

    // -------------------------------------------------- events (steps a/b)

    /// Step (a): data registered by a boss after a data-server upload.
    pub fn register_data(&mut self, n: usize) -> Delta {
        self.allocator.add_data(n)
    }

    /// Step (b): new trainer joins; returns the allocation delta (ids the
    /// worker must download + revokes to others).  The paper has joiners
    /// wait for the iteration boundary — the sim enforces that by calling
    /// this between iterations.
    pub fn worker_join(&mut self, w: WorkerId) -> Delta {
        self.allocator.worker_join(w)
    }

    /// A client's data worker finished downloading `id` (§3.3a cached
    /// index bookkeeping).
    pub fn mark_cached(&mut self, w: WorkerId, id: crate::allocation::DataId) {
        self.allocator.mark_cached(w, id);
    }

    /// Lost client (tab closed / churn): reallocate its data.
    pub fn worker_leave(&mut self, w: WorkerId) -> Delta {
        self.latency.forget(w);
        self.carryover.retain(|s| s.worker != w);
        self.allocator.worker_leave(w)
    }

    /// Step (d) scheduling half: the compute budget (ms) the master tells
    /// `worker` to run for next iteration.
    pub fn work_budget_ms(&self, w: WorkerId) -> f64 {
        self.latency.work_budget_ms(w, self.iter_ms())
    }

    /// Tracker workers report test error right after a broadcast (§3.6
    /// tracking mode); attached to the just-closed iteration's record
    /// (it was computed with that iteration's parameters).  Before the
    /// first iteration it is held for the first record instead.
    pub fn report_test_error(&mut self, error: f64) {
        if self.timeline.is_empty() {
            self.pending_test_error = Some(error);
        } else {
            self.timeline.set_last_test_error(error);
        }
    }

    // ------------------------------------------------------ step c/d/e

    /// Close the current iteration: ingest submissions (policy-dependent),
    /// run the reduce + optimizer step, update latency estimates, shed
    /// overloaded workers, account the broadcast.  Returns the outcome and
    /// advances virtual time.
    pub fn finish_iteration(&mut self, submissions: Vec<Submission>) -> IterationOutcome {
        let iter_ms = self.iter_ms();
        let t0 = self.t_virtual_ms;

        // ---- ingest: compute completion time per submission (step c)
        let mut subs = std::mem::take(&mut self.carryover);
        let carried = subs.len();
        subs.extend(submissions);

        // ---- sanitation gate (robustness plane).  Before anything can
        // reach the reduce: a non-finite payload is quarantined (it still
        // drains — the bytes were sent — but never merges and never enters
        // carryover) and strikes its worker; repeated uploads of the same
        // worker within one iteration keep only the first copy.  Carryover
        // was screened when it arrived but is re-checked — cheap, and it
        // keeps the invariant local.
        let mut quarantine = vec![false; subs.len()];
        let mut quarantined = 0u64;
        let mut duplicates = 0u64;
        let mut to_evict: Vec<WorkerId> = Vec::new();
        let mut seen_new: Vec<WorkerId> = Vec::new();
        for (i, s) in subs.iter().enumerate() {
            if !s.payload.is_finite() {
                quarantine[i] = true;
                quarantined += 1;
                let strikes = self.strikes.entry(s.worker).or_insert(0);
                *strikes += 1;
                if *strikes >= self.cfg.strike_limit && !to_evict.contains(&s.worker) {
                    to_evict.push(s.worker);
                }
            } else if i >= carried {
                if seen_new.contains(&s.worker) {
                    // Duplicate delivery (fault plane replays the upload):
                    // merging it would double-count the worker's examples.
                    quarantine[i] = true;
                    duplicates += 1;
                } else {
                    seen_new.push(s.worker);
                }
            }
        }
        let mut evicted: Vec<(WorkerId, Delta)> = Vec::new();
        for w in to_evict {
            if self.allocator.worker_ids().contains(&w) {
                // `worker_leave` also purges carryover — already taken
                // above, so only this iteration's `subs` still reference
                // the evicted worker, and those are quarantined.
                let delta = self.worker_leave(w);
                evicted.push((w, delta));
            }
        }

        let arrivals: Vec<(f64, u64, usize)> = subs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                // Carryover merges at iteration start (offset 0).
                let offset = if i < carried { 0.0 } else { s.send_offset_ms };
                (offset, s.bytes, self.cfg.param_count)
            })
            .collect();
        let completions = self.cfg.master_model.drain_delays(&arrivals);

        // ---- quorum close (graceful degradation).  Under a synchronous
        // policy with quorum q > 0 the barrier releases once ⌈q·workers⌉
        // fresh valid submissions have drained: later ones become
        // carryover (bounded staleness 1, like Async stragglers) instead
        // of extending the wall.  Below quorum the barrier stalls — the
        // strict Sync semantics, waiting for everything.
        let quorum_stat: Option<(usize, usize, f64)> = if self.cfg.quorum > 0.0
            && !matches!(self.cfg.policy, ReducePolicy::Async)
        {
            let workers = self.allocator.n_workers();
            let needed = ((self.cfg.quorum * workers as f64).ceil() as usize).max(1);
            let mut times: Vec<f64> = completions
                .iter()
                .enumerate()
                .filter(|&(i, _)| i >= carried && !quarantine[i])
                .map(|(_, &d)| d)
                .collect();
            times.sort_unstable_by(f64::total_cmp);
            let reported = times.len();
            let close = if reported >= needed {
                times[needed - 1].max(iter_ms)
            } else {
                times.last().copied().unwrap_or(0.0).max(iter_ms)
            };
            Some((needed, reported, close))
        } else {
            None
        };

        // ---- split on-time vs late under the async policy / quorum close
        let mut merged_idx: Vec<usize> = Vec::new();
        let mut late_idx: Vec<usize> = Vec::new();
        for (i, &done) in completions.iter().enumerate() {
            if quarantine[i] {
                continue; // neither merged nor carried
            }
            match self.cfg.policy {
                ReducePolicy::Async if done > iter_ms && i >= carried => late_idx.push(i),
                _ => match quorum_stat {
                    Some((_, _, close)) if done > close && i >= carried => late_idx.push(i),
                    _ => merged_idx.push(i),
                },
            }
        }

        // Ingest spans: master-side drain of each merged submission, on
        // the submitting worker's track (emitted before the late-requeue
        // below mutates `subs`).
        if self.trace.is_on() {
            for &i in &merged_idx {
                let (overhead_ms, ingest_ms, merge_ms) = self
                    .cfg
                    .master_model
                    .service_breakdown(subs[i].bytes, self.cfg.param_count);
                self.trace.span(
                    Track::worker(self.trace_pid, subs[i].worker as u32),
                    "train",
                    "ingest",
                    t0 + arrivals[i].0,
                    t0 + completions[i],
                    &[
                        ("bytes", ArgValue::U64(subs[i].bytes)),
                        ("carried", ArgValue::U64(u64::from(i < carried))),
                        ("overhead_ms", ArgValue::F64(overhead_ms)),
                        ("wire_ms", ArgValue::F64(ingest_ms)),
                        ("merge_ms", ArgValue::F64(merge_ms)),
                    ],
                );
            }
        }

        // ---- reduce (step c): batch the merged submissions' gradient
        // views (no copies — dense payloads stay behind their Arc) and
        // merge them sharded across threads; bitwise-identical to the
        // serial reference for any shard count.
        self.accumulator.reset();
        let mut vectors = 0u64;
        let mut loss_sum = 0.0f64;
        let mut loss_examples = 0u64;
        let mut bytes_up = 0u64;
        let mut batch: Vec<(GradView<'_>, u64)> = Vec::with_capacity(merged_idx.len());
        for &i in &merged_idx {
            let s = &subs[i];
            batch.push((s.payload.as_view(), s.examples));
            vectors += s.vectors;
            loss_sum += s.loss_sum;
            loss_examples += s.examples;
            bytes_up += s.bytes;
        }
        let stepped;
        if self.cfg.aggregation.is_robust() {
            // Robust modes need the per-worker rows, not a running sum —
            // they combine over the same shard bounds on the same scoped
            // threads, writing the aggregate straight into `avg_scratch`.
            // The Mean branch below stays bitwise-untouched.
            stepped = batch.iter().any(|&(_, n)| n > 0);
            if stepped {
                self.accumulator.robust_aggregate_into(
                    self.cfg.aggregation,
                    &batch,
                    &mut self.avg_scratch,
                );
                self.optimizer.step(&mut self.params, &self.avg_scratch);
            }
            drop(batch);
        } else {
            self.accumulator.merge(&batch);
            drop(batch);
            stepped = !self.accumulator.is_empty();
            if stepped {
                self.accumulator.weighted_average_into(&mut self.avg_scratch);
                self.optimizer.step(&mut self.params, &self.avg_scratch);
            }
        }

        // ---- storage plane: fingerprint the reduce while its inputs are
        // still intact (the late-requeue below reorders `subs`).  Worker
        // ids hash in merge order; the gradient digest covers the weighted
        // average actually fed to the optimizer; the params digest is
        // post-step.  All bitwise (FNV over LE bytes), so replay equality
        // means bit-for-bit reproduction.
        let wal_digests = self.wal_seed.map(|seed| {
            let mut ws = Fnv64::new();
            for &i in &merged_idx {
                ws.write_u64(subs[i].worker);
            }
            let grad_digest = if stepped { digest_f32s(&self.avg_scratch) } else { 0 };
            (seed, ws.finish(), grad_digest, digest_f32s(&self.params))
        });

        // ---- latency estimates (step d).  The monitor learns the part
        // the client is responsible for (compute overrun + network:
        // arrival − scheduled end) — the master's own queue/merge delay is
        // known to it and must not shrink budgets.  The *reported* latency
        // (Fig 4's metric) is completion-based: what a slave experiences
        // between sending and the reduce picking it up.
        let mut latencies: Vec<f64> = Vec::new();
        for (i, &done) in completions.iter().enumerate() {
            if i < carried {
                continue;
            }
            if quarantine[i] {
                // A quarantined submission must not feed the latency
                // monitor: `observe` would re-register a worker the
                // eviction above just forgot.
                continue;
            }
            let s = &subs[i];
            let scheduled_end = self.latency.work_budget_ms(s.worker, iter_ms);
            let network = (s.send_offset_ms - scheduled_end).max(0.0);
            self.latency.observe(s.worker, network);
            latencies.push((done - scheduled_end).max(0.0));
        }

        // ---- data-allocation adjustment (step d)
        let mut shed_deltas = Vec::new();
        for w in self.allocator.worker_ids() {
            if self.latency.is_overloaded(w, iter_ms, self.cfg.shed_threshold) {
                let owned = self.allocator.owned_by(w).len();
                if owned > 1 {
                    let delta = self.allocator.shed_load(w, owned / 4);
                    if !delta.is_empty() {
                        shed_deltas.push((w, delta));
                    }
                }
            }
        }

        // ---- queue late submissions for the next iteration (async)
        // (reverse order so indices stay valid under swap_remove)
        for &i in late_idx.iter().rev() {
            let s = subs.swap_remove(i);
            self.carryover.push(s);
        }

        // ---- broadcast accounting (step e).  Bytes are charged to the
        // egress metric; the broadcast itself pipelines with the next map
        // step (a client starts computing as soon as *its* parameters
        // arrive, it does not wait for the other clients' transfers), so
        // it does not extend the synchronous wall time.
        let n_clients = self.allocator.n_workers() as u64;
        let bytes_down = n_clients * (self.cfg.param_count as u64 * 4);

        // ---- wall clock: the sync barrier waits for the slowest merged
        // submission ("asynchronous reduction callback delay", §3.3d).
        let slowest = merged_idx
            .iter()
            .map(|&i| completions[i])
            .fold(0.0f64, f64::max);
        let wall_ms = match self.cfg.policy {
            ReducePolicy::Async => iter_ms,
            _ => slowest.max(iter_ms),
        };
        self.t_virtual_ms += wall_ms;
        self.iteration += 1;

        // ---- storage plane: one WAL record per closed iteration.
        if let Some((seed, worker_set_digest, grad_digest, params_digest)) = wal_digests {
            let record = WalRecord {
                iteration: self.iteration - 1,
                t_virtual_ms: self.t_virtual_ms,
                seed,
                workers: merged_idx.len() as u32,
                worker_set_digest,
                stepped,
                grad_digest,
                params_digest,
            };
            if let Some(wal) = self.wal.as_mut() {
                if let Err(e) = wal.append(&record) {
                    // A durable run that cannot log cannot recover; fail
                    // loudly rather than silently dropping durability.
                    panic!("wal append failed at iteration {}: {e}", record.iteration);
                }
                if self.trace.is_on() {
                    self.trace.counter(
                        Track::master(self.trace_pid),
                        "storage/wal",
                        self.t_virtual_ms,
                        &[
                            ("bytes_appended", wal.bytes_appended() as f64),
                            ("records_since_checkpoint", wal.records_since_sync() as f64),
                        ],
                    );
                }
            }
            self.last_record = Some(record);
        }

        // Master-track spans for the iteration: the barrier itself, the
        // sharded reduce (bounded by the slowest merged drain), the
        // optimizer step, and the parameter broadcast.
        if self.trace.is_on() {
            let master = Track::master(self.trace_pid);
            self.trace.span(
                master,
                "train",
                "iteration",
                t0,
                t0 + wall_ms,
                &[
                    ("iteration", ArgValue::U64(self.iteration - 1)),
                    ("workers", ArgValue::U64(merged_idx.len() as u64)),
                    ("vectors", ArgValue::U64(vectors)),
                ],
            );
            if slowest > 0.0 {
                self.trace.span(
                    master,
                    "train",
                    "reduce",
                    t0,
                    t0 + slowest,
                    &[
                        ("messages", ArgValue::U64(merged_idx.len() as u64)),
                        ("bytes_up", ArgValue::U64(bytes_up)),
                    ],
                );
            }
            if stepped {
                self.trace.instant(
                    master,
                    "train",
                    "optimizer-step",
                    t0 + slowest,
                    &[("params", ArgValue::U64(self.cfg.param_count as u64))],
                );
            }
            if bytes_down > 0 {
                self.trace.instant(
                    master,
                    "train",
                    "broadcast",
                    t0 + wall_ms,
                    &[
                        ("bytes", ArgValue::U64(bytes_down)),
                        ("clients", ArgValue::U64(n_clients)),
                    ],
                );
            }
            // Gauges at the iteration boundary: gradients carried into the
            // next iteration and stragglers that missed this one's merge.
            self.trace.counter(
                master,
                "train/pending-gradients",
                t0 + wall_ms,
                &[("pending", self.carryover.len() as f64)],
            );
            self.trace.counter(
                master,
                "train/stragglers",
                t0 + wall_ms,
                &[("late", late_idx.len() as f64)],
            );
            // Robustness plane: what the sanitation gate rejected and
            // whether the quorum barrier released early.
            self.trace.counter(
                master,
                "train/quarantined",
                t0 + wall_ms,
                &[
                    ("quarantined", quarantined as f64),
                    ("duplicates", duplicates as f64),
                    ("evicted", evicted.len() as f64),
                ],
            );
            if let Some((needed, reported, close)) = quorum_stat {
                self.trace.counter(
                    master,
                    "train/quorum",
                    t0 + wall_ms,
                    &[
                        ("needed", needed as f64),
                        ("reported", reported as f64),
                        ("met", f64::from(u8::from(reported >= needed))),
                        ("close_ms", close),
                    ],
                );
            }
        }

        let mean_latency_ms = if latencies.is_empty() {
            0.0
        } else {
            latencies.iter().sum::<f64>() / latencies.len() as f64
        };
        let max_latency_ms = latencies.iter().fold(0.0f64, |a, &b| a.max(b));
        let mean_loss = if loss_examples > 0 {
            Some(loss_sum / loss_examples as f64)
        } else {
            None
        };

        self.timeline.push(IterationRecord {
            iteration: self.iteration - 1,
            t_virtual_ms: self.t_virtual_ms,
            vectors,
            workers: merged_idx.len() as u32,
            mean_latency_ms,
            max_latency_ms,
            loss: mean_loss,
            test_error: self.pending_test_error.take(),
            bytes_up,
            bytes_down,
        });

        IterationOutcome {
            wall_ms,
            mean_latency_ms,
            max_latency_ms,
            vectors,
            shed_deltas,
            bytes_up,
            bytes_down,
            mean_loss,
            quarantined: quarantined + duplicates,
            evicted,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Payload;
    use crate::netsim::ReduceMode;

    fn cfg(policy: ReducePolicy) -> MasterConfig {
        MasterConfig {
            param_count: 2,
            iter_duration_s: 4.0,
            learning_rate: 0.1,
            policy,
            ..Default::default()
        }
    }

    fn sub(worker: WorkerId, offset: f64, g: Vec<f32>, n: u64) -> Submission {
        Submission {
            worker,
            payload: Payload::dense(g),
            examples: n,
            vectors: n,
            loss_sum: n as f64,
            send_offset_ms: offset,
            bytes: 64,
        }
    }

    #[test]
    fn sync_waits_for_slowest() {
        let mut m = Master::new(cfg(ReducePolicy::Sync), vec![0.0; 2]);
        m.register_data(10);
        m.worker_join(1);
        m.worker_join(2);
        let out =
            m.finish_iteration(vec![sub(1, 3900.0, vec![1.0, 1.0], 1), sub(2, 6000.0, vec![1.0, 1.0], 1)]);
        assert!(out.wall_ms > 6000.0, "{}", out.wall_ms);
        assert_eq!(out.vectors, 2);
    }

    #[test]
    fn async_closes_at_t_and_carries_late_work() {
        let mut m = Master::new(cfg(ReducePolicy::Async), vec![0.0; 2]);
        m.register_data(10);
        m.worker_join(1);
        m.worker_join(2);
        let out = m.finish_iteration(vec![
            sub(1, 1000.0, vec![1.0, 1.0], 1),
            sub(2, 7000.0, vec![1.0, 1.0], 1), // late
        ]);
        assert_eq!(out.vectors, 1);
        assert!(out.wall_ms < 4600.0, "{}", out.wall_ms);
        // late gradient merges next iteration even with no new submissions
        let out2 = m.finish_iteration(vec![]);
        assert_eq!(out2.vectors, 1);
    }

    #[test]
    fn traced_iteration_emits_master_and_worker_spans() {
        let mut m = Master::new(cfg(ReducePolicy::Sync), vec![0.0; 2]);
        let trace = TraceHandle::recording();
        m.set_trace(trace.clone(), 7);
        m.register_data(10);
        m.worker_join(1);
        m.finish_iteration(vec![sub(1, 1000.0, vec![1.0, 1.0], 1)]);
        let evs = trace.snapshot();
        assert!(evs
            .iter()
            .any(|e| e.name == "iteration" && e.track == Track::master(7)));
        assert!(evs
            .iter()
            .any(|e| e.name == "ingest" && e.track == Track::worker(7, 1)));
        assert!(evs.iter().any(|e| e.name == "reduce"));
        assert!(evs.iter().any(|e| e.name == "optimizer-step"));
        assert!(evs.iter().any(|e| e.name == "broadcast"));
        assert!(evs.iter().any(|e| e.name == "train/quarantined"));
        // Second iteration starts where the first ended: spans never
        // run backwards on the virtual clock.
        let t_end = m.now_ms();
        m.finish_iteration(vec![sub(1, 500.0, vec![1.0, 1.0], 1)]);
        assert!(trace
            .snapshot()
            .iter()
            .filter(|e| e.seq >= evs.len() as u64)
            .all(|e| e.ts_ms >= t_end - 1e-9));
    }

    #[test]
    fn empty_iteration_is_safe_and_advances_time() {
        let mut m = Master::new(cfg(ReducePolicy::Sync), vec![0.5, -0.5]);
        let p0 = m.params().to_vec();
        let out = m.finish_iteration(vec![]);
        assert_eq!(m.params(), p0.as_slice());
        assert_eq!(out.vectors, 0);
        assert!(out.mean_loss.is_none());
        assert_eq!(m.iteration(), 1);
        assert!(m.now_ms() >= 4000.0);
    }

    #[test]
    fn zero_worker_iteration_is_safe() {
        // An iteration may close with no workers registered at all (fleet
        // fully churned out): no reduce, no shed, time still advances.
        let mut m = Master::new(cfg(ReducePolicy::Sync), vec![0.25, -0.25]);
        m.register_data(50);
        let p0 = m.params().to_vec();
        let out = m.finish_iteration(vec![]);
        assert_eq!(m.params(), p0.as_slice());
        assert_eq!(out.vectors, 0);
        assert!(out.shed_deltas.is_empty());
        assert_eq!(out.bytes_down, 0, "no clients → no broadcast bytes");
        assert!(out.wall_ms >= 4000.0);
        assert!(m.params().iter().all(|p| p.is_finite()));
    }

    #[test]
    fn zero_example_submission_does_not_step_or_nan() {
        // A trainer can legitimately report zero examples (joined late,
        // nothing cached yet).  The weighted average would divide by the
        // example count — the master must not step on a 0-count reduce.
        let mut c = cfg(ReducePolicy::Sync);
        c.optimizer = OptimizerKind::Sgd;
        let mut m = Master::new(c, vec![0.5, 0.5]);
        m.register_data(10);
        m.worker_join(1);
        let out = m.finish_iteration(vec![sub(1, 100.0, vec![3.0, -3.0], 0)]);
        assert_eq!(m.params(), &[0.5, 0.5], "0-example gradient must not step");
        assert!(m.params().iter().all(|p| p.is_finite()));
        assert_eq!(out.vectors, 0);
        assert!(out.mean_loss.is_none(), "no examples → no loss average");
        // A later real submission still works.
        let out2 = m.finish_iteration(vec![sub(1, 100.0, vec![1.0, 1.0], 1)]);
        assert_eq!(out2.vectors, 1);
        assert!(m.params()[0] < 0.5);
    }

    #[test]
    fn weighted_average_across_heterogeneous_workers() {
        // worker 1: 1 example grad sum [1, 0]; worker 2: 3 examples [0, 6]
        // avg = [0.25, 1.5]; SGD lr=0.1 → params -= [0.025, 0.15]
        let mut c = cfg(ReducePolicy::Sync);
        c.optimizer = OptimizerKind::Sgd;
        let mut m = Master::new(c, vec![0.0; 2]);
        m.register_data(4);
        m.worker_join(1);
        m.finish_iteration(vec![
            sub(1, 100.0, vec![1.0, 0.0], 1),
            sub(1, 100.0, vec![0.0, 6.0], 3),
        ]);
        let p = m.params();
        assert!((p[0] + 0.025).abs() < 1e-6 && (p[1] + 0.15).abs() < 1e-6, "{p:?}");
    }

    #[test]
    fn sharded_reduce_mode_is_bitwise_identical_to_serial() {
        // Same submissions (dense + sparse) through a serial master and a
        // param-sharded one: every parameter must match bit for bit.
        let run = |mode: ReduceMode| {
            let mut c = cfg(ReducePolicy::Sync);
            c.param_count = 11; // non-dividing for shards ∈ {3}
            c.master_model.reduce_mode = mode;
            let mut m = Master::new(c, vec![0.05; 11]);
            m.register_data(10);
            m.worker_join(1);
            m.worker_join(2);
            for it in 0..3 {
                let g: Vec<f32> = (0..11).map(|i| (i as f32 + it as f32).sin()).collect();
                let sparse = Payload::sparsify(&g, 0.4);
                m.finish_iteration(vec![
                    sub(1, 100.0, g.clone(), 2),
                    Submission {
                        worker: 2,
                        payload: sparse,
                        examples: 3,
                        vectors: 3,
                        loss_sum: 1.0,
                        send_offset_ms: 200.0,
                        bytes: 64,
                    },
                ]);
            }
            m.params().to_vec()
        };
        let serial = run(ReduceMode::MessageParallel);
        let sharded = run(ReduceMode::Sharded { shards: 3 });
        assert_eq!(serial, sharded);
    }

    #[test]
    fn latency_estimates_update_and_budgets_shrink() {
        let mut m = Master::new(cfg(ReducePolicy::Sync), vec![0.0; 2]);
        m.register_data(10);
        m.worker_join(1);
        let b0 = m.work_budget_ms(1);
        for _ in 0..5 {
            m.finish_iteration(vec![sub(1, 5000.0, vec![0.0, 0.0], 1)]);
        }
        assert!(m.work_budget_ms(1) < b0);
    }

    #[test]
    fn overloaded_worker_sheds_data() {
        let mut m = Master::new(cfg(ReducePolicy::Sync), vec![0.0; 2]);
        m.register_data(100);
        m.worker_join(1);
        m.worker_join(2);
        // worker 1 is extremely slow for several iterations
        let mut shed_seen = false;
        for _ in 0..6 {
            let out = m.finish_iteration(vec![
                sub(1, 9000.0, vec![0.0, 0.0], 1),
                sub(2, 100.0, vec![0.0, 0.0], 1),
            ]);
            if out.shed_deltas.iter().any(|(w, _)| *w == 1) {
                shed_seen = true;
            }
        }
        assert!(shed_seen, "slow worker never shed load");
        assert!(m.allocator().owned_by(1).len() < 50);
        m.allocator().check_invariants().unwrap();
    }

    #[test]
    fn leave_during_training_reallocates() {
        let mut m = Master::new(cfg(ReducePolicy::Sync), vec![0.0; 2]);
        m.register_data(60);
        m.worker_join(1);
        m.worker_join(2);
        m.finish_iteration(vec![sub(1, 10.0, vec![1.0, 1.0], 1)]);
        let delta = m.worker_leave(1);
        assert!(!delta.is_empty());
        assert_eq!(m.allocator().owned_by(2).len(), 60);
        m.allocator().check_invariants().unwrap();
    }

    #[test]
    fn export_import_resumes_bitwise_with_carryover() {
        // Async + AdaGrad: carryover submissions and optimizer history are
        // both live state.  A restored master must continue bit-for-bit.
        let mk = || {
            let mut c = cfg(ReducePolicy::Async);
            c.param_count = 5;
            Master::new(c, vec![0.1; 5])
        };
        let mut a = mk();
        a.register_data(20);
        a.worker_join(1);
        a.worker_join(2);
        a.report_test_error(0.9);
        for it in 0..4 {
            let g: Vec<f32> = (0..5).map(|i| ((i + it) as f32).cos()).collect();
            a.finish_iteration(vec![
                sub(1, 500.0, g.clone(), 2),
                sub(2, 7000.0, g, 1), // late → carryover
            ]);
        }
        assert!(!a.export_state().carryover.is_empty(), "test needs carryover");

        let mut b = mk();
        b.import_state(a.export_state());
        assert_eq!(b.iteration(), a.iteration());
        assert_eq!(b.now_ms(), a.now_ms());
        assert_eq!(b.timeline().records(), a.timeline().records());

        a.enable_wal_digests(42);
        b.enable_wal_digests(42);
        for it in 0..3 {
            let g: Vec<f32> = (0..5).map(|i| ((i * it) as f32).sin()).collect();
            let subs = vec![sub(1, 600.0, g.clone(), 1), sub(2, 800.0, g, 3)];
            a.finish_iteration(subs.clone());
            b.finish_iteration(subs);
            assert_eq!(
                a.params()
                    .iter()
                    .map(|p| p.to_bits())
                    .collect::<Vec<_>>(),
                b.params()
                    .iter()
                    .map(|p| p.to_bits())
                    .collect::<Vec<_>>()
            );
            assert_eq!(a.last_wal_record(), b.last_wal_record());
        }
    }

    #[test]
    #[should_panic(expected = "optimizer kind mismatch")]
    fn import_rejects_foreign_optimizer_state() {
        let mut src = cfg(ReducePolicy::Sync);
        src.optimizer = OptimizerKind::Sgd;
        let st = Master::new(src, vec![0.0; 2]).export_state();
        let mut dst = Master::new(cfg(ReducePolicy::Sync), vec![0.0; 2]); // adagrad
        dst.import_state(st);
    }

    #[test]
    fn wal_records_append_and_read_back() {
        let dir = std::env::temp_dir().join(format!(
            "mlitb-master-wal-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = crate::storage::wal_path(&dir);
        let identity = crate::storage::RunIdentity {
            seed: 7,
            config_digest: 11,
        };
        let writer = WalWriter::open(&path, identity).unwrap();

        let mut m = Master::new(cfg(ReducePolicy::Sync), vec![0.0; 2]);
        m.register_data(10);
        m.worker_join(1);
        m.attach_wal(writer, 7);
        m.finish_iteration(vec![sub(1, 100.0, vec![1.0, -1.0], 1)]);
        m.finish_iteration(vec![]);
        let last = *m.last_wal_record().unwrap();
        assert_eq!(last.iteration, 1);
        assert!(!last.stepped, "empty iteration must not claim a step");
        m.wal_mut().unwrap().sync().unwrap();

        let (id, records, tail) = crate::storage::read_wal(&path).unwrap();
        assert_eq!(id, identity);
        assert_eq!(tail, crate::storage::TailStatus::Clean);
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].iteration, 0);
        assert!(records[0].stepped);
        assert_ne!(records[0].params_digest, 0);
        assert_eq!(records[1], last);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn test_error_lands_on_next_record() {
        let mut m = Master::new(cfg(ReducePolicy::Sync), vec![0.0; 2]);
        m.report_test_error(0.42);
        m.finish_iteration(vec![]);
        assert_eq!(m.timeline().last().unwrap().test_error, Some(0.42));
        m.finish_iteration(vec![]);
        assert_eq!(m.timeline().last().unwrap().test_error, None);
    }

    #[test]
    fn poisoned_worker_is_quarantined_then_evicted() {
        // Regression for the sanitation gate: before it existed a single
        // NaN payload flowed through `avg_scratch` into the parameters
        // even under plain Mean aggregation.
        let mut c = cfg(ReducePolicy::Sync);
        c.optimizer = OptimizerKind::Sgd;
        let mut m = Master::new(c, vec![0.0; 2]);
        m.register_data(40);
        m.worker_join(1);
        m.worker_join(2);
        for it in 0..3 {
            let out = m.finish_iteration(vec![
                sub(1, 100.0, vec![f32::NAN, 1.0], 1),
                sub(2, 100.0, vec![1.0, 1.0], 1),
            ]);
            assert_eq!(out.quarantined, 1, "iteration {it}");
            assert!(
                m.params().iter().all(|p| p.is_finite()),
                "NaN reached the params at iteration {it}"
            );
            if it < 2 {
                assert!(out.evicted.is_empty(), "evicted before the strike limit");
            } else {
                assert_eq!(out.evicted.len(), 1, "third strike must evict");
                assert_eq!(out.evicted[0].0, 1);
            }
        }
        // The evicted worker's data went back to the honest one, and only
        // the honest gradient stepped the parameters.
        assert_eq!(m.allocator().owned_by(2).len(), 40);
        m.allocator().check_invariants().unwrap();
        assert!(m.params()[0] < 0.0);
        // Strike history survives an export/import round trip.
        let st = m.export_state();
        assert_eq!(st.strikes, vec![(1, 3)]);
        let mut b = {
            let mut c = cfg(ReducePolicy::Sync);
            c.optimizer = OptimizerKind::Sgd;
            Master::new(c, vec![0.0; 2])
        };
        b.import_state(st.clone());
        assert_eq!(b.export_state().strikes, st.strikes);
    }

    #[test]
    fn duplicate_deliveries_merge_once() {
        let mut c = cfg(ReducePolicy::Sync);
        c.optimizer = OptimizerKind::Sgd;
        let mut m = Master::new(c, vec![0.0; 2]);
        m.register_data(10);
        m.worker_join(1);
        // The fault plane can replay an upload: only the first copy may
        // count, or the worker's examples double-weight the reduce.
        let out = m.finish_iteration(vec![
            sub(1, 100.0, vec![1.0, 1.0], 1),
            sub(1, 150.0, vec![1.0, 1.0], 1),
        ]);
        assert_eq!(out.quarantined, 1, "duplicate counts as rejected");
        assert_eq!(out.vectors, 1);
        let p = m.params();
        assert!((p[0] + 0.1).abs() < 1e-6, "double-merged duplicate: {p:?}");
        // Duplicates are not strikes — the worker keeps a clean record.
        assert!(m.export_state().strikes.is_empty());
    }

    #[test]
    fn quorum_releases_the_barrier_and_carries_stragglers() {
        let mut c = cfg(ReducePolicy::Sync);
        c.quorum = 0.5;
        let mut m = Master::new(c, vec![0.0; 2]);
        m.register_data(10);
        for w in 1..=4 {
            m.worker_join(w);
        }
        let out = m.finish_iteration(vec![
            sub(1, 1000.0, vec![1.0, 1.0], 1),
            sub(2, 2000.0, vec![1.0, 1.0], 1),
            sub(3, 9000.0, vec![1.0, 1.0], 1),
            sub(4, 12000.0, vec![1.0, 1.0], 1),
        ]);
        // ⌈0.5·4⌉ = 2: the barrier releases once worker 2 drains; the
        // two stragglers become carryover instead of stretching the wall.
        assert_eq!(out.vectors, 2);
        assert!(out.wall_ms < 9000.0, "{}", out.wall_ms);
        let out2 = m.finish_iteration(vec![]);
        assert_eq!(out2.vectors, 2, "stragglers merge next iteration");
    }

    #[test]
    fn quorum_unmet_stalls_like_strict_sync() {
        let mut c = cfg(ReducePolicy::Sync);
        c.quorum = 0.75;
        let mut m = Master::new(c, vec![0.0; 2]);
        m.register_data(10);
        for w in 1..=4 {
            m.worker_join(w);
        }
        // Only 2 of the needed ⌈0.75·4⌉ = 3 report: the barrier waits for
        // everything it did get (strict Sync degradation, no lost work).
        let out = m.finish_iteration(vec![
            sub(1, 1000.0, vec![1.0, 1.0], 1),
            sub(2, 8000.0, vec![1.0, 1.0], 1),
        ]);
        assert_eq!(out.vectors, 2);
        assert!(out.wall_ms > 8000.0, "{}", out.wall_ms);
    }

    #[test]
    fn trimmed_mean_shrugs_off_a_hostile_gradient() {
        let mut c = cfg(ReducePolicy::Sync);
        c.optimizer = OptimizerKind::Sgd;
        c.aggregation = AggregationMode::TrimmedMean { k: 1 };
        let mut m = Master::new(c, vec![0.0; 2]);
        m.register_data(10);
        for w in 1..=3 {
            m.worker_join(w);
        }
        m.finish_iteration(vec![
            sub(1, 100.0, vec![1.0, 1.0], 1),
            sub(2, 100.0, vec![1.0, 1.0], 1),
            sub(3, 100.0, vec![-1000.0, 1000.0], 1), // hostile outlier
        ]);
        // Trimming 1 from each end leaves the honest 1.0 per coordinate;
        // SGD lr=0.1 steps both params by exactly −0.1.
        let p = m.params();
        assert!((p[0] + 0.1).abs() < 1e-6 && (p[1] + 0.1).abs() < 1e-6, "{p:?}");
    }
}
