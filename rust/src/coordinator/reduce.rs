//! Reduce-step policies: the paper's synchronized reduce plus the §5
//! mitigations (asynchronous updates, partial-gradient communication).

use std::sync::Arc;

use crate::allocation::WorkerId;
use crate::params::GradView;

/// Gradient payload from one trainer for one iteration.
///
/// Dense gradients are shared slices (`Arc<[f32]>`): requeueing a
/// submission under the Async policy, cloning for tests, or fanning a
/// payload out to shard threads bumps a refcount instead of copying
/// ~100 KB of gradient — the ingest path is zero-copy end-to-end.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Full Σ-gradient over the worker's processed examples.
    Dense(Arc<[f32]>),
    /// Top-k (index, Σ-value) pairs — partial-gradient communication —
    /// sorted ascending by index (shards binary-search this ordering).
    Sparse(Vec<(u32, f32)>),
}

impl Payload {
    /// Build a dense payload from an owned gradient (no copy).
    pub fn dense(grad: Vec<f32>) -> Payload {
        Payload::Dense(grad.into())
    }

    /// Wire size of this payload (f32 values, u32 indices).
    pub fn bytes(&self) -> u64 {
        match self {
            Payload::Dense(v) => (v.len() * 4) as u64,
            Payload::Sparse(v) => (v.len() * 8) as u64,
        }
    }

    /// Borrowed view for the reduce step (`params::ShardedAccumulator`).
    pub fn as_view(&self) -> GradView<'_> {
        match self {
            Payload::Dense(v) => GradView::Dense(&v[..]),
            Payload::Sparse(e) => GradView::Sparse(e),
        }
    }

    /// True when every carried value is finite — the master's sanitation
    /// gate: a NaN/Inf payload is quarantined (strike against the worker)
    /// instead of poisoning the shared parameters through the reduce.
    pub fn is_finite(&self) -> bool {
        match self {
            Payload::Dense(v) => v.iter().all(|x| x.is_finite()),
            Payload::Sparse(e) => e.iter().all(|(_, x)| x.is_finite()),
        }
    }

    /// Build a sparse payload keeping the `keep_fraction` largest-|g|
    /// coordinates ("send the most informative", §5 Communication
    /// Overhead).
    pub fn sparsify(dense: &[f32], keep_fraction: f64) -> Payload {
        let keep = ((dense.len() as f64 * keep_fraction).ceil() as usize)
            .clamp(1, dense.len());
        let mut idx: Vec<u32> = (0..dense.len() as u32).collect();
        // Partial selection by |g| descending.  total_cmp: a NaN gradient
        // coordinate (diverged training) sorts as the largest magnitude
        // and gets *kept* — it must surface at the master, and the old
        // `partial_cmp().unwrap()` panicked mid-comparison instead.
        idx.select_nth_unstable_by(keep - 1, |&a, &b| {
            dense[b as usize]
                .abs()
                .total_cmp(&dense[a as usize].abs())
        });
        let mut entries: Vec<(u32, f32)> = idx[..keep]
            .iter()
            .map(|&i| (i, dense[i as usize]))
            .collect();
        entries.sort_unstable_by_key(|e| e.0);
        Payload::Sparse(entries)
    }
}

/// One trainer's end-of-iteration message, as seen at the master.
#[derive(Debug, Clone)]
pub struct Submission {
    pub worker: WorkerId,
    pub payload: Payload,
    /// Examples behind the Σ-gradient (weighting for the reduce).
    pub examples: u64,
    /// Data vectors processed this iteration (power accounting; equals
    /// `examples` for dense, also for sparse — sparsity drops coordinates,
    /// not examples).
    pub vectors: u64,
    /// Σ loss over processed examples.
    pub loss_sum: f64,
    /// When the message reaches the master, relative to iteration start
    /// (ms): scheduled compute end + uplink latency + transmit time.
    pub send_offset_ms: f64,
    /// Wire bytes (payload + envelope) for the master's ingest model.
    pub bytes: u64,
}

/// Reduce policy (§3.3c baseline; §5 mitigations as ablations).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReducePolicy {
    /// Paper prototype: barrier until the slowest submission arrives and
    /// is merged ("asynchronous reduction callback delay").
    Sync,
    /// §5 mitigation: the iteration closes at T; late submissions are
    /// merged in the *next* iteration (bounded staleness 1).
    Async,
    /// Sync barrier but workers send only the top-|g| fraction.
    PartialSync { keep_fraction: f64 },
}

impl ReducePolicy {
    pub fn parse(s: &str) -> Result<Self, String> {
        if s == "sync" {
            Ok(ReducePolicy::Sync)
        } else if s == "async" {
            Ok(ReducePolicy::Async)
        } else if let Some(frac) = s.strip_prefix("partial:") {
            let f: f64 = frac
                .parse()
                .map_err(|_| format!("bad partial fraction '{frac}'"))?;
            if !(0.0..=1.0).contains(&f) || f == 0.0 {
                return Err(format!("partial fraction {f} out of (0, 1]"));
            }
            Ok(ReducePolicy::PartialSync { keep_fraction: f })
        } else {
            Err(format!("unknown policy '{s}' (sync|async|partial:<f>)"))
        }
    }

    pub fn name(&self) -> String {
        match self {
            ReducePolicy::Sync => "sync".into(),
            ReducePolicy::Async => "async".into(),
            ReducePolicy::PartialSync { keep_fraction } => format!("partial:{keep_fraction}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparsify_keeps_largest_magnitudes() {
        let dense = vec![0.1, -5.0, 0.0, 3.0, -0.2];
        let Payload::Sparse(entries) = Payload::sparsify(&dense, 0.4) else {
            panic!()
        };
        assert_eq!(entries, vec![(1, -5.0), (3, 3.0)]);
    }

    #[test]
    fn sparsify_full_fraction_keeps_everything() {
        let dense = vec![1.0, 2.0, 3.0];
        let Payload::Sparse(entries) = Payload::sparsify(&dense, 1.0) else {
            panic!()
        };
        assert_eq!(entries.len(), 3);
    }

    #[test]
    fn sparsify_keeps_at_least_one() {
        let Payload::Sparse(entries) = Payload::sparsify(&[0.5, 0.1], 1e-9) else {
            panic!()
        };
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, 0);
    }

    #[test]
    fn payload_bytes() {
        assert_eq!(Payload::dense(vec![0.0; 10]).bytes(), 40);
        assert_eq!(Payload::Sparse(vec![(0, 1.0); 10]).bytes(), 80);
    }

    #[test]
    fn dense_payload_clone_shares_the_gradient() {
        let p = Payload::dense(vec![1.0; 64]);
        let q = p.clone();
        let (Payload::Dense(a), Payload::Dense(b)) = (&p, &q) else {
            panic!()
        };
        assert!(Arc::ptr_eq(a, b), "clone must share, not copy");
    }

    #[test]
    fn sparsify_with_nan_does_not_panic_and_keeps_the_nan() {
        // A diverged gradient must reach the master, not kill the client.
        let dense = vec![0.1, f32::NAN, 0.5, -2.0];
        let Payload::Sparse(entries) = Payload::sparsify(&dense, 0.5) else {
            panic!()
        };
        assert_eq!(entries.len(), 2);
        assert!(
            entries.iter().any(|&(i, v)| i == 1 && v.is_nan()),
            "NaN sorts as largest magnitude: {entries:?}"
        );
    }

    #[test]
    fn payload_finiteness_gate() {
        assert!(Payload::dense(vec![1.0, -2.0]).is_finite());
        assert!(!Payload::dense(vec![1.0, f32::NAN]).is_finite());
        assert!(!Payload::dense(vec![f32::INFINITY]).is_finite());
        assert!(Payload::Sparse(vec![(0, 1.0)]).is_finite());
        assert!(!Payload::Sparse(vec![(0, 1.0), (3, f32::NEG_INFINITY)]).is_finite());
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(ReducePolicy::parse("sync").unwrap(), ReducePolicy::Sync);
        assert_eq!(ReducePolicy::parse("async").unwrap(), ReducePolicy::Async);
        assert_eq!(
            ReducePolicy::parse("partial:0.1").unwrap(),
            ReducePolicy::PartialSync { keep_fraction: 0.1 }
        );
        assert!(ReducePolicy::parse("partial:0").is_err());
        assert!(ReducePolicy::parse("wat").is_err());
    }
}
