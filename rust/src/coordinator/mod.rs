//! The master server — MLitB's coordination contribution.
//!
//! Implements the paper's **master event loop** (§3.3): a synchronized
//! map-reduce iteration of user-set duration `T` with five ordered steps —
//! (a) data upload/allocation, (b) new-trainer init + allocation,
//! (c) the reduce step (weighted gradient average + AdaGrad), (d) latency
//! monitoring + adaptive work budgets, (e) parameter broadcast — plus the
//! paper's §5 mitigations as first-class reduce policies (async updates,
//! partial gradients, multiple master processes).
//!
//! The master is *pure coordination*: it consumes [`Submission`]s (whose
//! arrival offsets the simulation computes from compute budgets and link
//! models) and produces parameter updates, allocation deltas, and timeline
//! records.  This keeps it unit-testable without the PJRT engine.

mod latency;
mod master;
mod reduce;

pub use latency::{LatencyMonitor, DEFAULT_PRIOR_MS};
pub use master::{
    IterationOutcome, Master, MasterConfig, MasterState, PayloadState, SubmissionState,
};
pub use reduce::{Payload, ReducePolicy, Submission};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::PAPER_CAPACITY;
    use crate::params::OptimizerKind;

    /// One full synchronized iteration end-to-end at the coordinator level.
    #[test]
    fn one_iteration_updates_params_and_timeline() {
        let cfg = MasterConfig {
            param_count: 4,
            iter_duration_s: 4.0,
            optimizer: OptimizerKind::AdaGrad,
            learning_rate: 0.1,
            capacity: PAPER_CAPACITY,
            policy: ReducePolicy::Sync,
            ..Default::default()
        };
        let mut m = Master::new(cfg, vec![0.0; 4]);
        m.register_data(100);
        m.worker_join(1);
        let sub = Submission {
            worker: 1,
            payload: Payload::dense(vec![4.0, 4.0, 4.0, 4.0]),
            examples: 4,
            vectors: 4,
            loss_sum: 9.2,
            send_offset_ms: 4000.0,
            bytes: 1024,
        };
        let out = m.finish_iteration(vec![sub]);
        assert_eq!(m.iteration(), 1);
        assert!(out.wall_ms >= 4000.0);
        // AdaGrad first step: -lr * sign(g)
        for p in m.params() {
            assert!((p + 0.1).abs() < 1e-4, "{:?}", m.params());
        }
        assert_eq!(m.timeline().len(), 1);
        let rec = m.timeline().last().unwrap();
        assert_eq!(rec.vectors, 4);
        assert!((rec.loss.unwrap() - 2.3).abs() < 1e-6);
    }
}
