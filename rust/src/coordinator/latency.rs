//! Latency monitoring and adaptive work scheduling (§3.3d).
//!
//! "At each reduce step, the master node estimates the latency between the
//! client and the master and informs the client worker how long it should
//! run for.  A client does not need to have a batch size because it just
//! clocks its own computation and returns results at the end of its
//! scheduled work time. ... if the user's device slows or has increased
//! latency, the master will decrease the load on the device for the next
//! iteration."

use std::collections::BTreeMap;

use crate::allocation::WorkerId;

/// Prior estimate for a worker we have not heard from yet (ms round trip).
pub const DEFAULT_PRIOR_MS: f64 = 50.0;

/// EWMA smoothing factor for latency updates.
const ALPHA: f64 = 0.3;

/// Per-worker round-trip latency estimates + work-budget computation.
#[derive(Debug, Clone, Default)]
pub struct LatencyMonitor {
    estimates: BTreeMap<WorkerId, f64>,
}

impl LatencyMonitor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an observed round-trip overhead for `worker` (everything the
    /// master saw beyond the scheduled compute time: network + queueing).
    pub fn observe(&mut self, worker: WorkerId, observed_ms: f64) {
        let e = self.estimates.entry(worker).or_insert(observed_ms);
        *e = (1.0 - ALPHA) * *e + ALPHA * observed_ms;
    }

    /// Current estimate (prior if unseen).
    pub fn estimate(&self, worker: WorkerId) -> f64 {
        self.estimates
            .get(&worker)
            .copied()
            .unwrap_or(DEFAULT_PRIOR_MS)
    }

    pub fn forget(&mut self, worker: WorkerId) {
        self.estimates.remove(&worker);
    }

    /// The compute budget the master schedules for `worker` so that its
    /// result arrives by the sync point: T minus the latency estimate
    /// (clamped to ≥10% of T so even very slow links do some work —
    /// matching the paper's goal of keeping every device contributing).
    pub fn work_budget_ms(&self, worker: WorkerId, iter_ms: f64) -> f64 {
        (iter_ms - self.estimate(worker)).max(0.1 * iter_ms)
    }

    /// §3.3d data-allocation adjustment trigger: a worker whose latency
    /// eats more than `frac` of the iteration should shed cached load.
    pub fn is_overloaded(&self, worker: WorkerId, iter_ms: f64, frac: f64) -> bool {
        self.estimate(worker) > frac * iter_ms
    }

    /// Estimates as sorted (worker, estimate) pairs — for checkpointing.
    pub fn export_state(&self) -> Vec<(WorkerId, f64)> {
        self.estimates.iter().map(|(&w, &e)| (w, e)).collect()
    }

    /// Rebuild the monitor from a captured export. EWMA continuation is
    /// exact: the estimate is the whole observable state.
    pub fn import_state(&mut self, state: Vec<(WorkerId, f64)>) {
        self.estimates = state.into_iter().collect();
    }

    /// Mean estimate over known workers (Fig 4's latency axis).
    pub fn mean_estimate(&self) -> f64 {
        if self.estimates.is_empty() {
            return 0.0;
        }
        self.estimates.values().sum::<f64>() / self.estimates.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges_toward_observations() {
        let mut m = LatencyMonitor::new();
        assert_eq!(m.estimate(1), DEFAULT_PRIOR_MS);
        for _ in 0..50 {
            m.observe(1, 100.0);
        }
        assert!((m.estimate(1) - 100.0).abs() < 1.0);
    }

    #[test]
    fn first_observation_replaces_prior() {
        let mut m = LatencyMonitor::new();
        m.observe(7, 10.0);
        assert_eq!(m.estimate(7), 10.0);
    }

    #[test]
    fn budget_shrinks_with_latency() {
        let mut m = LatencyMonitor::new();
        m.observe(1, 500.0);
        m.observe(2, 50.0);
        let b1 = m.work_budget_ms(1, 4000.0);
        let b2 = m.work_budget_ms(2, 4000.0);
        assert!(b1 < b2);
        assert!((b1 - 3500.0).abs() < 1e-9);
    }

    #[test]
    fn budget_floor_keeps_slow_devices_working() {
        let mut m = LatencyMonitor::new();
        m.observe(1, 10_000.0);
        assert_eq!(m.work_budget_ms(1, 4000.0), 400.0);
    }

    #[test]
    fn overload_detection() {
        let mut m = LatencyMonitor::new();
        m.observe(1, 3000.0);
        assert!(m.is_overloaded(1, 4000.0, 0.5));
        assert!(!m.is_overloaded(1, 10_000.0, 0.5));
    }

    #[test]
    fn forget_restores_prior() {
        let mut m = LatencyMonitor::new();
        m.observe(1, 1.0);
        m.forget(1);
        assert_eq!(m.estimate(1), DEFAULT_PRIOR_MS);
    }
}
