//! `mlitb lint` — zero-dependency static analyzer for the crate's own
//! determinism invariants.
//!
//! The repo's headline claims — equal seeds give bitwise-identical
//! params and byte-identical trace exports — rest on conventions a
//! compiler never checks: no unordered-map iteration on deterministic
//! paths, `total_cmp` instead of `partial_cmp().unwrap()`, no
//! wall-clock reads outside `bench/`, all randomness through `rng::`,
//! no unscoped threads, no printing from library planes.  This module
//! turns those conventions into a checker, hand-rolled in the same
//! zero-dep spirit as `crate::json`:
//!
//! - [`lexer`] — a small Rust lexer (strings, raw strings, char vs
//!   lifetime, nested block comments) producing tokens + comments;
//! - [`rules`] — six token-pattern rule passes scoped by module path;
//! - [`report`] — stable-ordered diagnostics, rendered to `String`.
//!
//! Suppression: `// lint: allow(<rule>) — <reason>` on the offending
//! line or the line above; the reason is mandatory.  See DESIGN.md
//! "Determinism discipline" for every rule and its rationale.

pub mod lexer;
pub mod report;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use report::{Diagnostic, Report};
pub use rules::RuleId;

/// Analyze one file's source text.  `rel_path` is used both for rule
/// scoping (module path) and for diagnostic positions.
pub fn analyze_source(rel_path: &str, src: &str) -> Vec<Diagnostic> {
    let lexed = lexer::lex(src);
    let mut diags = rules::run_rules(rel_path, &lexed);
    let sups = rules::parse_suppressions(&lexed.comments);
    if !sups.is_empty() {
        apply_suppressions(&mut diags, &sups, &lexed);
    }
    for s in &sups {
        if s.rule.is_none() {
            diags.push(Diagnostic {
                path: rel_path.to_string(),
                line: s.line,
                col: 1,
                rule: RuleId::BadSuppression,
                message: format!(
                    "unknown rule `{}` in lint: allow(…) — known rules: {}",
                    s.raw_rule,
                    RuleId::ALL.map(|r| r.id()).join(", ")
                ),
                snippet: format!("lint: allow({})", s.raw_rule),
                suppressed: false,
                missing_reason: false,
            });
        }
    }
    diags
}

/// A suppression covers findings on the comment's own line(s) — the
/// trailing-comment case — and on the first token-bearing line after
/// it — the comment-above case.
fn apply_suppressions(
    diags: &mut [Diagnostic],
    suppressions: &[rules::Suppression],
    lexed: &lexer::Lexed,
) {
    for s in suppressions {
        let Some(rule) = s.rule else { continue };
        let next_line = lexed
            .tokens
            .iter()
            .map(|t| t.line)
            .filter(|&l| l > s.end_line)
            .min();
        for d in diags.iter_mut() {
            if d.rule != rule {
                continue;
            }
            let covered = (d.line >= s.line && d.line <= s.end_line) || Some(d.line) == next_line;
            if covered {
                if s.has_reason {
                    d.suppressed = true;
                } else {
                    d.missing_reason = true;
                }
            }
        }
    }
}

/// Analyze a file on disk, using its path string for scoping.
pub fn analyze_file(path: &Path) -> io::Result<Vec<Diagnostic>> {
    let src = fs::read_to_string(path)?;
    Ok(analyze_source(&path.to_string_lossy(), &src))
}

/// Recursively lint every `.rs` file under `root` (which may itself be
/// a single file).  Files are visited in sorted path order, so the
/// report is deterministic regardless of directory-entry order.
pub fn analyze_tree(root: &Path, report: &mut Report) -> io::Result<()> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    for f in &files {
        report.extend(analyze_file(f)?);
    }
    report.sort();
    Ok(())
}

fn collect_rs_files(path: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if path.is_dir() {
        for entry in fs::read_dir(path)? {
            collect_rs_files(&entry?.path(), out)?;
        }
    } else if path.extension().is_some_and(|e| e == "rs") {
        out.push(path.to_path_buf());
    }
    Ok(())
}
