//! Determinism rule passes over the token stream.
//!
//! Every rule is a token-pattern matcher scoped by module path — no
//! type inference, so the matchers are deliberately conservative and
//! anchored on qualified paths (`std :: time`, `thread :: spawn`) or
//! on receivers *declared in the same file* as `HashMap`/`HashSet`
//! (the unordered-iteration rule).  False-positive escape hatch:
//! `// lint: allow(<rule>) — <reason>` on or directly above the
//! offending line, reason mandatory (see [`parse_suppressions`]).

use super::lexer::{Comment, Lexed, Token, TokenKind};
use super::report::Diagnostic;

/// The six determinism rules plus the meta-diagnostic for malformed
/// suppression comments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    /// `.iter()/.keys()/.values()/.drain()/for … in &` on a
    /// `HashMap`/`HashSet` receiver inside an order-sensitive plane.
    UnorderedIteration,
    /// `partial_cmp` chained with `.unwrap()` or used inside a sort/
    /// min/max comparator — NaN panics; use `total_cmp`.
    FloatOrdUnwrap,
    /// `std::time::{Instant,SystemTime}` or `thread::sleep` outside
    /// `bench/` — wall-clock reads break virtual-clock determinism.
    WallClock,
    /// RNG construction outside `rng::` — all randomness must flow
    /// from an explicitly seeded `Pcg32`.
    UnseededRandomness,
    /// `thread::spawn` outside `params/sharded.rs` — unscoped threads
    /// make completion order a scheduler artifact.
    RawSpawn,
    /// `println!`/`eprintln!`/`dbg!` outside `cli/`, `main.rs` and
    /// benches — library planes must return data, not print it.
    StrayPrint,
    /// A `lint: allow(…)` comment naming an unknown rule.
    BadSuppression,
}

impl RuleId {
    /// The six user-facing rules (excludes [`RuleId::BadSuppression`]).
    pub const ALL: [RuleId; 6] = [
        RuleId::UnorderedIteration,
        RuleId::FloatOrdUnwrap,
        RuleId::WallClock,
        RuleId::UnseededRandomness,
        RuleId::RawSpawn,
        RuleId::StrayPrint,
    ];

    /// Stable diagnostic / suppression id.
    pub fn id(self) -> &'static str {
        match self {
            RuleId::UnorderedIteration => "unordered-iteration",
            RuleId::FloatOrdUnwrap => "float-ord-unwrap",
            RuleId::WallClock => "wall-clock",
            RuleId::UnseededRandomness => "unseeded-randomness",
            RuleId::RawSpawn => "raw-spawn",
            RuleId::StrayPrint => "stray-print",
            RuleId::BadSuppression => "bad-suppression",
        }
    }

    pub fn from_id(s: &str) -> Option<RuleId> {
        RuleId::ALL.iter().copied().find(|r| r.id() == s)
    }
}

/// Module scope derived from the (slash-normalized) relative path.
#[derive(Debug)]
struct Scope {
    /// Path components after the last `src` component (empty when the
    /// path has no `src`, e.g. `benches/micro.rs`).
    module: Vec<String>,
    /// Under `benches/`, `examples/` or `tests/` — measurement and
    /// harness code where wall-clock and printing are the point.
    bench_like: bool,
    is_main: bool,
}

impl Scope {
    fn new(rel_path: &str) -> Self {
        let norm = rel_path.replace('\\', "/");
        let mut parts: Vec<&str> = norm.split('/').collect();
        parts.retain(|p| !p.is_empty() && *p != ".");
        let after_src = match parts.iter().rposition(|p| *p == "src") {
            Some(i) => &parts[i + 1..],
            None => &parts[..],
        };
        let mut bench_like = after_src.first() == Some(&"bench");
        for p in &parts {
            if matches!(*p, "benches" | "examples" | "tests") {
                bench_like = true;
            }
        }
        Scope {
            module: after_src.iter().map(|s| s.to_string()).collect(),
            bench_like,
            is_main: after_src == ["main.rs"],
        }
    }

    fn top(&self) -> &str {
        self.module.first().map(String::as_str).unwrap_or("")
    }

    /// Order-sensitive planes: anywhere map iteration order could leak
    /// into params, schedules, logs or exports.
    fn ordered_plane(&self) -> bool {
        const PLANES: [&str; 12] = [
            "sim",
            "serve",
            "cosim",
            "coordinator",
            "params",
            "netsim",
            "trace",
            "metrics",
            "data",
            "client",
            "storage",
            "faults",
        ];
        PLANES.contains(&self.top())
    }

    fn wall_clock_exempt(&self) -> bool {
        self.bench_like || self.top() == "bench"
    }

    fn rng_exempt(&self) -> bool {
        self.top() == "rng"
    }

    fn spawn_exempt(&self) -> bool {
        self.module == ["params", "sharded.rs"]
    }

    fn print_exempt(&self) -> bool {
        self.bench_like || self.is_main || self.top() == "cli" || self.top() == "bench"
    }
}

const ITER_METHODS: [&str; 9] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
];

const SORT_FNS: [&str; 9] = [
    "sort_by",
    "sort_unstable_by",
    "sort_by_key",
    "sort_unstable_by_key",
    "min_by",
    "max_by",
    "min_by_key",
    "max_by_key",
    "binary_search_by",
];

const RNG_IDENTS: [&str; 5] = ["thread_rng", "from_entropy", "OsRng", "getrandom", "RandomState"];

/// Run every rule over one lexed file.  `rel_path` scopes the rules;
/// suppressions are applied by the caller (`analysis::analyze_source`).
pub fn run_rules(rel_path: &str, lexed: &Lexed) -> Vec<Diagnostic> {
    let scope = Scope::new(rel_path);
    let toks = &lexed.tokens;
    let mut out = Vec::new();

    let map_names = if scope.ordered_plane() {
        collect_map_names(toks)
    } else {
        Vec::new()
    };

    // Sort-comparator context for float-ord-unwrap: stack of paren
    // depths at which a sort/min/max call opened.
    let mut depth = 0usize;
    let mut sort_depths: Vec<usize> = Vec::new();

    for i in 0..toks.len() {
        let t = &toks[i];
        match t.kind {
            TokenKind::Punct if t.text == "(" => {
                depth += 1;
                if i > 0
                    && toks[i - 1].kind == TokenKind::Ident
                    && SORT_FNS.contains(&toks[i - 1].text.as_str())
                {
                    sort_depths.push(depth);
                }
                continue;
            }
            TokenKind::Punct if t.text == ")" => {
                if sort_depths.last() == Some(&depth) {
                    sort_depths.pop();
                }
                depth = depth.saturating_sub(1);
                continue;
            }
            TokenKind::Ident => {}
            _ => continue,
        }

        // --- unordered-iteration -------------------------------------
        if !map_names.is_empty() {
            if ITER_METHODS.contains(&t.text.as_str())
                && tok_is(toks, i + 1, "(")
                && tok_is(toks, i.wrapping_sub(1), ".")
                && i >= 2
                && toks[i - 2].kind == TokenKind::Ident
                && map_names.contains(&toks[i - 2].text)
            {
                out.push(diag(
                    RuleId::UnorderedIteration,
                    rel_path,
                    &toks[i - 2],
                    format!("`{}.{}()` iterates a HashMap/HashSet", toks[i - 2].text, t.text),
                    snippet(toks, i - 2, 5),
                ));
            }
            if t.text == "for" {
                if let Some(d) = for_loop_over_map(toks, i, &map_names, rel_path) {
                    out.push(d);
                }
            }
        }

        // --- float-ord-unwrap ----------------------------------------
        if t.text == "partial_cmp" {
            let in_sort = !sort_depths.is_empty();
            let unwrapped = call_then_unwrap(toks, i);
            if in_sort || unwrapped {
                let why = if unwrapped {
                    "`partial_cmp(..).unwrap()` panics on NaN"
                } else {
                    "`partial_cmp` inside a comparator panics on NaN"
                };
                out.push(diag(
                    RuleId::FloatOrdUnwrap,
                    rel_path,
                    t,
                    format!("{why}; use `total_cmp`"),
                    snippet(toks, i, 6),
                ));
            }
        }

        // --- wall-clock ----------------------------------------------
        if !scope.wall_clock_exempt() {
            let hit = (t.text == "std" && path_next(toks, i, "time"))
                || ((t.text == "Instant" || t.text == "SystemTime") && path_next(toks, i, "now"))
                || (t.text == "thread" && path_next(toks, i, "sleep"));
            if hit {
                out.push(diag(
                    RuleId::WallClock,
                    rel_path,
                    t,
                    "wall-clock access outside bench/ breaks virtual-clock determinism",
                    snippet(toks, i, 6),
                ));
            }
        }

        // --- unseeded-randomness -------------------------------------
        if !scope.rng_exempt() {
            let hit = RNG_IDENTS.contains(&t.text.as_str())
                || (t.text == "rand" && tok_is(toks, i + 1, ":") && tok_is(toks, i + 2, ":"));
            if hit {
                out.push(diag(
                    RuleId::UnseededRandomness,
                    rel_path,
                    t,
                    "RNG construction outside rng:: — all randomness must be seeded Pcg32",
                    snippet(toks, i, 6),
                ));
            }
        }

        // --- raw-spawn -----------------------------------------------
        if !scope.spawn_exempt() && t.text == "thread" && path_next(toks, i, "spawn") {
            out.push(diag(
                RuleId::RawSpawn,
                rel_path,
                t,
                "thread::spawn outside params/sharded.rs — use the scoped reduce pool",
                snippet(toks, i, 6),
            ));
        }

        // --- stray-print ---------------------------------------------
        if !scope.print_exempt()
            && matches!(t.text.as_str(), "println" | "print" | "eprintln" | "eprint" | "dbg")
            && tok_is(toks, i + 1, "!")
        {
            out.push(diag(
                RuleId::StrayPrint,
                rel_path,
                t,
                "printing from a library plane — return data and print in cli/ or main.rs",
                snippet(toks, i, 4),
            ));
        }
    }

    out.sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
    out.dedup_by(|a, b| a.line == b.line && a.col == b.col && a.rule == b.rule);
    out
}

/// Pass 1 of unordered-iteration: names declared in this file with a
/// `HashMap`/`HashSet` type annotation or `= HashMap::new()`-style
/// initializer (`name : [path ::] HashMap` or `name = [path ::]
/// HashMap`).
fn collect_map_names(toks: &[Token]) -> Vec<String> {
    let mut names = Vec::new();
    for i in 0..toks.len() {
        if toks[i].kind != TokenKind::Ident
            || (toks[i].text != "HashMap" && toks[i].text != "HashSet")
        {
            continue;
        }
        // Walk left over `ident ::` path qualifiers.
        let mut j = i;
        while j >= 3
            && tok_is(toks, j - 1, ":")
            && tok_is(toks, j - 2, ":")
            && toks[j - 3].kind == TokenKind::Ident
        {
            j -= 3;
        }
        // Before the path: a single `:` (type annotation) or `=`
        // (initializer), preceded by the binding name.
        if j >= 2 {
            let sep_single_colon = tok_is(toks, j - 1, ":") && !tok_is(toks, j - 2, ":");
            let sep = if sep_single_colon {
                j - 1
            } else if tok_is(toks, j - 1, "=") {
                j - 1
            } else {
                continue;
            };
            if sep >= 1 && toks[sep - 1].kind == TokenKind::Ident {
                let name = &toks[sep - 1].text;
                if !names.contains(name) {
                    names.push(name.clone());
                }
            }
        }
    }
    names
}

/// `for … in [&][mut] [self.]name {` where `name` is a known map.
fn for_loop_over_map(
    toks: &[Token],
    for_idx: usize,
    map_names: &[String],
    rel_path: &str,
) -> Option<Diagnostic> {
    // Find the `in` keyword (bounded scan: patterns are destructuring
    // only, never long).
    let in_idx = (for_idx + 1..toks.len().min(for_idx + 16))
        .find(|&k| toks[k].kind == TokenKind::Ident && toks[k].text == "in")?;
    // Collect the iterated expression up to the body `{` at depth 0.
    let mut depth = 0i32;
    let mut last: Option<usize> = None;
    for k in in_idx + 1..toks.len().min(in_idx + 24) {
        let t = &toks[k];
        if t.kind == TokenKind::Punct {
            match t.text.as_str() {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "{" if depth == 0 => {
                    // Flag only when the loop iterates the map value
                    // itself: the last expression token is the name.
                    let l = last?;
                    if toks[l].kind == TokenKind::Ident && map_names.contains(&toks[l].text) {
                        return Some(diag(
                            RuleId::UnorderedIteration,
                            rel_path,
                            &toks[l],
                            format!("`for … in {}` iterates a HashMap/HashSet", toks[l].text),
                            snippet(toks, for_idx, (l - for_idx).min(10) + 1),
                        ));
                    }
                    return None;
                }
                _ => {}
            }
        }
        last = Some(k);
    }
    None
}

/// True when `toks[i]` opens a call whose balanced close is followed
/// by `.unwrap(` / `.expect(`.
fn call_then_unwrap(toks: &[Token], i: usize) -> bool {
    if !tok_is(toks, i + 1, "(") {
        return false;
    }
    let mut depth = 0i32;
    let mut k = i + 1;
    while k < toks.len() {
        match (toks[k].kind, toks[k].text.as_str()) {
            (TokenKind::Punct, "(") => depth += 1,
            (TokenKind::Punct, ")") => {
                depth -= 1;
                if depth == 0 {
                    if !tok_is(toks, k + 1, ".") || !tok_is(toks, k + 3, "(") {
                        return false;
                    }
                    return ident_at(toks, k + 2, "unwrap") || ident_at(toks, k + 2, "expect");
                }
            }
            _ => {}
        }
        k += 1;
    }
    false
}

/// `toks[i] :: next` — the qualified-path successor check that keeps
/// `EventKind::Instant` (an enum variant) from tripping wall-clock.
fn path_next(toks: &[Token], i: usize, next: &str) -> bool {
    tok_is(toks, i + 1, ":") && tok_is(toks, i + 2, ":") && ident_at(toks, i + 3, next)
}

fn tok_is(toks: &[Token], i: usize, text: &str) -> bool {
    match toks.get(i) {
        Some(t) => t.kind == TokenKind::Punct && t.text == text,
        None => false,
    }
}

fn ident_at(toks: &[Token], i: usize, text: &str) -> bool {
    match toks.get(i) {
        Some(t) => t.kind == TokenKind::Ident && t.text == text,
        None => false,
    }
}

/// Compact source-ish snippet from up to `n` tokens starting at `i`.
fn snippet(toks: &[Token], i: usize, n: usize) -> String {
    let mut s = String::new();
    let mut prev_wordy = false;
    for t in toks.iter().skip(i).take(n) {
        let wordy = matches!(t.kind, TokenKind::Ident | TokenKind::Num | TokenKind::Lifetime);
        if prev_wordy && wordy {
            s.push(' ');
        }
        s.push_str(&t.text);
        prev_wordy = wordy;
    }
    s
}

fn diag(
    rule: RuleId,
    path: &str,
    at: &Token,
    message: impl Into<String>,
    snippet: String,
) -> Diagnostic {
    Diagnostic {
        path: path.to_string(),
        line: at.line,
        col: at.col,
        rule,
        message: message.into(),
        snippet,
        suppressed: false,
        missing_reason: false,
    }
}

/// A parsed `lint: allow(<rule>) — <reason>` comment.
#[derive(Debug)]
pub struct Suppression {
    /// `None` when the named rule id is unknown (→ bad-suppression).
    pub rule: Option<RuleId>,
    /// Raw rule name as written (for the bad-suppression message).
    pub raw_rule: String,
    /// True when a non-empty reason follows the closing paren.
    pub has_reason: bool,
    pub line: u32,
    pub end_line: u32,
}

/// Extract every `lint: allow(<rule>) — <reason>` marker from the captured
/// comments.  The reason is mandatory: anything after the closing
/// paren containing at least one alphanumeric character counts.
///
/// A marker only counts as a suppression *attempt* when the rule name
/// is shaped like a rule id (lowercase, digits, dashes) — prose such
/// as documentation writing out the `allow(<rule>)` syntax is ignored
/// rather than reported as a bad suppression.
pub fn parse_suppressions(comments: &[Comment]) -> Vec<Suppression> {
    let mut out = Vec::new();
    for c in comments {
        let mut rest = c.text.as_str();
        while let Some(pos) = rest.find("lint: allow(") {
            rest = &rest[pos + "lint: allow(".len()..];
            let Some(close) = rest.find(')') else { break };
            let raw_rule = rest[..close].trim().to_string();
            let tail = &rest[close + 1..];
            let id_shaped = !raw_rule.is_empty()
                && raw_rule
                    .chars()
                    .all(|ch| ch.is_ascii_lowercase() || ch.is_ascii_digit() || ch == '-');
            if !id_shaped {
                rest = tail;
                continue;
            }
            let has_reason = tail
                .split("lint: allow(")
                .next()
                .unwrap_or("")
                .chars()
                .any(|ch| ch.is_alphanumeric());
            out.push(Suppression {
                rule: RuleId::from_id(&raw_rule),
                raw_rule,
                has_reason,
                line: c.line,
                end_line: c.end_line,
            });
            rest = tail;
        }
    }
    out
}
