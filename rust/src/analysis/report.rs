//! Diagnostic collection and rendering for `mlitb lint`.
//!
//! Nothing in this module (or anywhere under `analysis/`) prints:
//! [`Report::render`] returns a `String` and the CLI decides where it
//! goes — which also keeps the analyzer clean under its own
//! stray-print rule.

use super::rules::RuleId;

/// One finding, positioned and classified.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Path as given on the command line (slash-normalized).
    pub path: String,
    /// 1-based line of the offending token.
    pub line: u32,
    /// 1-based column of the offending token.
    pub col: u32,
    pub rule: RuleId,
    /// Human explanation of why this pattern is banned.
    pub message: String,
    /// Compact reconstruction of the offending tokens.
    pub snippet: String,
    /// Covered by a well-formed `lint: allow` with a reason.
    pub suppressed: bool,
    /// A `lint: allow` matched but carried no reason — the finding
    /// stays live and the render says why.
    pub missing_reason: bool,
}

/// All findings for a lint run, in stable (path, line, col) order.
#[derive(Debug, Default)]
pub struct Report {
    diags: Vec<Diagnostic>,
}

impl Report {
    pub fn extend(&mut self, diags: Vec<Diagnostic>) {
        self.diags.extend(diags);
    }

    /// Every finding, suppressed or not.
    pub fn all(&self) -> &[Diagnostic] {
        &self.diags
    }

    /// Findings that gate CI: not suppressed, or suppressed without a
    /// reason.
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diags.iter().filter(|d| !d.suppressed)
    }

    pub fn unsuppressed_count(&self) -> usize {
        self.unsuppressed().count()
    }

    pub fn suppressed_count(&self) -> usize {
        self.diags.len() - self.unsuppressed_count()
    }

    pub fn is_clean(&self) -> bool {
        self.unsuppressed_count() == 0
    }

    /// Stable ordering: path, then line, then column, then rule.
    pub fn sort(&mut self) {
        self.diags.sort_by(|a, b| {
            (&a.path, a.line, a.col, a.rule).cmp(&(&b.path, b.line, b.col, b.rule))
        });
    }

    /// Render the gating findings plus a one-line summary.  Returns an
    /// empty string when the tree is clean and nothing was suppressed.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in self.unsuppressed() {
            out.push_str(&format!(
                "{}:{}:{}: [{}] {} — `{}`",
                d.path,
                d.line,
                d.col,
                d.rule.id(),
                d.message,
                d.snippet
            ));
            if d.missing_reason {
                out.push_str("  (lint: allow present but the reason is missing)");
            }
            out.push('\n');
        }
        let live = self.unsuppressed_count();
        let quiet = self.suppressed_count();
        if live > 0 || quiet > 0 {
            out.push_str(&format!("lint: {live} finding(s), {quiet} suppressed with reason\n"));
        }
        out
    }
}
