//! Token-level lexer for the determinism linter.
//!
//! Hand-rolled in the same spirit as `crate::json`: zero dependencies,
//! byte-indexed scanning, no allocation beyond the output vectors.  The
//! lexer is deliberately *not* a full Rust lexer — it only needs to be
//! precise about the constructs that would otherwise produce false
//! positives in a token-pattern matcher:
//!
//! - string literals (plain, raw `r#"…"#` with any hash count, byte)
//! - char literals vs lifetimes (`'x'` vs `'a`)
//! - line comments and *nested* block comments
//! - raw identifiers (`r#match`)
//!
//! Comments are captured separately (with their line numbers) so the
//! rule layer can resolve `// lint: allow(<rule>) — <reason>`
//! suppressions without re-scanning the source.

/// Classification of a lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (`HashMap`, `for`, `iter`, …).
    Ident,
    /// Lifetime marker such as `'a` (the leading `'` is included).
    Lifetime,
    /// String literal of any flavour (plain, raw, byte, byte-raw).
    Str,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// Numeric literal (integers and floats, lexed conservatively).
    Num,
    /// Any single punctuation byte (`.`, `:`, `(`, `!`, …).
    Punct,
}

/// One lexed token with its source position (1-based line and column).
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// A comment captured during lexing, used for suppression lookup.
#[derive(Debug, Clone)]
pub struct Comment {
    /// Comment text without the `//` / `/*` delimiters, trimmed.
    pub text: String,
    /// Line the comment *starts* on (1-based).
    pub line: u32,
    /// Line the comment ends on (same as `line` for `//` comments).
    pub end_line: u32,
}

/// Lexer output: the token stream plus the captured comments.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
}

impl<'a> Cursor<'a> {
    fn new(src: &'a str) -> Self {
        Cursor {
            bytes: src.as_bytes(),
            pos: 0,
            line: 1,
            col: 1,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn peek_at(&self, off: usize) -> Option<u8> {
        self.bytes.get(self.pos + off).copied()
    }

    /// Advance one byte, tracking line/column.  Multi-byte UTF-8
    /// continuation bytes do not bump the column, so columns count
    /// characters, not bytes.
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.col = 1;
        } else if b & 0xc0 != 0x80 {
            self.col += 1;
        }
        Some(b)
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_' || b >= 0x80
}

fn is_ident_continue(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

/// Lex `src` into tokens and comments.  Never panics: malformed input
/// (unterminated strings, stray bytes) degrades to best-effort tokens
/// rather than an error, because the linter must not crash on the code
/// it is trying to check.
pub fn lex(src: &str) -> Lexed {
    let mut cur = Cursor::new(src);
    let mut out = Lexed::default();

    while let Some(b) = cur.peek() {
        let (line, col) = (cur.line, cur.col);
        match b {
            b' ' | b'\t' | b'\r' | b'\n' => {
                cur.bump();
            }
            b'/' if cur.peek_at(1) == Some(b'/') => {
                let start = cur.pos + 2;
                while let Some(c) = cur.peek() {
                    if c == b'\n' {
                        break;
                    }
                    cur.bump();
                }
                let text = std::str::from_utf8(&cur.bytes[start..cur.pos])
                    .unwrap_or("")
                    .trim()
                    .to_string();
                out.comments.push(Comment {
                    text,
                    line,
                    end_line: line,
                });
            }
            b'/' if cur.peek_at(1) == Some(b'*') => {
                cur.bump();
                cur.bump();
                let start = cur.pos;
                let mut depth = 1usize;
                let mut end = cur.pos;
                while depth > 0 {
                    match (cur.peek(), cur.peek_at(1)) {
                        (Some(b'/'), Some(b'*')) => {
                            depth += 1;
                            cur.bump();
                            cur.bump();
                        }
                        (Some(b'*'), Some(b'/')) => {
                            depth -= 1;
                            end = cur.pos;
                            cur.bump();
                            cur.bump();
                        }
                        (Some(_), _) => {
                            cur.bump();
                        }
                        (None, _) => break,
                    }
                }
                let end = end.max(start);
                let text = std::str::from_utf8(&cur.bytes[start..end])
                    .unwrap_or("")
                    .trim()
                    .to_string();
                out.comments.push(Comment {
                    text,
                    line,
                    end_line: cur.line,
                });
            }
            b'r' | b'b' if starts_raw_or_byte_literal(&cur) => {
                let text = lex_raw_or_byte(&mut cur);
                out.tokens.push(Token {
                    kind: TokenKind::Str,
                    text,
                    line,
                    col,
                });
            }
            _ if is_ident_start(b) => {
                let start = cur.pos;
                while let Some(c) = cur.peek() {
                    if is_ident_continue(c) {
                        cur.bump();
                    } else {
                        break;
                    }
                }
                // b'x' byte-char literal: `b` directly followed by `'`.
                let text = String::from_utf8_lossy(&cur.bytes[start..cur.pos]).into_owned();
                if text == "b" && cur.peek() == Some(b'\'') {
                    let ch = lex_char_body(&mut cur);
                    out.tokens.push(Token {
                        kind: TokenKind::Char,
                        text: format!("b{ch}"),
                        line,
                        col,
                    });
                } else {
                    out.tokens.push(Token {
                        kind: TokenKind::Ident,
                        text,
                        line,
                        col,
                    });
                }
            }
            b'"' => {
                let text = lex_plain_string(&mut cur);
                out.tokens.push(Token {
                    kind: TokenKind::Str,
                    text,
                    line,
                    col,
                });
            }
            b'\'' => {
                // Disambiguate char literal from lifetime.  After the
                // quote: a backslash always means a char escape; an
                // ident char followed by a closing quote is a char
                // (`'x'`); otherwise it is a lifetime (`'a`, `'static`).
                let kind = classify_quote(&cur);
                match kind {
                    QuoteKind::Char => {
                        let text = lex_char_body(&mut cur);
                        out.tokens.push(Token {
                            kind: TokenKind::Char,
                            text,
                            line,
                            col,
                        });
                    }
                    QuoteKind::Lifetime => {
                        let start = cur.pos;
                        cur.bump(); // '
                        while let Some(c) = cur.peek() {
                            if is_ident_continue(c) {
                                cur.bump();
                            } else {
                                break;
                            }
                        }
                        let text =
                            String::from_utf8_lossy(&cur.bytes[start..cur.pos]).into_owned();
                        out.tokens.push(Token {
                            kind: TokenKind::Lifetime,
                            text,
                            line,
                            col,
                        });
                    }
                }
            }
            _ if b.is_ascii_digit() => {
                let start = cur.pos;
                cur.bump();
                while let Some(c) = cur.peek() {
                    if c.is_ascii_alphanumeric() || c == b'_' {
                        cur.bump();
                    } else if c == b'.' && cur.peek_at(1).is_some_and(|d| d.is_ascii_digit()) {
                        // `1.5` continues the number; `0..n` does not.
                        cur.bump();
                    } else {
                        break;
                    }
                }
                let text = String::from_utf8_lossy(&cur.bytes[start..cur.pos]).into_owned();
                out.tokens.push(Token {
                    kind: TokenKind::Num,
                    text,
                    line,
                    col,
                });
            }
            _ => {
                cur.bump();
                out.tokens.push(Token {
                    kind: TokenKind::Punct,
                    text: (b as char).to_string(),
                    line,
                    col,
                });
            }
        }
    }
    out
}

enum QuoteKind {
    Char,
    Lifetime,
}

/// Look past a `'` and decide char-literal vs lifetime without
/// consuming anything.
fn classify_quote(cur: &Cursor<'_>) -> QuoteKind {
    match cur.peek_at(1) {
        Some(b'\\') => QuoteKind::Char,
        Some(c) if is_ident_start(c) => {
            // `'x'` is a char, `'x` (no closing quote after one ident
            // char run) is a lifetime.  Scan the ident run.
            let mut off = 2;
            while cur.peek_at(off).is_some_and(is_ident_continue) {
                off += 1;
            }
            if cur.peek_at(off) == Some(b'\'') {
                QuoteKind::Char
            } else {
                QuoteKind::Lifetime
            }
        }
        Some(_) => QuoteKind::Char, // '1', ' ' etc.
        None => QuoteKind::Lifetime,
    }
}

/// Consume a char literal starting at `'`.  Returns its full text.
fn lex_char_body(cur: &mut Cursor<'_>) -> String {
    let start = cur.pos;
    cur.bump(); // opening '
    if cur.peek() == Some(b'\\') {
        cur.bump();
        cur.bump(); // escaped byte (enough for \n, \', \\, and the x of \x7f)
        while let Some(c) = cur.peek() {
            if c == b'\'' {
                break;
            }
            cur.bump();
        }
    } else {
        // one char, possibly multi-byte
        cur.bump();
        while cur.peek().is_some_and(|c| c & 0xc0 == 0x80) {
            cur.bump();
        }
    }
    cur.bump(); // closing '
    String::from_utf8_lossy(&cur.bytes[start..cur.pos]).into_owned()
}

/// True if the cursor sits on `r"`, `r#`-string, `r#ident`, `b"`,
/// `br"`, or `br#` — anything needing raw/byte-literal handling.
/// (`r#ident` is handled here too: we return false and let the ident
/// path deal with it only if it is *not* followed by `"` or more `#`s
/// that lead to a quote.)
fn starts_raw_or_byte_literal(cur: &Cursor<'_>) -> bool {
    let b0 = cur.peek();
    let mut off = 1;
    if b0 == Some(b'b') && cur.peek_at(1) == Some(b'r') {
        off = 2;
    } else if b0 == Some(b'b') {
        // b"…" byte string; b'…' handled by the ident path.
        return cur.peek_at(1) == Some(b'"');
    }
    // here: r… or br…
    match cur.peek_at(off) {
        Some(b'"') => true,
        Some(b'#') => {
            // skip hashes; raw string iff they end in a quote
            let mut k = off;
            while cur.peek_at(k) == Some(b'#') {
                k += 1;
            }
            cur.peek_at(k) == Some(b'"')
        }
        _ => false,
    }
}

/// Consume a raw string `r#*"…"#*`, byte string `b"…"`, or byte-raw
/// string `br#*"…"#*`.  Returns the full literal text.
fn lex_plain_string(cur: &mut Cursor<'_>) -> String {
    let start = cur.pos;
    cur.bump(); // opening "
    while let Some(c) = cur.peek() {
        match c {
            b'\\' => {
                cur.bump();
                cur.bump();
            }
            b'"' => {
                cur.bump();
                break;
            }
            _ => {
                cur.bump();
            }
        }
    }
    String::from_utf8_lossy(&cur.bytes[start..cur.pos]).into_owned()
}

fn lex_raw_or_byte(cur: &mut Cursor<'_>) -> String {
    let start = cur.pos;
    if cur.peek() == Some(b'b') {
        cur.bump();
    }
    if cur.peek() == Some(b'r') {
        cur.bump();
        let mut hashes = 0usize;
        while cur.peek() == Some(b'#') {
            hashes += 1;
            cur.bump();
        }
        cur.bump(); // opening "
        // scan for `"` followed by `hashes` hashes
        'outer: while let Some(c) = cur.peek() {
            if c == b'"' {
                for k in 1..=hashes {
                    if cur.peek_at(k) != Some(b'#') {
                        cur.bump();
                        continue 'outer;
                    }
                }
                cur.bump(); // closing "
                for _ in 0..hashes {
                    cur.bump();
                }
                break;
            }
            cur.bump();
        }
        String::from_utf8_lossy(&cur.bytes[start..cur.pos]).into_owned()
    } else {
        // plain byte string b"…": escapes behave like a normal string
        let tail = lex_plain_string(cur);
        let mut text = String::from_utf8_lossy(&cur.bytes[start..start + 1]).into_owned();
        text.push_str(&tail);
        text
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn by_kind(l: &Lexed, kind: TokenKind) -> Vec<String> {
        let mut v = Vec::new();
        for t in &l.tokens {
            if t.kind == kind {
                v.push(t.text.clone());
            }
        }
        v
    }

    fn idents(src: &str) -> Vec<String> {
        by_kind(&lex(src), TokenKind::Ident)
    }

    #[test]
    fn idents_and_puncts() {
        use TokenKind::{Ident, Punct};
        let l = lex("m.iter()");
        let kinds: Vec<_> = l.tokens.iter().map(|t| t.kind).collect();
        assert_eq!(kinds, vec![Ident, Punct, Ident, Punct, Punct]);
        assert_eq!(l.tokens[0].line, 1);
        assert_eq!(l.tokens[0].col, 1);
        assert_eq!(l.tokens[2].col, 3);
    }

    #[test]
    fn string_contents_are_not_idents() {
        assert_eq!(idents("let s = \"partial_cmp unwrap\";"), vec!["let", "s"]);
    }

    #[test]
    fn raw_string_with_hashes_and_quotes() {
        let src = r####"let s = r#"inner "quote" and partial_cmp"#; x"####;
        assert_eq!(idents(src), vec!["let", "s", "x"]);
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* outer /* inner */ still comment */ b";
        assert_eq!(idents(src), vec!["a", "b"]);
        let l = lex(src);
        assert_eq!(l.comments.len(), 1);
        assert!(l.comments[0].text.contains("inner"));
    }

    #[test]
    fn lifetime_vs_char() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        assert_eq!(by_kind(&l, TokenKind::Lifetime), vec!["'a", "'a"]);
        assert_eq!(by_kind(&l, TokenKind::Char), vec!["'x'", "'\\n'"]);
    }

    #[test]
    fn static_lifetime_is_lifetime() {
        let l = lex("&'static str");
        assert_eq!(l.tokens[1].kind, TokenKind::Lifetime);
        assert_eq!(l.tokens[1].text, "'static");
    }

    #[test]
    fn byte_string_and_byte_char() {
        let l = lex("let a = b\"bytes\"; let c = b'x';");
        assert_eq!(by_kind(&l, TokenKind::Str), vec!["b\"bytes\""]);
        assert_eq!(by_kind(&l, TokenKind::Char), vec!["b'x'"]);
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let l = lex("for i in 0..n { let x = 1.5f64; }");
        assert_eq!(by_kind(&l, TokenKind::Num), vec!["0", "1.5f64"]);
    }

    #[test]
    fn line_comment_captured_with_line() {
        let l = lex("let a = 1;\n// lint: allow(wall-clock) — bench only\nlet b = 2;");
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].line, 2);
        assert!(l.comments[0].text.starts_with("lint:"));
    }

    #[test]
    fn unterminated_string_does_not_panic() {
        let l = lex("let s = \"never closed");
        assert_eq!(l.tokens.last().unwrap().kind, TokenKind::Str);
    }

    #[test]
    fn raw_ident_is_ident() {
        // `r#match` — the `r` path must fall through to ident lexing.
        assert_eq!(idents("let r#match = 1;"), vec!["let", "r", "match"]);
    }
}
