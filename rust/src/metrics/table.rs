//! Aligned text/markdown table printer for bench output — the benches print
//! the same rows/series the paper's figures report.

/// Column-aligned table with a title, printed to stdout or rendered to
/// markdown for EXPERIMENTS.md.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                w[i] = w[i].max(cell.len());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn render_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    pub fn print(&self) {
        // lint: allow(stray-print) — Table::print is the benches' and
        // CLI's shared stdout sink; the table itself renders to String.
        println!("{}", self.render());
    }
}

/// Shorthand cell formatters used by the benches.
pub trait Cell {
    fn cell(&self) -> String;
}

impl Cell for f64 {
    fn cell(&self) -> String {
        if self.is_nan() {
            "-".into()
        } else if self.abs() >= 1000.0 {
            format!("{self:.0}")
        } else {
            format!("{self:.2}")
        }
    }
}

impl Cell for u64 {
    fn cell(&self) -> String {
        format!("{self}")
    }
}

impl Cell for usize {
    fn cell(&self) -> String {
        format!("{self}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["nodes", "power"]);
        t.row(vec!["1".into(), "250.0".into()]);
        t.row(vec!["96".into(), "15000.0".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.lines().count() == 5);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("m", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn cell_formats() {
        assert_eq!(1234.6f64.cell(), "1235");
        assert_eq!(12.345f64.cell(), "12.35");
        assert_eq!(f64::NAN.cell(), "-");
        assert_eq!(42u64.cell(), "42");
    }
}
