//! Metrics substrate: counters, per-iteration timelines, per-request and
//! staleness logs, summary stats and CSV/markdown table output — the
//! instrumentation behind Figs 4/5/8 and the serving/cosim frontiers.

mod histogram;
mod series;
mod staleness;
mod stats;
mod table;

pub use histogram::Histogram;
pub use series::{IterationRecord, RejectionRecord, RequestLog, RequestRecord, Timeline};
pub use staleness::{StalenessLog, StalenessRecord};
pub use stats::Summary;
pub use table::{Cell, Table};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_to_table() {
        let mut tl = Timeline::new();
        for i in 0..3 {
            tl.push(IterationRecord {
                iteration: i,
                t_virtual_ms: (i as f64) * 4000.0,
                vectors: 100 * (i + 1) as u64,
                workers: 2,
                mean_latency_ms: 35.0,
                max_latency_ms: 50.0,
                loss: Some(2.3 - i as f64 * 0.1),
                test_error: None,
                bytes_up: 1,
                bytes_down: 2,
            });
        }
        assert_eq!(tl.len(), 3);
        let csv = tl.to_csv();
        assert!(csv.lines().count() == 4); // header + 3 rows
        assert!(csv.starts_with("iteration,"));
    }
}
