//! Per-iteration timeline of the master event loop — the raw series behind
//! the power/latency (Fig 4), convergence (Fig 5) and tracking (Fig 8)
//! plots — plus the serving subsystem's per-request log ([`RequestLog`]),
//! the series behind throughput/latency-percentile tables.

use crate::serve::{ModelVersion, ProjectId};

use super::stats::Summary;

/// One master-loop iteration's measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct IterationRecord {
    pub iteration: u64,
    /// Virtual wall-clock at the end of the iteration (ms).
    pub t_virtual_ms: f64,
    /// Data vectors processed by all workers this iteration.
    pub vectors: u64,
    /// Trainer workers that contributed to the reduce step.
    pub workers: u32,
    /// Mean / max slave↔master latency observed this iteration (ms).
    pub mean_latency_ms: f64,
    pub max_latency_ms: f64,
    /// Weighted-average training loss per example (if any work arrived).
    pub loss: Option<f64>,
    /// Test error from tracker workers (if a tracker ran this iteration).
    pub test_error: Option<f64>,
    /// Master ingress/egress bytes this iteration (gradients / broadcast).
    pub bytes_up: u64,
    pub bytes_down: u64,
}

/// Append-only series of iteration records with CSV export.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    records: Vec<IterationRecord>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: IterationRecord) {
        self.records.push(r);
    }

    /// Rebuild a timeline from checkpointed records (restore path).
    pub fn from_records(records: Vec<IterationRecord>) -> Self {
        Self { records }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[IterationRecord] {
        &self.records
    }

    pub fn last(&self) -> Option<&IterationRecord> {
        self.records.last()
    }

    /// Attach a tracker-worker test error to the most recent record (the
    /// evaluation runs right after that iteration's broadcast).
    pub fn set_last_test_error(&mut self, error: f64) {
        if let Some(last) = self.records.last_mut() {
            last.test_error = Some(error);
        }
    }

    /// Aggregate power over the whole run: total vectors / total seconds —
    /// Fig 4's y-axis.
    pub fn power_vectors_per_sec(&self) -> f64 {
        let vectors: u64 = self.records.iter().map(|r| r.vectors).sum();
        match (self.records.first(), self.records.last()) {
            (Some(first), Some(last)) if last.t_virtual_ms > 0.0 => {
                let dt_ms = last.t_virtual_ms
                    - (first.t_virtual_ms - first.iter_duration_hint());
                if dt_ms <= 0.0 {
                    return 0.0;
                }
                vectors as f64 / (dt_ms / 1000.0)
            }
            _ => 0.0,
        }
    }

    /// Mean of per-iteration mean latencies — Fig 4's second axis.
    /// 0.0 on an empty timeline (a run that never completed an iteration
    /// has no latency to report; NaN would poison downstream summaries).
    pub fn mean_latency_ms(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.mean_latency_ms).sum::<f64>()
            / self.records.len() as f64
    }

    /// Last recorded test error at or before `iteration` (Fig 5 readout).
    pub fn test_error_at(&self, iteration: u64) -> Option<f64> {
        self.records
            .iter()
            .take_while(|r| r.iteration <= iteration)
            .filter_map(|r| r.test_error)
            .last()
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "iteration,t_virtual_ms,vectors,workers,mean_latency_ms,max_latency_ms,loss,test_error,bytes_up,bytes_down\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.3},{},{},{:.3},{:.3},{},{},{},{}\n",
                r.iteration,
                r.t_virtual_ms,
                r.vectors,
                r.workers,
                r.mean_latency_ms,
                r.max_latency_ms,
                r.loss.map_or(String::new(), |v| format!("{v:.6}")),
                r.test_error.map_or(String::new(), |v| format!("{v:.6}")),
                r.bytes_up,
                r.bytes_down,
            ));
        }
        out
    }
}

/// One served prediction request — the serving path's analogue of
/// [`IterationRecord`] (training iterates; serving answers requests).
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub client: u32,
    /// Client send / client receive timestamps (virtual ms).
    pub sent_ms: f64,
    pub done_ms: f64,
    /// End-to-end latency the client experienced (ms).
    pub latency_ms: f64,
    /// Serving shard that answered (0 on a single-endpoint run).
    pub shard: u32,
    /// Typed model version (project + snapshot) that answered — under a
    /// live-training hot swap the log shows exactly which project's
    /// parameters, at which version, served each request.
    pub version: ModelVersion,
    /// Requests in the executed batch (0 for cache hits and coalesced
    /// waiters — neither occupies an executed batch slot).
    pub batch_size: u32,
    pub cache_hit: bool,
    /// Answered by piggybacking on a duplicate's in-flight computation.
    pub coalesced: bool,
    /// Argmax class served — lets log-level checks verify that batching,
    /// caching, routing and coalescing never change the answer.
    pub class: u32,
}

/// One shed request: the client got a fast error instead of a prediction.
/// Recording these makes `offered − completed − rejected` reconcilable
/// per client (shedding used to be invisible to the log).
#[derive(Debug, Clone, PartialEq)]
pub struct RejectionRecord {
    pub id: u64,
    pub client: u32,
    /// The hosted project whose request was shed.
    pub project: ProjectId,
    /// Client send / server arrival timestamps (virtual ms).
    pub sent_ms: f64,
    pub arrival_ms: f64,
    /// Shard whose admission queue shed it.
    pub shard: u32,
}

/// Append-only per-request series with percentile summaries + CSV export.
/// Completions and rejections are separate streams: `len()` counts
/// completions only (a shed request never produced an answer).
#[derive(Debug, Clone, Default)]
pub struct RequestLog {
    records: Vec<RequestRecord>,
    rejections: Vec<RejectionRecord>,
}

impl RequestLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: RequestRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Record a shed request (admission-queue overflow).
    pub fn push_rejection(&mut self, r: RejectionRecord) {
        self.rejections.push(r);
    }

    pub fn rejections(&self) -> &[RejectionRecord] {
        &self.rejections
    }

    /// Shed count per client id — the attribution the bench sweeps roll
    /// up into per-link-profile shed rates.
    pub fn rejections_by_client(&self) -> std::collections::BTreeMap<u32, u64> {
        let mut by_client = std::collections::BTreeMap::new();
        for r in &self.rejections {
            *by_client.entry(r.client).or_insert(0) += 1;
        }
        by_client
    }

    /// End-to-end latency distribution (feed to `quantile`/`p95`).
    pub fn latency_summary(&self) -> Summary {
        Summary::from(self.records.iter().map(|r| r.latency_ms).collect())
    }

    /// This log restricted to one project's completions and rejections —
    /// per-project percentiles and reconciliation on a multi-tenant tier.
    pub fn for_project(&self, project: ProjectId) -> RequestLog {
        RequestLog {
            records: self
                .records
                .iter()
                .filter(|r| r.version.project == project)
                .cloned()
                .collect(),
            rejections: self
                .rejections
                .iter()
                .filter(|r| r.project == project)
                .cloned()
                .collect(),
        }
    }

    /// Completed requests per virtual second over [0, horizon].
    pub fn throughput_rps(&self, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            return 0.0;
        }
        self.records.len() as f64 / horizon_s
    }

    /// Latest completion time (ms); 0 when empty.
    pub fn span_ms(&self) -> f64 {
        self.records.iter().map(|r| r.done_ms).fold(0.0, f64::max)
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "id,client,sent_ms,done_ms,latency_ms,shard,project,snapshot,batch_size,cache_hit,coalesced,class\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{:.3},{:.3},{:.3},{},{},{},{},{},{},{}\n",
                r.id,
                r.client,
                r.sent_ms,
                r.done_ms,
                r.latency_ms,
                r.shard,
                r.version.project.as_u32(),
                r.version.version,
                r.batch_size,
                r.cache_hit as u8,
                r.coalesced as u8,
                r.class,
            ));
        }
        out
    }

    /// The shed stream as CSV (one line per rejected request + header).
    pub fn rejections_to_csv(&self) -> String {
        let mut out = String::from("id,client,project,sent_ms,arrival_ms,shard\n");
        for r in &self.rejections {
            out.push_str(&format!(
                "{},{},{},{:.3},{:.3},{}\n",
                r.id,
                r.client,
                r.project.as_u32(),
                r.sent_ms,
                r.arrival_ms,
                r.shard,
            ));
        }
        out
    }
}

impl IterationRecord {
    /// Rough duration of one iteration for power normalization: the spacing
    /// to use when only a single record exists.
    fn iter_duration_hint(&self) -> f64 {
        if self.iteration == 0 {
            self.t_virtual_ms
        } else {
            self.t_virtual_ms / (self.iteration + 1) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: u64, t: f64, vectors: u64) -> IterationRecord {
        IterationRecord {
            iteration: i,
            t_virtual_ms: t,
            vectors,
            workers: 1,
            mean_latency_ms: 10.0,
            max_latency_ms: 20.0,
            loss: None,
            test_error: if i == 1 { Some(0.5) } else { None },
            bytes_up: 0,
            bytes_down: 0,
        }
    }

    #[test]
    fn power_is_vectors_per_second() {
        let mut tl = Timeline::new();
        tl.push(rec(0, 4000.0, 400));
        tl.push(rec(1, 8000.0, 400));
        // 800 vectors over 8 seconds
        assert!((tl.power_vectors_per_sec() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn empty_and_degenerate_timelines_report_zero_not_nan() {
        let tl = Timeline::new();
        assert_eq!(tl.mean_latency_ms(), 0.0);
        assert_eq!(tl.power_vectors_per_sec(), 0.0);
        // A single record must still produce finite numbers.
        let mut one = Timeline::new();
        one.push(rec(0, 4000.0, 400));
        assert!(one.mean_latency_ms().is_finite());
        assert_eq!(one.mean_latency_ms(), 10.0);
        assert!(one.power_vectors_per_sec().is_finite());
        assert!((one.power_vectors_per_sec() - 100.0).abs() < 1e-9);
        // A record pinned at t=0 (degenerate span) is zero, not inf/NaN.
        let mut zero_t = Timeline::new();
        zero_t.push(rec(0, 0.0, 400));
        assert_eq!(zero_t.power_vectors_per_sec(), 0.0);
    }

    #[test]
    fn test_error_at_iteration() {
        let mut tl = Timeline::new();
        tl.push(rec(0, 4000.0, 1));
        tl.push(rec(1, 8000.0, 1));
        tl.push(rec(2, 12000.0, 1));
        assert_eq!(tl.test_error_at(0), None);
        assert_eq!(tl.test_error_at(1), Some(0.5));
        assert_eq!(tl.test_error_at(2), Some(0.5));
    }

    #[test]
    fn csv_has_all_rows() {
        let mut tl = Timeline::new();
        tl.push(rec(0, 1.0, 1));
        let csv = tl.to_csv();
        assert!(csv.contains("0,1.000,1,1"));
    }

    fn req(id: u64, sent: f64, done: f64, hit: bool) -> RequestRecord {
        req_p(id, sent, done, hit, 0)
    }

    fn req_p(id: u64, sent: f64, done: f64, hit: bool, project: u32) -> RequestRecord {
        RequestRecord {
            id,
            client: 1,
            sent_ms: sent,
            done_ms: done,
            latency_ms: done - sent,
            shard: 2,
            version: ModelVersion {
                project: ProjectId::new(project),
                version: 5,
            },
            batch_size: if hit { 0 } else { 8 },
            cache_hit: hit,
            coalesced: false,
            class: 3,
        }
    }

    #[test]
    fn request_log_percentiles_and_throughput() {
        let mut log = RequestLog::new();
        for i in 0..10 {
            log.push(req(i, i as f64, i as f64 + 10.0 + i as f64, i % 2 == 0));
        }
        assert_eq!(log.len(), 10);
        let lat = log.latency_summary();
        assert_eq!(lat.min(), 10.0);
        assert_eq!(lat.max(), 19.0);
        // 10 requests completing within 28 ms of virtual time.
        assert!((log.throughput_rps(2.0) - 5.0).abs() < 1e-12);
        assert_eq!(log.throughput_rps(0.0), 0.0);
        assert_eq!(log.span_ms(), 28.0);
    }

    #[test]
    fn request_log_csv_shape() {
        let mut log = RequestLog::new();
        log.push(req(7, 1.0, 3.5, true));
        let csv = log.to_csv();
        assert!(csv.starts_with("id,client,"));
        assert!(csv.contains("7,1,1.000,3.500,2.500,2,0,5,0,1,0,3"));
    }

    #[test]
    fn rejections_are_recorded_and_attributed() {
        let mut log = RequestLog::new();
        log.push(req(1, 0.0, 5.0, false));
        log.push_rejection(RejectionRecord {
            id: 2,
            client: 4,
            project: ProjectId::new(0),
            sent_ms: 1.0,
            arrival_ms: 2.5,
            shard: 1,
        });
        log.push_rejection(RejectionRecord {
            id: 3,
            client: 4,
            project: ProjectId::new(1),
            sent_ms: 1.2,
            arrival_ms: 2.7,
            shard: 0,
        });
        // Completions and rejections are separate streams.
        assert_eq!(log.len(), 1);
        assert_eq!(log.rejections().len(), 2);
        assert_eq!(log.rejections_by_client().get(&4), Some(&2));
        assert_eq!(log.rejections_by_client().get(&1), None);
        let csv = log.rejections_to_csv();
        assert!(csv.starts_with("id,client,project,sent_ms,arrival_ms,shard\n"));
        assert!(csv.contains("2,4,0,1.000,2.500,1"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn for_project_isolates_streams() {
        // Interleave two projects' completions and rejections: the
        // per-project view must carry exactly that project's records, and
        // its summaries must match a log built from those records alone.
        let mut log = RequestLog::new();
        let mut only_b = RequestLog::new();
        for i in 0..8 {
            let p = (i % 2) as u32;
            let r = req_p(i, i as f64, i as f64 + 5.0 + p as f64, false, p);
            if p == 1 {
                only_b.push(r.clone());
            }
            log.push(r);
        }
        log.push_rejection(RejectionRecord {
            id: 99,
            client: 1,
            project: ProjectId::new(1),
            sent_ms: 0.0,
            arrival_ms: 1.0,
            shard: 0,
        });
        let a = log.for_project(ProjectId::new(0));
        let b = log.for_project(ProjectId::new(1));
        assert_eq!(a.len() + b.len(), log.len());
        assert_eq!(a.rejections().len(), 0);
        assert_eq!(b.rejections().len(), 1);
        assert_eq!(b.to_csv(), only_b.to_csv());
        assert_eq!(a.latency_summary().max(), 5.0);
        assert_eq!(b.latency_summary().min(), 6.0);
    }
}
