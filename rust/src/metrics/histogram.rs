//! Log-bucketed latency histogram: constant memory per distribution,
//! mergeable across shards, ~1% relative quantile error.
//!
//! `Summary` needs every sample retained and sorted — fine for a few
//! thousand iteration timings, wrong for per-request serving latencies
//! where a 10⁵-request run would hold (and clone) megabyte vectors just
//! to print three percentiles.  This histogram buckets samples
//! geometrically (64 sub-buckets per octave, so bucket edges are ~1.09%
//! apart) over [1 µs-ish, 10⁴ s] of virtual milliseconds: ~2 k fixed
//! `u64` counters (≈17 KiB) regardless of sample count, exact min/max
//! tracking, and element-wise addition as the merge operator — two
//! shards' histograms combine into exactly the histogram of the combined
//! stream.
//!
//! Quantiles interpolate linearly *within* the landing bucket and clamp
//! to the exact observed [min, max], so degenerate cases (n = 1, all
//! samples equal) are exact and everything else is within half a bucket
//! width (&lt;1% relative) — tight enough that the serving tests asserting
//! strict p50/p99 orderings between policies pass unchanged.

/// Smallest resolvable value (ms).  Everything at or below lands in
/// bucket 0.
const MIN_MS: f64 = 1e-3;
/// Sub-buckets per octave (power of two): bucket width ≈ 2^(1/64) − 1 ≈
/// 1.09% of the value.
const SUB: f64 = 64.0;
/// Bucket count covering [MIN_MS, 1e7 ms]: 1 underflow bucket +
/// ⌈log2(1e10) · 64⌉ data buckets, with the last bucket absorbing
/// overflow.
const BUCKETS: usize = 2 + (34 * 64);

/// Log-bucketed histogram over non-negative f64 samples (latencies, ms).
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

fn bucket_index(v: f64) -> usize {
    if v <= MIN_MS {
        return 0;
    }
    let i = 1 + ((v / MIN_MS).log2() * SUB).floor() as usize;
    i.min(BUCKETS - 1)
}

/// Lower edge of bucket `i` (upper edge is `bucket_lo(i + 1)`).
fn bucket_lo(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        MIN_MS * ((i - 1) as f64 / SUB).exp2()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample.  Non-finite values are ignored (mirrors
    /// `Summary::from`'s retain).
    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let v = v.max(0.0);
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Fold another histogram in.  Layouts are identical by
    /// construction, so this is exact: merge-then-quantile equals
    /// quantile over the concatenated streams.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sample count as usize — API-compatible with `Summary::len`.
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Quantile estimate, q in [0, 1]; NaN when empty.  Uses the same
    /// rank convention as `Summary::quantile` (pos = q·(n−1)) with linear
    /// interpolation across the landing bucket, clamped to the exact
    /// observed range.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if q <= 0.0 {
            return self.min;
        }
        if q >= 1.0 {
            return self.max;
        }
        let pos = q * (self.count - 1) as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 > pos {
                let lo = bucket_lo(i);
                let hi = bucket_lo(i + 1);
                // Treat the c samples as spread uniformly across the
                // bucket at positions (k + ½)/c for k = 0..c.
                let k_frac = pos - cum as f64;
                let v = lo + (hi - lo) * ((k_frac + 0.5) / c as f64);
                return v.clamp(self.min, self.max);
            }
            cum += c;
        }
        self.max
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Summary;

    #[test]
    fn empty_is_nan_everywhere() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert!(h.mean().is_nan());
        assert!(h.min().is_nan());
        assert!(h.max().is_nan());
        assert!(h.median().is_nan());
        assert!(h.p999().is_nan());
    }

    #[test]
    fn single_sample_is_exact() {
        let mut h = Histogram::new();
        h.observe(37.25);
        for q in [0.0, 0.5, 0.95, 0.99, 0.999, 1.0] {
            assert_eq!(h.quantile(q), 37.25, "q={q}");
        }
        assert_eq!(h.mean(), 37.25);
        assert_eq!(h.min(), 37.25);
        assert_eq!(h.max(), 37.25);
    }

    #[test]
    fn quantiles_track_summary_within_a_bucket_width() {
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let s = Summary::from(xs.clone());
        let mut h = Histogram::new();
        for x in &xs {
            h.observe(*x);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - s.mean()).abs() < 1e-9);
        for q in [0.05, 0.25, 0.5, 0.9, 0.95, 0.99, 0.999] {
            let exact = s.quantile(q);
            let approx = h.quantile(q);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.015, "q={q}: exact={exact} approx={approx} rel={rel}");
        }
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(h.quantile(1.0), 1000.0);
    }

    #[test]
    fn merge_is_exact() {
        let mut all = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 0..500 {
            let v = 0.5 + (i as f64) * 1.7;
            all.observe(v);
            if i % 2 == 0 {
                a.observe(v);
            } else {
                b.observe(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        for q in [0.1, 0.5, 0.95, 0.99] {
            assert_eq!(a.quantile(q), all.quantile(q), "q={q}");
        }
    }

    #[test]
    fn non_finite_ignored_and_out_of_range_clamped() {
        let mut h = Histogram::new();
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert!(h.is_empty());
        h.observe(1e-9); // below resolution → underflow bucket
        h.observe(1e12); // beyond range → top bucket
        assert_eq!(h.count(), 2);
        assert_eq!(h.min(), 1e-9);
        assert_eq!(h.max(), 1e12);
        let m = h.median();
        assert!(m >= h.min() && m <= h.max());
    }

    #[test]
    fn memory_is_constant_in_sample_count() {
        let mut h = Histogram::new();
        let before = h.counts.len();
        for i in 0..100_000 {
            h.observe((i % 977) as f64 + 0.1);
        }
        assert_eq!(h.counts.len(), before, "no growth with samples");
        assert_eq!(h.count(), 100_000);
    }
}
