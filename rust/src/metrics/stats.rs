//! Summary statistics over f64 samples (latency distributions, timings).

/// Order statistics + moments for a sample set.
#[derive(Debug, Clone)]
pub struct Summary {
    sorted: Vec<f64>,
    sum: f64,
}

impl Summary {
    pub fn from(mut xs: Vec<f64>) -> Self {
        xs.retain(|x| x.is_finite());
        // total_cmp, not partial_cmp().unwrap(): the retain above keeps
        // NaN out today, but ordering must not be a panic away from any
        // future caller handing us raw measurements.
        xs.sort_by(f64::total_cmp);
        let sum = xs.iter().sum();
        Self { sorted: xs, sum }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.sorted.is_empty() {
            return f64::NAN;
        }
        self.sum / self.sorted.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.sorted.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(f64::NAN)
    }

    /// Linear-interpolated quantile, q in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.sorted.is_empty() {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        let pos = q * (self.sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.sorted[lo]
        } else {
            let frac = pos - lo as f64;
            self.sorted[lo] * (1.0 - frac) + self.sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }

    pub fn std(&self) -> f64 {
        if self.sorted.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .sorted
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (self.sorted.len() - 1) as f64;
        var.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let s = Summary::from(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.median(), 2.5);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn quantiles_interpolate() {
        let s = Summary::from(vec![0.0, 10.0]);
        assert_eq!(s.quantile(0.25), 2.5);
        assert_eq!(s.quantile(1.0), 10.0);
        assert_eq!(s.quantile(0.0), 0.0);
    }

    #[test]
    fn empty_and_nan_inputs() {
        let s = Summary::from(vec![]);
        assert!(s.mean().is_nan());
        let s = Summary::from(vec![f64::NAN, 1.0, f64::INFINITY]);
        assert_eq!(s.len(), 1);
        assert_eq!(s.mean(), 1.0);
    }

    #[test]
    fn unsorted_input_handled() {
        let s = Summary::from(vec![5.0, 1.0, 3.0]);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.median(), 3.0);
    }

    #[test]
    fn tail_quantiles_interpolate_at_small_n() {
        // p99 of ten samples must interpolate between the 9th and 10th
        // order statistics, not snap to either endpoint.
        let s = Summary::from((1..=10).map(|i| i as f64).collect());
        assert!((s.p99() - 9.91).abs() < 1e-9);
        assert!((s.p999() - 9.991).abs() < 1e-9);
        assert!((s.quantile(0.95) - 9.55).abs() < 1e-9);
    }

    #[test]
    fn single_sample_is_every_quantile() {
        let s = Summary::from(vec![7.0]);
        for q in [0.0, 0.5, 0.95, 0.99, 0.999, 1.0] {
            assert_eq!(s.quantile(q), 7.0);
        }
    }

    #[test]
    fn quantile_clamps_out_of_range() {
        let s = Summary::from(vec![1.0, 2.0, 3.0]);
        assert_eq!(s.quantile(-0.5), 1.0);
        assert_eq!(s.quantile(1.5), 3.0);
    }

    #[test]
    fn empty_tail_quantiles_are_nan() {
        let s = Summary::from(vec![]);
        assert!(s.p99().is_nan());
        assert!(s.p999().is_nan());
    }
}
