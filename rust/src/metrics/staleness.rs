//! Staleness instrumentation for the serve × train co-simulation.
//!
//! When live masters publish snapshots mid-traffic, every served answer
//! is computed against parameters some number of iterations (and virtual
//! milliseconds) behind its own project's master.  The [`StalenessLog`]
//! correlates each served request with the typed [`ModelVersion`] that
//! answered it, the age of that snapshot relative to **its project's**
//! master, and — when the probe is enabled — the prediction delta
//! against the live master parameters: the L1 distance between the
//! served probability row and the row the freshest parameters would have
//! produced, plus whether the argmax class flipped.  This is the raw
//! series behind the `fig_cosim` staleness-vs-latency frontier and the
//! `fig_multitenant` per-project tables.
//!
//! **Isolation.**  Projects interleave in one log but never mix in the
//! statistics: [`StalenessLog::for_project`] restricts the series, and
//! the per-project percentiles of an interleaved log equal those of a
//! log holding only that project's trace (pinned by tests).

use std::collections::BTreeMap;

use crate::serve::{ModelVersion, ProjectId};

use super::stats::Summary;

/// One served request's staleness measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct StalenessRecord {
    /// Request id (joins against [`super::RequestRecord`]).
    pub id: u64,
    pub client: u32,
    /// Client receive time (virtual ms).
    pub done_ms: f64,
    /// Model version (project + snapshot) that answered.
    pub version: ModelVersion,
    /// Training iteration the snapshot captured.
    pub snapshot_iteration: u64,
    /// The owning project's master iteration live while the request was
    /// served.
    pub master_iteration: u64,
    /// Virtual ms between the snapshot's publication and the response.
    pub age_ms: f64,
    /// L1 distance between served and fresh probability rows (`None`
    /// when the probe was disabled).
    pub delta: Option<f64>,
    /// Argmax class under the live master parameters (`None` when the
    /// probe was disabled).
    pub fresh_class: Option<u32>,
    /// Argmax class actually served.
    pub class: u32,
}

impl StalenessRecord {
    /// Snapshot age in training iterations at serve time (relative to the
    /// owning project's master).
    pub fn age_iters(&self) -> u64 {
        self.master_iteration.saturating_sub(self.snapshot_iteration)
    }

    /// Did staleness flip the served argmax class?  `None` when the
    /// probe was disabled.
    pub fn class_changed(&self) -> Option<bool> {
        self.fresh_class.map(|fresh| fresh != self.class)
    }
}

/// Append-only per-request staleness series with summaries + CSV export.
#[derive(Debug, Clone, Default)]
pub struct StalenessLog {
    records: Vec<StalenessRecord>,
}

impl StalenessLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: StalenessRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[StalenessRecord] {
        &self.records
    }

    /// This log restricted to one project's answers (record order
    /// preserved) — the isolation view behind per-project percentiles.
    pub fn for_project(&self, project: ProjectId) -> StalenessLog {
        StalenessLog {
            records: self
                .records
                .iter()
                .filter(|r| r.version.project == project)
                .cloned()
                .collect(),
        }
    }

    /// Snapshot-age distribution in training iterations.
    pub fn age_iters_summary(&self) -> Summary {
        Summary::from(self.records.iter().map(|r| r.age_iters() as f64).collect())
    }

    /// Snapshot-age distribution in virtual milliseconds.
    pub fn age_ms_summary(&self) -> Summary {
        Summary::from(self.records.iter().map(|r| r.age_ms).collect())
    }

    /// Prediction-delta distribution over probed records (empty when the
    /// probe was disabled).
    pub fn delta_summary(&self) -> Summary {
        Summary::from(self.records.iter().filter_map(|r| r.delta).collect())
    }

    /// Fraction of probed answers whose argmax class the live parameters
    /// would have flipped (0 when nothing was probed).
    pub fn stale_class_rate(&self) -> f64 {
        let probed: Vec<bool> = self
            .records
            .iter()
            .filter_map(StalenessRecord::class_changed)
            .collect();
        if probed.is_empty() {
            return 0.0;
        }
        probed.iter().filter(|&&flipped| flipped).count() as f64 / probed.len() as f64
    }

    /// Requests answered per model version (which versions of which
    /// projects actually carried traffic — GC should be reclaiming the
    /// zeros).
    pub fn by_version(&self) -> BTreeMap<ModelVersion, u64> {
        let mut by = BTreeMap::new();
        for r in &self.records {
            *by.entry(r.version).or_insert(0) += 1;
        }
        by
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "id,client,done_ms,project,snapshot,snapshot_iteration,master_iteration,age_iters,age_ms,delta,fresh_class,class\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{:.3},{},{},{},{},{},{:.3},{},{},{}\n",
                r.id,
                r.client,
                r.done_ms,
                r.version.project.as_u32(),
                r.version.version,
                r.snapshot_iteration,
                r.master_iteration,
                r.age_iters(),
                r.age_ms,
                r.delta.map_or(String::new(), |d| format!("{d:.6}")),
                r.fresh_class.map_or(String::new(), |c| c.to_string()),
                r.class,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec_p(
        id: u64,
        project: u32,
        snap: u64,
        snap_iter: u64,
        master_iter: u64,
        delta: Option<f64>,
    ) -> StalenessRecord {
        StalenessRecord {
            id,
            client: 0,
            done_ms: id as f64 * 10.0,
            version: ModelVersion {
                project: ProjectId::new(project),
                version: snap,
            },
            snapshot_iteration: snap_iter,
            master_iteration: master_iter,
            age_ms: (master_iter - snap_iter) as f64 * 4_000.0,
            delta,
            fresh_class: delta.map(|d| if d > 0.5 { 1 } else { 0 }),
            class: 0,
        }
    }

    fn rec(id: u64, snap: u64, snap_iter: u64, master_iter: u64, delta: Option<f64>) -> StalenessRecord {
        rec_p(id, 0, snap, snap_iter, master_iter, delta)
    }

    #[test]
    fn ages_and_summaries() {
        let mut log = StalenessLog::new();
        log.push(rec(1, 1, 0, 0, Some(0.0)));
        log.push(rec(2, 1, 0, 2, Some(0.2)));
        log.push(rec(3, 2, 2, 6, Some(0.8)));
        assert_eq!(log.len(), 3);
        assert_eq!(log.records()[2].age_iters(), 4);
        let ages = log.age_iters_summary();
        assert_eq!(ages.min(), 0.0);
        assert_eq!(ages.max(), 4.0);
        assert_eq!(log.age_ms_summary().max(), 16_000.0);
        assert!((log.delta_summary().mean() - (1.0 / 3.0)).abs() < 1e-9);
        // One of three probed answers flipped class.
        assert!((log.stale_class_rate() - (1.0 / 3.0)).abs() < 1e-9);
        let v = |s: u64| ModelVersion {
            project: ProjectId::new(0),
            version: s,
        };
        assert_eq!(log.by_version().get(&v(1)), Some(&2));
        assert_eq!(log.by_version().get(&v(2)), Some(&1));
    }

    #[test]
    fn unprobed_records_have_no_delta() {
        let mut log = StalenessLog::new();
        log.push(rec(1, 1, 0, 3, None));
        assert_eq!(log.records()[0].class_changed(), None);
        assert!(log.delta_summary().is_empty());
        assert_eq!(log.stale_class_rate(), 0.0);
        // CSV leaves the probe columns empty, ages intact.
        let csv = log.to_csv();
        assert!(csv.starts_with("id,client,done_ms,project,snapshot,"));
        assert!(csv.contains("1,0,10.000,0,1,0,3,3,12000.000,,,0"));
    }

    #[test]
    fn csv_has_one_line_per_record() {
        let mut log = StalenessLog::new();
        for i in 0..5 {
            log.push(rec(i, 1, 0, 1, Some(0.1)));
        }
        assert_eq!(log.to_csv().lines().count(), 6);
    }

    #[test]
    fn interleaved_projects_do_not_contaminate_per_project_percentiles() {
        // The isolation satellite: build two projects' traces, interleave
        // them in one log, and require every per-project statistic to
        // match the single-project log holding the same trace.
        let trace_a: Vec<StalenessRecord> = (0..6)
            .map(|i| rec_p(i * 2, 0, 1 + i % 2, 0, i, Some(0.1 * i as f64)))
            .collect();
        let trace_b: Vec<StalenessRecord> = (0..9)
            .map(|i| rec_p(i * 2 + 1, 1, 1, 0, 2 * i + 1, Some(0.9)))
            .collect();
        let mut solo_a = StalenessLog::new();
        let mut solo_b = StalenessLog::new();
        let mut interleaved = StalenessLog::new();
        let (mut ia, mut ib) = (trace_a.iter(), trace_b.iter());
        // Deterministic unfair interleave: 1 of a, then 2 of b, repeat.
        loop {
            let a = ia.next();
            let b1 = ib.next();
            let b2 = ib.next();
            if a.is_none() && b1.is_none() {
                break;
            }
            for r in [a, b1, b2].into_iter().flatten() {
                interleaved.push(r.clone());
            }
        }
        for r in trace_a {
            solo_a.push(r);
        }
        for r in trace_b {
            solo_b.push(r);
        }
        assert_eq!(interleaved.len(), solo_a.len() + solo_b.len());
        let view_a = interleaved.for_project(ProjectId::new(0));
        let view_b = interleaved.for_project(ProjectId::new(1));
        // Byte-identical per-project series…
        assert_eq!(view_a.to_csv(), solo_a.to_csv());
        assert_eq!(view_b.to_csv(), solo_b.to_csv());
        // …and therefore identical percentiles on every axis.
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(
                view_a.age_iters_summary().quantile(q),
                solo_a.age_iters_summary().quantile(q)
            );
            assert_eq!(
                view_b.age_iters_summary().quantile(q),
                solo_b.age_iters_summary().quantile(q)
            );
            assert_eq!(
                view_a.delta_summary().quantile(q),
                solo_a.delta_summary().quantile(q)
            );
        }
        assert_eq!(view_a.stale_class_rate(), solo_a.stale_class_rate());
        assert_eq!(view_b.by_version(), solo_b.by_version());
        // The interleaved aggregate differs from both (the views really
        // restricted something).
        assert_ne!(
            interleaved.age_iters_summary().max(),
            view_a.age_iters_summary().max()
        );
    }
}
