//! Deterministic synthetic image corpora (MNIST / CIFAR-10 stand-ins).
//!
//! Each class is a prototype pattern (a few random strokes/blobs drawn from
//! a class-seeded PRNG); a sample is its prototype under a random ±2 pixel
//! translation, amplitude scaling, and additive noise.  Classes are
//! linearly non-trivial but comfortably learnable by the paper's
//! conv16+pool+FC network — convergence keeps the coverage-driven shape of
//! Fig 5 (more allocated data ⇒ lower test error).

use crate::rng::{Normal, Pcg32};

use super::Sample;

/// Shape + generation parameters of a synthetic corpus.
#[derive(Debug, Clone, Copy)]
pub struct SynthSpec {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: u8,
    /// Base seed; (seed, class, sample index) fully determine a sample.
    pub seed: u64,
}

impl SynthSpec {
    /// 28×28×1, 10 classes — the MNIST stand-in.
    pub fn mnist(seed: u64) -> Self {
        Self {
            height: 28,
            width: 28,
            channels: 1,
            classes: 10,
            seed,
        }
    }

    /// 32×32×3, 10 classes — the CIFAR-10 stand-in.
    pub fn cifar(seed: u64) -> Self {
        Self {
            height: 32,
            width: 32,
            channels: 3,
            classes: 10,
            seed,
        }
    }

    pub fn pixels(&self) -> usize {
        self.height * self.width * self.channels
    }
}

/// Corpus generator: precomputes per-class prototypes, then renders
/// samples on demand.
#[derive(Debug, Clone)]
pub struct Synthesizer {
    spec: SynthSpec,
    prototypes: Vec<Vec<f32>>, // classes × (h*w*c)
}

impl Synthesizer {
    pub fn new(spec: SynthSpec) -> Self {
        let prototypes = (0..spec.classes)
            .map(|c| Self::prototype(&spec, c))
            .collect();
        Self { spec, prototypes }
    }

    pub fn spec(&self) -> &SynthSpec {
        &self.spec
    }

    /// Class prototype: 4 strokes + 2 blobs from a class-seeded PRNG,
    /// channel-tinted for multi-channel specs.
    fn prototype(spec: &SynthSpec, class: u8) -> Vec<f32> {
        let (h, w, ch) = (spec.height, spec.width, spec.channels);
        let mut rng = Pcg32::with_stream(
            spec.seed ^ 0xC1A55,
            0x100 + class as u64,
        );
        let mut canvas = vec![0.0f32; h * w];
        for _ in 0..4 {
            let x0 = 3.0 + rng.gen_f64() * (w as f64 - 6.0);
            let y0 = 3.0 + rng.gen_f64() * (h as f64 - 6.0);
            let ang = rng.gen_f64() * std::f64::consts::TAU;
            let len = 6.0 + rng.gen_f64() * (w as f64 / 2.0);
            let (dx, dy) = (ang.cos(), ang.sin());
            let steps = (len * 2.0) as usize;
            for s in 0..steps {
                let t = s as f64 / 2.0;
                let x = x0 + dx * t;
                let y = y0 + dy * t;
                Self::splat(&mut canvas, h, w, x, y, 1.0);
            }
        }
        for _ in 0..2 {
            let cx = 4.0 + rng.gen_f64() * (w as f64 - 8.0);
            let cy = 4.0 + rng.gen_f64() * (h as f64 - 8.0);
            let r = 1.5 + rng.gen_f64() * 2.5;
            for py in 0..h {
                for px in 0..w {
                    let d2 = (px as f64 - cx).powi(2) + (py as f64 - cy).powi(2);
                    if d2 < r * r {
                        canvas[py * w + px] += 0.8 * (1.0 - d2 / (r * r)) as f32;
                    }
                }
            }
        }
        // clamp and tint channels
        let mut out = vec![0.0f32; h * w * ch];
        let tints: Vec<f32> = (0..ch)
            .map(|c| 0.5 + 0.5 * ((class as usize + c * 3) % 7) as f32 / 6.0)
            .collect();
        for py in 0..h {
            for px in 0..w {
                let v = canvas[py * w + px].min(1.0);
                for c in 0..ch {
                    out[(py * w + px) * ch + c] = v * tints[c];
                }
            }
        }
        out
    }

    /// Additive bilinear splat of intensity at a sub-pixel position.
    fn splat(canvas: &mut [f32], h: usize, w: usize, x: f64, y: f64, v: f32) {
        let xi = x.floor() as isize;
        let yi = y.floor() as isize;
        let fx = (x - xi as f64) as f32;
        let fy = (y - yi as f64) as f32;
        for (ox, oy, wgt) in [
            (0, 0, (1.0 - fx) * (1.0 - fy)),
            (1, 0, fx * (1.0 - fy)),
            (0, 1, (1.0 - fx) * fy),
            (1, 1, fx * fy),
        ] {
            let px = xi + ox;
            let py = yi + oy;
            if px >= 0 && (px as usize) < w && py >= 0 && (py as usize) < h {
                let idx = py as usize * w + px as usize;
                canvas[idx] = (canvas[idx] + v * wgt).min(1.5);
            }
        }
    }

    /// Render sample `index` of class `label` (fully deterministic).
    ///
    /// Hard-mode augmentation — rotation ±20°, translation ±4 px, strong
    /// noise, amplitude jitter, and a class-uninformative distractor
    /// stroke — so that generalization genuinely needs data volume: the
    /// §3.5 capacity policy (3000 vectors/node) must shape the Fig 5
    /// error-vs-nodes curve, which requires a corpus where 3000 samples
    /// under-determine the classifier.
    pub fn sample(&self, label: u8, index: u64) -> Sample {
        let spec = &self.spec;
        let (h, w, ch) = (spec.height, spec.width, spec.channels);
        let mut rng = Pcg32::with_stream(
            spec.seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(index),
            0x5A17 + label as u64,
        );
        let proto = &self.prototypes[label as usize];
        // geometric transform: rotation ±20° around center, shift ±4 px
        let theta = (rng.gen_f64() - 0.5) * (40.0f64).to_radians();
        let (sin_t, cos_t) = theta.sin_cos();
        let dx = rng.gen_f64() * 8.0 - 4.0;
        let dy = rng.gen_f64() * 8.0 - 4.0;
        let (cx, cy) = (w as f64 / 2.0, h as f64 / 2.0);
        let amp = 0.6 + 0.6 * rng.gen_f32();
        let noise = Normal::new(0.0, 0.15);
        let mut pixels = vec![0.0f32; h * w * ch];
        for py in 0..h {
            for px in 0..w {
                // inverse map: destination -> source (bilinear)
                let rx = px as f64 - cx - dx;
                let ry = py as f64 - cy - dy;
                let sx = cos_t * rx + sin_t * ry + cx;
                let sy = -sin_t * rx + cos_t * ry + cy;
                for c in 0..ch {
                    let v = Self::bilinear(proto, h, w, ch, sx, sy, c);
                    let n = noise.sample(&mut rng) as f32;
                    pixels[(py * w + px) * ch + c] = (v * amp + n).clamp(0.0, 1.0);
                }
            }
        }
        // distractor stroke: random line, class-uninformative clutter
        let x0 = rng.gen_f64() * (w as f64 - 1.0);
        let y0 = rng.gen_f64() * (h as f64 - 1.0);
        let ang = rng.gen_f64() * std::f64::consts::TAU;
        let len = 4.0 + rng.gen_f64() * (w as f64 / 3.0);
        for s in 0..(len * 2.0) as usize {
            let t = s as f64 / 2.0;
            let x = (x0 + ang.cos() * t).round();
            let y = (y0 + ang.sin() * t).round();
            if x >= 0.0 && (x as usize) < w && y >= 0.0 && (y as usize) < h {
                let idx = (y as usize * w + x as usize) * ch;
                for c in 0..ch {
                    pixels[idx + c] = (pixels[idx + c] + 0.6).min(1.0);
                }
            }
        }
        Sample { label, pixels }
    }

    /// Bilinear lookup into a prototype (zero outside the canvas).
    fn bilinear(proto: &[f32], h: usize, w: usize, ch: usize, x: f64, y: f64, c: usize) -> f32 {
        let x0 = x.floor();
        let y0 = y.floor();
        let fx = (x - x0) as f32;
        let fy = (y - y0) as f32;
        let mut acc = 0.0f32;
        for (ox, oy, wgt) in [
            (0.0, 0.0, (1.0 - fx) * (1.0 - fy)),
            (1.0, 0.0, fx * (1.0 - fy)),
            (0.0, 1.0, (1.0 - fx) * fy),
            (1.0, 1.0, fx * fy),
        ] {
            let px = x0 + ox;
            let py = y0 + oy;
            if px >= 0.0 && (px as usize) < w && py >= 0.0 && (py as usize) < h {
                acc += proto[(py as usize * w + px as usize) * ch + c] * wgt;
            }
        }
        acc
    }

    /// Generate a corpus of `n` samples with a balanced label cycle.
    pub fn corpus(&self, n: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| {
                let label = (i % self.spec.classes as usize) as u8;
                self.sample(label, i as u64)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let s = Synthesizer::new(SynthSpec::mnist(7));
        assert_eq!(s.sample(3, 10), s.sample(3, 10));
        assert_ne!(s.sample(3, 10), s.sample(3, 11));
        assert_ne!(s.sample(3, 10), s.sample(4, 10));
    }

    #[test]
    fn pixel_range_and_shape() {
        let s = Synthesizer::new(SynthSpec::cifar(1));
        let sample = s.sample(9, 0);
        assert_eq!(sample.pixels.len(), 32 * 32 * 3);
        assert!(sample.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn classes_are_distinguishable() {
        // Between-class prototype distance must dominate within-class
        // sample distance — otherwise the corpus is not learnable.
        let s = Synthesizer::new(SynthSpec::mnist(3));
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        // Average over a few pairs (hard-mode augmentation is strong).
        let mut within = 0.0;
        let mut between = 0.0;
        for i in 0..8 {
            within += d(&s.sample(0, i).pixels, &s.sample(0, i + 100).pixels);
            between += d(&s.sample(0, i).pixels, &s.sample(1, i).pixels);
        }
        assert!(
            between > 1.1 * within,
            "between {between} within {within}"
        );
    }

    #[test]
    fn corpus_is_label_balanced() {
        let s = Synthesizer::new(SynthSpec::mnist(0));
        let corpus = s.corpus(100);
        let mut counts = [0usize; 10];
        for smp in &corpus {
            counts[smp.label as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn images_are_nonempty() {
        let s = Synthesizer::new(SynthSpec::mnist(5));
        for cls in 0..10u8 {
            let smp = s.sample(cls, 0);
            let mass: f32 = smp.pixels.iter().sum();
            assert!(mass > 10.0, "class {cls} image nearly blank: {mass}");
        }
    }
}
