//! The data server — "an independent Node.js application" in the paper
//! (§3.2), here an in-process store with the same contract: accept zip
//! uploads, register indices + labels, and serve id-addressed chunks to
//! client data workers (zip over XHR in the paper; we serve shared sample
//! handles and account the compressed byte cost for the bandwidth model).

use std::sync::Arc;

use super::{archive, ArchiveError, Sample, SharedSample};

/// Transfer accounting for one serve call (fed to `netsim`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeStats {
    pub ids: usize,
    /// Estimated on-the-wire bytes (compressed zip payload).
    pub bytes: u64,
}

/// Id-addressed dataset store.
#[derive(Debug, Default, Clone)]
pub struct DataServer {
    samples: Vec<SharedSample>,
    /// Measured compression ratio from uploads (wire bytes / raw bytes),
    /// reused to estimate serve sizes without re-zipping per request.
    compression_ratio: f64,
}

impl DataServer {
    pub fn new() -> Self {
        Self {
            samples: Vec::new(),
            compression_ratio: 1.0,
        }
    }

    /// §3.3a: upload a zip; returns (first id, labels of new samples) —
    /// the index/label registration the boss forwards to the master.
    pub fn upload_zip(&mut self, bytes: &[u8]) -> Result<(u32, Vec<u8>), ArchiveError> {
        let samples = archive::read_archive(bytes)?;
        let raw: usize = samples.iter().map(|s| s.byte_size() as usize).sum();
        if raw > 0 {
            self.compression_ratio = bytes.len() as f64 / raw as f64;
        }
        let first = self.samples.len() as u32;
        let labels = samples.iter().map(|s| s.label).collect();
        self.samples
            .extend(samples.into_iter().map(Arc::new));
        Ok((first, labels))
    }

    /// Direct ingestion path used by simulations (skips the zip encode —
    /// the byte cost is still modeled via `estimate_serve_bytes`).
    pub fn upload_samples(&mut self, samples: Vec<Sample>) -> (u32, Vec<u8>) {
        let raw: usize = samples.iter().map(|s| s.byte_size() as usize).sum();
        if raw > 0 && self.compression_ratio == 1.0 {
            // default ratio for synthetic f32 imagery (measured ~0.9)
            self.compression_ratio = 0.9;
        }
        let first = self.samples.len() as u32;
        let labels = samples.iter().map(|s| s.label).collect();
        self.samples.extend(samples.into_iter().map(Arc::new));
        (first, labels)
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn get(&self, id: u32) -> Option<&SharedSample> {
        self.samples.get(id as usize)
    }

    /// Serve a set of ids: shared handles + wire-byte estimate.
    pub fn serve(&self, ids: &[u32]) -> (Vec<(u32, SharedSample)>, ServeStats) {
        let mut out = Vec::with_capacity(ids.len());
        let mut raw_bytes = 0u64;
        for &id in ids {
            if let Some(s) = self.samples.get(id as usize) {
                raw_bytes += s.byte_size();
                out.push((id, Arc::clone(s)));
            }
        }
        let stats = ServeStats {
            ids: out.len(),
            bytes: (raw_bytes as f64 * self.compression_ratio).ceil() as u64,
        };
        (out, stats)
    }

    /// Serve as a real zip payload (integration tests / examples).
    pub fn serve_zip(&self, ids: &[u32]) -> Result<Vec<u8>, ArchiveError> {
        let samples: Vec<Sample> = ids
            .iter()
            .filter_map(|&id| self.samples.get(id as usize))
            .map(|s| (**s).clone())
            .collect();
        archive::build_archive(&samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{build_archive, SynthSpec, Synthesizer};

    fn corpus(n: usize) -> Vec<Sample> {
        Synthesizer::new(SynthSpec::mnist(3)).corpus(n)
    }

    #[test]
    fn upload_zip_registers_indices() {
        let mut ds = DataServer::new();
        let bytes = build_archive(&corpus(10)).unwrap();
        let (first, labels) = ds.upload_zip(&bytes).unwrap();
        assert_eq!(first, 0);
        assert_eq!(labels.len(), 10);
        assert_eq!(ds.len(), 10);
        // second upload appends
        let (first2, _) = ds.upload_zip(&bytes).unwrap();
        assert_eq!(first2, 10);
        assert_eq!(ds.len(), 20);
    }

    #[test]
    fn serve_size_estimation_before_any_upload() {
        // The compression ratio is measured from uploads; before any
        // upload it must hold its neutral default (never 0/0) and serve
        // calls must produce finite, zero-byte estimates.
        let ds = DataServer::new();
        assert!(ds.is_empty());
        let (got, stats) = ds.serve(&[0, 1, 2]);
        assert!(got.is_empty());
        assert_eq!(stats.ids, 0);
        assert_eq!(stats.bytes, 0);
    }

    #[test]
    fn empty_upload_keeps_ratio_sane() {
        // A zero-sample upload has raw size 0 — the ratio update must not
        // divide by zero, and later estimates must still be finite.
        let mut ds = DataServer::new();
        let (first, labels) = ds.upload_samples(Vec::new());
        assert_eq!(first, 0);
        assert!(labels.is_empty());
        assert!(ds.is_empty());
        ds.upload_samples(corpus(4));
        let (got, stats) = ds.serve(&[0, 1, 2, 3]);
        assert_eq!(got.len(), 4);
        assert!(stats.bytes > 0);
        let raw: u64 = got.iter().map(|(_, s)| s.byte_size()).sum();
        assert!(stats.bytes <= raw, "estimate {} vs raw {raw}", stats.bytes);
    }

    #[test]
    fn serve_returns_requested_ids() {
        let mut ds = DataServer::new();
        ds.upload_samples(corpus(10));
        let (got, stats) = ds.serve(&[1, 3, 5]);
        assert_eq!(stats.ids, 3);
        assert!(stats.bytes > 0);
        assert_eq!(got.len(), 3);
        assert_eq!(got[1].0, 3);
    }

    #[test]
    fn serve_skips_unknown_ids() {
        let mut ds = DataServer::new();
        ds.upload_samples(corpus(5));
        let (got, stats) = ds.serve(&[2, 99]);
        assert_eq!(got.len(), 1);
        assert_eq!(stats.ids, 1);
    }

    #[test]
    fn serve_zip_roundtrips() {
        let mut ds = DataServer::new();
        let samples = corpus(6);
        ds.upload_samples(samples.clone());
        let zip = ds.serve_zip(&[0, 2]).unwrap();
        let back = crate::data::read_archive(&zip).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0], samples[0]);
        assert_eq!(back[1], samples[2]);
    }

    #[test]
    fn wire_estimate_tracks_compression() {
        let mut ds = DataServer::new();
        let samples = corpus(20);
        let bytes = build_archive(&samples).unwrap();
        ds.upload_zip(&bytes).unwrap();
        let (_, stats) = ds.serve(&(0..20).collect::<Vec<_>>());
        let raw: u64 = samples.iter().map(|s| s.byte_size()).sum();
        // estimate should be close to the actual zip size, below raw
        assert!(stats.bytes <= raw);
        let actual = ds.serve_zip(&(0..20).collect::<Vec<_>>()).unwrap().len() as u64;
        let ratio = stats.bytes as f64 / actual as f64;
        assert!((0.7..1.4).contains(&ratio), "estimate {} actual {actual}", stats.bytes);
    }
}
