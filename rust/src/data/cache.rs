//! Client-side sample cache with a byte budget.
//!
//! "A redundant cache of data is stored locally in the clients' browser's
//! memory" (§3.2); the practical limit the paper measured is ~100 MB
//! (§3.7).  Eviction is LRU over *non-allocated* entries first — evicting
//! an id the worker is currently allocated would force an immediate
//! re-download.
//!
//! Eviction order is driven by a `BTreeMap` recency index (tick → id,
//! ticks strictly increasing, hence unique keys), the same pattern as
//! `serve::cache`: the LRU victim is the first unpinned entry in tick
//! order, an O(log n) ordered walk instead of an O(n) scan over an
//! unordered map — and it keeps eviction order independent of
//! `HashMap` internals (determinism discipline, see DESIGN.md).

use std::collections::{BTreeMap, HashMap};

use super::SharedSample;

/// Browser-memory-bounded cache, LRU beyond the byte budget.
#[derive(Debug, Clone)]
pub struct ClientCache {
    budget_bytes: u64,
    used_bytes: u64,
    // Point access only (get/insert/remove by id) — never iterated, so
    // map order cannot reach observable state.
    entries: HashMap<u32, Entry>,
    /// Recency index: last-used tick → sample id.  Ticks are unique, so
    /// this is a total order; the front is always the LRU candidate.
    recency: BTreeMap<u64, u32>,
    tick: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    sample: SharedSample,
    last_used: u64,
    pinned: bool, // currently allocated to this worker
}

/// The paper's practical browser memory limit (§3.7).
pub const PRACTICAL_BUDGET: u64 = 100 * 1024 * 1024;

/// Serializable cache structure (no pixel bytes): tick counter plus
/// entries in recency order — see [`ClientCache::export_state`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheState {
    pub tick: u64,
    pub entries: Vec<CacheEntryState>,
}

/// One cached sample's bookkeeping (recency tick, id, pin status).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheEntryState {
    pub last_used: u64,
    pub id: u32,
    pub pinned: bool,
}

impl ClientCache {
    pub fn new(budget_bytes: u64) -> Self {
        Self {
            budget_bytes,
            used_bytes: 0,
            entries: HashMap::new(),
            recency: BTreeMap::new(),
            tick: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn contains(&self, id: u32) -> bool {
        self.entries.contains_key(&id)
    }

    /// Insert (or refresh) a sample; evicts LRU unpinned entries if over
    /// budget.  Returns false if the sample alone exceeds the budget.
    pub fn insert(&mut self, id: u32, sample: SharedSample, pinned: bool) -> bool {
        let size = sample.byte_size();
        if size > self.budget_bytes {
            return false;
        }
        self.tick += 1;
        if let Some(prev) = self.entries.insert(
            id,
            Entry {
                sample,
                last_used: self.tick,
                pinned,
            },
        ) {
            self.used_bytes -= prev.sample.byte_size();
            self.recency.remove(&prev.last_used);
        }
        self.recency.insert(self.tick, id);
        self.used_bytes += size;
        self.evict_over_budget();
        true
    }

    /// Fetch a sample, refreshing recency.
    pub fn get(&mut self, id: u32) -> Option<SharedSample> {
        self.tick += 1;
        let tick = self.tick;
        let e = self.entries.get_mut(&id)?;
        let prev_tick = e.last_used;
        e.last_used = tick;
        let out = SharedSample::clone(&e.sample);
        self.recency.remove(&prev_tick);
        self.recency.insert(tick, id);
        Some(out)
    }

    /// Update pin status when the allocation changes (§3.3b revokes).
    /// No index maintenance needed: pins are consulted at eviction time.
    pub fn set_pinned(&mut self, id: u32, pinned: bool) {
        if let Some(e) = self.entries.get_mut(&id) {
            e.pinned = pinned;
        }
    }

    /// Cache state for checkpointing: the logical tick plus every entry
    /// as `(last_used, id, pinned)` in recency order. Sample bytes are
    /// *not* exported — the corpus is deterministic from the run seed, so
    /// restore refetches pixels by id and only the recency/pin structure
    /// (which drives observable eviction order) needs to survive.
    pub fn export_state(&self) -> CacheState {
        CacheState {
            tick: self.tick,
            entries: self
                .recency
                .iter()
                .map(|(&tick, &id)| {
                    let e = &self.entries[&id];
                    CacheEntryState {
                        last_used: tick,
                        id,
                        pinned: e.pinned,
                    }
                })
                .collect(),
        }
    }

    /// Rebuild a cache from a captured export, refetching sample bytes
    /// through `fetch` (backed by the run's `DataServer`). Restores the
    /// exact tick counter and recency order, so post-restore eviction
    /// decisions are bitwise-identical to the uninterrupted run's.
    pub fn restore(
        budget_bytes: u64,
        state: &CacheState,
        mut fetch: impl FnMut(u32) -> SharedSample,
    ) -> Self {
        let mut cache = Self::new(budget_bytes);
        cache.tick = state.tick;
        for e in &state.entries {
            let sample = fetch(e.id);
            cache.used_bytes += sample.byte_size();
            cache.entries.insert(
                e.id,
                Entry {
                    sample,
                    last_used: e.last_used,
                    pinned: e.pinned,
                },
            );
            cache.recency.insert(e.last_used, e.id);
        }
        cache
    }

    fn evict_over_budget(&mut self) {
        while self.used_bytes > self.budget_bytes {
            // LRU among unpinned: first tick in the ordered recency
            // index whose entry is not pinned.
            let victim = self
                .recency
                .iter()
                .map(|(_, id)| *id)
                .find(|id| self.entries.get(id).is_some_and(|e| !e.pinned));
            match victim {
                Some(id) => {
                    let e = self.entries.remove(&id).unwrap();
                    self.recency.remove(&e.last_used);
                    self.used_bytes -= e.sample.byte_size();
                }
                None => break, // everything pinned: allow overshoot
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Sample;
    use std::sync::Arc;

    fn sample(n_pixels: usize) -> SharedSample {
        Arc::new(Sample {
            label: 0,
            pixels: vec![0.5; n_pixels],
        })
    }

    #[test]
    fn insert_get_roundtrip() {
        let mut c = ClientCache::new(10_000);
        assert!(c.insert(1, sample(100), true));
        assert!(c.contains(1));
        assert_eq!(c.get(1).unwrap().pixels.len(), 100);
        assert!(c.get(2).is_none());
    }

    #[test]
    fn evicts_lru_unpinned_first() {
        // each sample: 401 bytes; budget fits 2
        let mut c = ClientCache::new(900);
        c.insert(1, sample(100), false);
        c.insert(2, sample(100), true);
        c.get(1); // refresh 1
        // Inserting 3 overshoots the budget; the pinned 2 must survive,
        // so the LRU unpinned entry (1, refreshed before 3 arrived) goes.
        c.insert(3, sample(100), false);
        assert!(!c.contains(1));
        assert!(c.contains(2), "pinned entry must survive");
        assert!(c.contains(3));
    }

    #[test]
    fn oversized_sample_rejected() {
        let mut c = ClientCache::new(100);
        assert!(!c.insert(1, sample(1000), true));
        assert!(c.is_empty());
    }

    #[test]
    fn all_pinned_allows_overshoot() {
        let mut c = ClientCache::new(500);
        c.insert(1, sample(100), true);
        c.insert(2, sample(100), true);
        assert_eq!(c.len(), 2);
        assert!(c.used_bytes() > 500);
    }

    #[test]
    fn reinsert_updates_bytes_once() {
        let mut c = ClientCache::new(10_000);
        c.insert(1, sample(100), true);
        let used = c.used_bytes();
        c.insert(1, sample(100), true);
        assert_eq!(c.used_bytes(), used);
    }

    #[test]
    fn unpinning_makes_evictable() {
        let mut c = ClientCache::new(900);
        c.insert(1, sample(100), true);
        c.insert(2, sample(100), true);
        c.set_pinned(1, false);
        c.insert(3, sample(100), true);
        assert!(!c.contains(1));
        assert!(c.contains(2) && c.contains(3));
    }

    #[test]
    fn export_restore_preserves_recency_and_pins() {
        let mut c = ClientCache::new(900);
        c.insert(1, sample(100), false);
        c.insert(2, sample(100), true);
        c.get(1); // 2's tick is now older than 1's
        let state = c.export_state();
        assert_eq!(state.entries.len(), 2);

        let mut r = ClientCache::restore(900, &state, |_| sample(100));
        assert_eq!(r.export_state(), state);
        assert_eq!(r.used_bytes(), c.used_bytes());
        // Same eviction decision as the original would make: insert 3,
        // the unpinned LRU — which is 1? No: 1 was refreshed, 2 is pinned,
        // so 1 is the only unpinned entry and must be the victim.
        r.insert(3, sample(100), false);
        c.insert(3, sample(100), false);
        assert_eq!(r.contains(1), c.contains(1));
        assert_eq!(r.contains(2), c.contains(2));
        assert_eq!(r.contains(3), c.contains(3));
        assert_eq!(r.export_state(), c.export_state());
    }

    #[test]
    fn recency_index_stays_consistent_across_refresh_and_evict() {
        let mut c = ClientCache::new(900);
        c.insert(1, sample(100), false);
        c.insert(2, sample(100), false);
        c.get(1); // 2 is now LRU
        c.insert(3, sample(100), false); // evicts 2
        assert!(c.contains(1) && !c.contains(2) && c.contains(3));
        // reinsert 2: must not resurrect a stale recency slot for it
        c.insert(2, sample(100), false); // evicts 1 (LRU after 3)
        assert!(!c.contains(1) && c.contains(2) && c.contains(3));
        assert_eq!(c.len(), 2);
    }
}
