//! Data substrate: synthetic corpora, zip dataset archives, the data
//! server, and the client-side cache.
//!
//! Mirrors the paper's data path (§3.2–3.3a): users upload **zip files**
//! whose sub-directory names define class labels; the data server registers
//! *indices* with the master; clients download their allocated ids as
//! zipped chunks over XHR, unzip, decode, and cache them locally
//! ("a redundant cache of data is stored locally in the client's browser's
//! memory", practical limit ~100 MB §3.7).
//!
//! MNIST/CIFAR-10 are not downloadable in this sandbox; `synth` builds
//! deterministic, learnable stand-ins with the same tensor shapes (see
//! DESIGN.md §Substitutions).

mod archive;
mod cache;
mod server;
mod synth;

pub use archive::{build_archive, read_archive, ArchiveError};
pub use cache::{CacheEntryState, CacheState, ClientCache, PRACTICAL_BUDGET};
pub use server::{DataServer, ServeStats};
pub use synth::{SynthSpec, Synthesizer};

use std::sync::Arc;

/// One data vector: an image tensor (HWC, f32 in [0,1]) plus its label.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub label: u8,
    pub pixels: Vec<f32>,
}

impl Sample {
    /// Serialized payload size (f32 pixels + 1 label byte) — the unit the
    /// bandwidth model charges for.
    pub fn byte_size(&self) -> u64 {
        (self.pixels.len() * 4 + 1) as u64
    }
}

/// Shared-ownership sample (server and many client caches hold the same
/// buffer; cloning a fleet of caches must not copy pixel data).
pub type SharedSample = Arc<Sample>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_byte_size() {
        let s = Sample {
            label: 3,
            pixels: vec![0.0; 784],
        };
        assert_eq!(s.byte_size(), 784 * 4 + 1);
    }
}
