//! Zip dataset archives — the paper's upload format (§3.2: "the data
//! server handles zipped image classification datasets (where
//! sub-directory names define class labels)").
//!
//! Layout inside the archive (mirroring `/cifar10/apple/apple_s_000022.png`):
//! `class_<label>/img_<index>.f32` where each entry is the raw
//! little-endian f32 tensor (this sandbox has no PNG/JPEG codecs; the
//! decode step in the client pipeline is a pass-through, with its CPU cost
//! modeled in the client's compute budget instead).

use std::io::{Cursor, Read, Write};

use zip::result::ZipError;
use zip::write::FileOptions;
use zip::{CompressionMethod, ZipArchive, ZipWriter};

use super::Sample;

/// Archive build/read failure.
#[derive(Debug, thiserror::Error)]
pub enum ArchiveError {
    #[error("zip error: {0}")]
    Zip(#[from] ZipError),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("malformed entry name: {0}")]
    BadEntry(String),
    #[error("entry payload not a whole number of f32s: {0}")]
    BadPayload(String),
}

/// Serialize samples into a zip archive (deflate — the paper ships real
/// zip files over XHR and we account their true compressed size).
pub fn build_archive(samples: &[Sample]) -> Result<Vec<u8>, ArchiveError> {
    let mut zw = ZipWriter::new(Cursor::new(Vec::new()));
    let opts =
        FileOptions::default().compression_method(CompressionMethod::Deflated);
    for (i, s) in samples.iter().enumerate() {
        zw.start_file(format!("class_{}/img_{:06}.f32", s.label, i), opts)?;
        let mut bytes = Vec::with_capacity(s.pixels.len() * 4);
        for p in &s.pixels {
            bytes.extend_from_slice(&p.to_le_bytes());
        }
        zw.write_all(&bytes)?;
    }
    Ok(zw.finish()?.into_inner())
}

/// Parse an archive back into samples (entry order).  Labels come from the
/// directory name, as in the paper.
pub fn read_archive(bytes: &[u8]) -> Result<Vec<Sample>, ArchiveError> {
    let mut za = ZipArchive::new(Cursor::new(bytes))?;
    let mut out = Vec::with_capacity(za.len());
    for i in 0..za.len() {
        let mut entry = za.by_index(i)?;
        if entry.is_dir() {
            continue;
        }
        let name = entry.name().to_string();
        let label = parse_label(&name)?;
        let mut payload = Vec::with_capacity(entry.size() as usize);
        entry.read_to_end(&mut payload)?;
        if payload.len() % 4 != 0 {
            return Err(ArchiveError::BadPayload(name));
        }
        let pixels = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(Sample { label, pixels });
    }
    Ok(out)
}

/// `class_<label>/...` → label.
fn parse_label(name: &str) -> Result<u8, ArchiveError> {
    let dir = name
        .split('/')
        .next()
        .ok_or_else(|| ArchiveError::BadEntry(name.to_string()))?;
    let digits = dir
        .strip_prefix("class_")
        .ok_or_else(|| ArchiveError::BadEntry(name.to_string()))?;
    digits
        .parse::<u8>()
        .map_err(|_| ArchiveError::BadEntry(name.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthSpec, Synthesizer};

    #[test]
    fn roundtrip_preserves_samples() {
        let synth = Synthesizer::new(SynthSpec::mnist(1));
        let samples = synth.corpus(20);
        let bytes = build_archive(&samples).unwrap();
        let back = read_archive(&bytes).unwrap();
        assert_eq!(samples, back);
    }

    #[test]
    fn archive_compresses() {
        let synth = Synthesizer::new(SynthSpec::mnist(2));
        let samples = synth.corpus(50);
        let raw: usize = samples.iter().map(|s| s.pixels.len() * 4).sum();
        let bytes = build_archive(&samples).unwrap();
        assert!(
            bytes.len() < raw,
            "zip {} >= raw {raw}",
            bytes.len()
        );
    }

    #[test]
    fn rejects_bad_entry_names() {
        let mut zw = ZipWriter::new(Cursor::new(Vec::new()));
        let opts = FileOptions::default();
        zw.start_file("nolabel.f32", opts).unwrap();
        zw.write_all(&[0u8; 8]).unwrap();
        let bytes = zw.finish().unwrap().into_inner();
        assert!(matches!(
            read_archive(&bytes),
            Err(ArchiveError::BadEntry(_))
        ));
    }

    #[test]
    fn rejects_misaligned_payload() {
        let mut zw = ZipWriter::new(Cursor::new(Vec::new()));
        let opts = FileOptions::default();
        zw.start_file("class_1/x.f32", opts).unwrap();
        zw.write_all(&[0u8; 5]).unwrap();
        let bytes = zw.finish().unwrap().into_inner();
        assert!(matches!(
            read_archive(&bytes),
            Err(ArchiveError::BadPayload(_))
        ));
    }

    #[test]
    fn empty_archive_is_empty_corpus() {
        let bytes = build_archive(&[]).unwrap();
        assert!(read_archive(&bytes).unwrap().is_empty());
    }
}
