//! Sampling distributions over [`Pcg32`], used by the network simulator
//! (latency models), the churn process, and synthetic-data generation.

use super::Pcg32;

/// Uniform over [lo, hi).
#[derive(Debug, Clone, Copy)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi >= lo);
        Self { lo, hi }
    }
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        self.lo + (self.hi - self.lo) * rng.gen_f64()
    }
}

/// Gaussian via Marsaglia polar method (no cached spare: simpler, still fast
/// enough for simulation workloads).
#[derive(Debug, Clone, Copy)]
pub struct Normal {
    pub mean: f64,
    pub std: f64,
}

impl Normal {
    pub fn new(mean: f64, std: f64) -> Self {
        assert!(std >= 0.0);
        Self { mean, std }
    }
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        loop {
            let u = 2.0 * rng.gen_f64() - 1.0;
            let v = 2.0 * rng.gen_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let factor = (-2.0 * s.ln() / s).sqrt();
                return self.mean + self.std * u * factor;
            }
        }
    }
}

/// Exponential with rate λ (mean 1/λ): inter-arrival times of churn events.
#[derive(Debug, Clone, Copy)]
pub struct Exp {
    pub rate: f64,
}

impl Exp {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0);
        Self { rate }
    }
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        // Inverse CDF; 1-u in (0,1] avoids ln(0).
        -(1.0 - rng.gen_f64()).ln() / self.rate
    }
}

/// Log-normal — heavy-tailed latency jitter (the paper's cellular links
/// "communicate with longer delays"; heavy tails model stragglers).
#[derive(Debug, Clone, Copy)]
pub struct LogNormal {
    pub mu: f64,
    pub sigma: f64,
}

impl LogNormal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        Self { mu, sigma }
    }
    /// Construct from the desired median and a tail factor σ.
    pub fn from_median(median: f64, sigma: f64) -> Self {
        Self {
            mu: median.ln(),
            sigma,
        }
    }
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        let n = Normal::new(self.mu, self.sigma).sample(rng);
        n.exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_of(mut f: impl FnMut(&mut Pcg32) -> f64, n: usize, seed: u64) -> f64 {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| f(&mut rng)).sum::<f64>() / n as f64
    }

    #[test]
    fn uniform_mean() {
        let d = Uniform::new(2.0, 6.0);
        let m = mean_of(|r| d.sample(r), 50_000, 1);
        assert!((m - 4.0).abs() < 0.05, "{m}");
    }

    #[test]
    fn uniform_bounds() {
        let d = Uniform::new(-1.0, 1.0);
        let mut rng = Pcg32::new(2);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((-1.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_mean_and_std() {
        let d = Normal::new(10.0, 3.0);
        let mut rng = Pcg32::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean={mean}");
        assert!((var.sqrt() - 3.0).abs() < 0.05, "std={}", var.sqrt());
    }

    #[test]
    fn exp_mean_matches_rate() {
        let d = Exp::new(0.5); // mean 2
        let m = mean_of(|r| d.sample(r), 100_000, 4);
        assert!((m - 2.0).abs() < 0.05, "{m}");
    }

    #[test]
    fn lognormal_median() {
        let d = LogNormal::from_median(50.0, 0.5);
        let mut rng = Pcg32::new(5);
        let mut xs: Vec<f64> = (0..50_001).map(|_| d.sample(&mut rng)).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        let median = xs[25_000];
        assert!((median - 50.0).abs() < 2.0, "median={median}");
    }

    #[test]
    fn total_cmp_sort_survives_nan_samples() {
        // The determinism discipline bans partial_cmp().unwrap() on
        // floats: a single NaN in the slice panics it mid-sort.  Pin
        // the total_cmp replacement: NaNs sort to the back, finite
        // values stay ordered, nothing panics.
        let d = LogNormal::from_median(50.0, 0.5);
        let mut rng = Pcg32::new(5);
        let mut xs: Vec<f64> = (0..1_000).map(|_| d.sample(&mut rng)).collect();
        xs[137] = f64::NAN;
        xs[842] = f64::NAN;
        xs.sort_by(|a, b| a.total_cmp(b));
        assert!(xs[998].is_nan() && xs[999].is_nan());
        for w in xs[..998].windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn lognormal_positive() {
        let d = LogNormal::from_median(10.0, 1.0);
        let mut rng = Pcg32::new(6);
        for _ in 0..10_000 {
            assert!(d.sample(&mut rng) > 0.0);
        }
    }
}
