//! Deterministic PRNG substrate (PCG32) + distributions.
//!
//! The `rand` crate family is not available in this offline environment, so
//! the simulation's randomness — device heterogeneity, latency jitter,
//! churn, data synthesis, parameter init — is built on a small,
//! well-understood generator: PCG-XSH-RR 64/32 (O'Neill 2014).  Everything
//! in the repo that draws randomness takes an explicit seed, making every
//! experiment bit-reproducible (the paper's §2.3 reproducibility goal).

mod distributions;
mod pcg;

pub use distributions::{Exp, LogNormal, Normal, Uniform};
pub use pcg::Pcg32;

/// Fisher–Yates shuffle with an explicit generator.
pub fn shuffle<T>(rng: &mut Pcg32, xs: &mut [T]) {
    if xs.is_empty() {
        return;
    }
    for i in (1..xs.len()).rev() {
        let j = rng.gen_range_usize(i + 1);
        xs.swap(i, j);
    }
}

/// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
pub fn sample_indices(rng: &mut Pcg32, n: usize, k: usize) -> Vec<usize> {
    let k = k.min(n);
    let mut idx: Vec<usize> = (0..n).collect();
    for i in 0..k {
        let j = i + rng.gen_range_usize(n - i);
        idx.swap(i, j);
    }
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(42);
        let mut xs: Vec<u32> = (0..100).collect();
        shuffle(&mut rng, &mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn shuffle_deterministic_per_seed() {
        let run = |seed| {
            let mut rng = Pcg32::new(seed);
            let mut xs: Vec<u32> = (0..32).collect();
            shuffle(&mut rng, &mut xs);
            xs
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Pcg32::new(1);
        let s = sample_indices(&mut rng, 50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn sample_indices_k_exceeding_n_clamps() {
        let mut rng = Pcg32::new(1);
        assert_eq!(sample_indices(&mut rng, 3, 10).len(), 3);
    }
}
