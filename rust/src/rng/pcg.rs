//! PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotate output.

/// Minimal PCG32 generator (O'Neill 2014, `pcg32_random_r`).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MULTIPLIER: u64 = 6364136223846793005;
const DEFAULT_STREAM: u64 = 1442695040888963407;

impl Pcg32 {
    /// Seeded generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, DEFAULT_STREAM)
    }

    /// Seeded generator with an explicit stream (odd increment derived
    /// from `stream`); distinct streams never collide.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Raw generator state `(state, inc)` — for checkpointing. Together
    /// with [`from_state`](Self::from_state) this restores the exact
    /// position in the stream (no re-warmup), which the durable-state
    /// plane relies on for bitwise-identical replay.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at an exact stream position captured by
    /// [`state`](Self::state). `inc` must be odd (every constructor
    /// guarantees this invariant).
    pub fn from_state(state: u64, inc: u64) -> Self {
        debug_assert!(inc & 1 == 1, "pcg increment must be odd");
        Self { state, inc }
    }

    /// Derive an independent child generator (for per-client streams).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg32::with_stream(seed, tag.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    /// Next raw 32 bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULTIPLIER).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) — Lemire's unbiased multiply-shift.
    pub fn gen_range_u32(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64).wrapping_mul(bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64).wrapping_mul(bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in [0, bound).
    pub fn gen_range_usize(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0 && bound <= u32::MAX as usize);
        self.gen_range_u32(bound as u32) as usize
    }

    /// Bernoulli draw.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_sequence_is_stable() {
        // Golden values: any change to the generator breaks reproducibility
        // of every experiment in EXPERIMENTS.md, so pin the first outputs.
        let mut rng = Pcg32::new(42);
        let got: Vec<u32> = (0..4).map(|_| rng.next_u32()).collect();
        let again: Vec<u32> = {
            let mut r = Pcg32::new(42);
            (0..4).map(|_| r.next_u32()).collect()
        };
        assert_eq!(got, again);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::with_stream(1, 1);
        let mut b = Pcg32::with_stream(1, 2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut rng = Pcg32::new(3);
        for _ in 0..10_000 {
            let x = rng.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_unbiased_small_bound() {
        let mut rng = Pcg32::new(9);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[rng.gen_range_usize(3)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn fork_produces_independent_streams() {
        let mut root = Pcg32::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn state_roundtrip_resumes_exact_stream() {
        let mut rng = Pcg32::new(77);
        for _ in 0..13 {
            rng.next_u32();
        }
        let (state, inc) = rng.state();
        let mut resumed = Pcg32::from_state(state, inc);
        let a: Vec<u32> = (0..16).map(|_| rng.next_u32()).collect();
        let b: Vec<u32> = (0..16).map(|_| resumed.next_u32()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn mean_of_uniform_close_to_half() {
        let mut rng = Pcg32::new(123);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| rng.gen_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }
}
