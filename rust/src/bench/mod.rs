//! Bench harness substrate (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with median/p95 reporting for the
//! micro benches, and wall-clock helpers for the figure-level experiment
//! drivers.  Benches are plain `harness = false` binaries under
//! `rust/benches/`.

use std::time::Instant;

use crate::metrics::Summary;

/// Result of a timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: usize,
    pub summary_ns: Summary,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        self.summary_ns.median()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12} med  {:>12} p95  {:>12} min   ({} iters)",
            self.name,
            fmt_ns(self.summary_ns.median()),
            fmt_ns(self.summary_ns.p95()),
            fmt_ns(self.summary_ns.min()),
            self.iterations
        )
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns.is_nan() {
        "-".into()
    } else if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f` `iters` times after `warmup` runs; returns per-call stats.
/// The closure's return value is black-boxed to prevent dead-code
/// elimination.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iterations: iters,
        summary_ns: Summary::from(samples),
    }
}

/// Optimizer barrier (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Wall-clock a single run of `f`, returning (result, seconds).
pub fn wall<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 10, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(r.iterations, 10);
        assert!(r.median_ns() > 0.0);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.00 s");
    }

    #[test]
    fn wall_returns_result() {
        let (v, secs) = wall(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
