//! JSON serializer (compact + pretty).  Float formatting uses the shortest
//! representation that round-trips (Rust's `{}` for f64 is shortest-exact),
//! so research closures preserve parameter values bit-for-bit through a
//! save/load cycle.

use super::Value;

/// Compact serialization (no whitespace).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Pretty serialization (2-space indent), for human-facing closures.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, level: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Number(n) => write_number(*n, out),
        Value::String(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_value(item, out, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; closures must never contain them (params are
        // checked upstream) — serialize as null to stay spec-valid.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::{object, parse};

    #[test]
    fn compact_format() {
        let v = object(vec![("b", 1.into()), ("a", Value::from(vec![1i64, 2]))]);
        // BTreeMap: keys sorted
        assert_eq!(to_string(&v), r#"{"a":[1,2],"b":1}"#);
    }

    #[test]
    fn numbers_roundtrip_exactly() {
        for x in [0.1f64, 1.0 / 3.0, 1e-300, -2.5e17, 123456789.123456] {
            let s = to_string(&Value::Number(x));
            let back = parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "{s}");
        }
    }

    #[test]
    fn integers_have_no_decimal_point() {
        assert_eq!(to_string(&Value::Number(42.0)), "42");
        assert_eq!(to_string(&Value::Number(-3.0)), "-3");
    }

    #[test]
    fn f32_params_roundtrip() {
        // Research closures store f32 params via f64; check exactness.
        for x in [0.123456789f32, -1.5e-30, 3.4e38] {
            let s = to_string(&Value::Number(x as f64));
            let back = parse(&s).unwrap().as_f64().unwrap() as f32;
            assert_eq!(back, x);
        }
    }

    #[test]
    fn string_escaping() {
        let v = Value::from("a\"b\\c\nd\u{1}");
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        assert_eq!(to_string(&Value::Number(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Number(f64::INFINITY)), "null");
    }

    #[test]
    fn pretty_parses_back() {
        let v = object(vec![
            ("xs", Value::from(vec![1.5f64, 2.5])),
            ("o", object(vec![("k", "v".into())])),
        ]);
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }
}
