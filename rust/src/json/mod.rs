//! JSON substrate: value model, parser, serializer.
//!
//! MLitB's reproducibility story (§2.3, §3.6 of the paper) rests on JSON:
//! *research closures* — model spec + parameters in a single universally
//! readable object — and the AOT `manifest.json` are both JSON documents.
//! serde is unavailable offline, so this is a complete from-scratch
//! implementation: a recursive-descent parser (UTF-8, escapes, nesting
//! limit) and a serializer (compact + pretty), with round-trip property
//! tests in `testing`.

mod parse;
mod value;
mod write;

pub use parse::{parse, ParseError};
pub use value::Value;
pub use write::{to_string, to_string_pretty};

use std::collections::BTreeMap;

/// Convenience: parse a file.
pub fn from_file(path: &std::path::Path) -> Result<Value, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))
}

/// Convenience: build an object from pairs.
pub fn object(pairs: Vec<(&str, Value)>) -> Value {
    let mut map = BTreeMap::new();
    for (k, v) in pairs {
        map.insert(k.to_string(), v);
    }
    Value::Object(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_roundtrip() {
        let v = object(vec![
            ("name", Value::from("mnist_conv")),
            ("params", Value::Array(vec![1.5.into(), (-2.0).into(), 0.0.into()])),
            ("meta", object(vec![("iter", 100.into()), ("ok", true.into())])),
            ("none", Value::Null),
        ]);
        let s = to_string(&v);
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
        let sp = to_string_pretty(&v);
        assert_eq!(parse(&sp).unwrap(), v);
    }
}
