//! Recursive-descent JSON parser (RFC 8259 subset sufficient for research
//! closures and manifests: full string escapes incl. \uXXXX surrogate
//! pairs, scientific-notation numbers, nesting-depth limit).

use std::collections::BTreeMap;
use std::fmt;

use super::Value;

/// Parse failure with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

const MAX_DEPTH: usize = 128;

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, ParseError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            // high surrogate: require \uXXXX low surrogate
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid UTF-8 lead byte")),
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated UTF-8 sequence"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid UTF-8 sequence"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit required after '.'"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit required in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("number out of range"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-0.5e2").unwrap(), Value::Number(-50.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b"), Some(&Value::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            parse(r#""a\n\t\"\\A""#).unwrap().as_str().unwrap(),
            "a\n\t\"\\A"
        );
    }

    #[test]
    fn surrogate_pair() {
        assert_eq!(parse(r#""😀""#).unwrap().as_str().unwrap(), "😀");
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse("\"héllo→\"").unwrap().as_str().unwrap(), "héllo→");
    }

    #[test]
    fn rejects_garbage() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "tru", "01", "1.", "1e", "\"\\x\"",
            "{\"a\":1}x", "[1 2]", "\"unterminated",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn rejects_lone_low_surrogate() {
        assert!(parse(r#""\uDC00""#).is_err());
    }

    #[test]
    fn depth_limit() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap().as_object().unwrap().len(), 0);
    }

    #[test]
    fn large_float_array() {
        let s = format!(
            "[{}]",
            (0..1000).map(|i| format!("{}.5", i)).collect::<Vec<_>>().join(",")
        );
        let v = parse(&s).unwrap();
        assert_eq!(v.as_array().unwrap().len(), 1000);
        assert_eq!(v.at(999).unwrap().as_f64(), Some(999.5));
    }
}
