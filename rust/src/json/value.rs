//! JSON value model with typed accessors.

use std::collections::BTreeMap;

/// A JSON document node.  Numbers are f64 (JSON has one number type); the
/// integer accessors check representability.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Number(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> Option<&Value> {
        self.as_array().and_then(|a| a.get(idx))
    }

    /// Typed helpers that surface an error message with the key path —
    /// manifest parsing uses these to fail loudly on schema drift.
    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| format!("missing/invalid string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        self.get(key)
            .and_then(Value::as_usize)
            .ok_or_else(|| format!("missing/invalid integer field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| format!("missing/invalid number field '{key}'"))
    }

    pub fn req_array(&self, key: &str) -> Result<&[Value], String> {
        self.get(key)
            .and_then(Value::as_array)
            .ok_or_else(|| format!("missing/invalid array field '{key}'"))
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Number(n)
    }
}
impl From<f32> for Value {
    fn from(n: f32) -> Self {
        Value::Number(n as f64)
    }
}
impl From<i32> for Value {
    fn from(n: i32) -> Self {
        Value::Number(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Number(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Number(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::String(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::String(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v: Value = 3.0.into();
        assert_eq!(v.as_i64(), Some(3));
        assert_eq!(v.as_f64(), Some(3.0));
        let v: Value = 3.5.into();
        assert_eq!(v.as_i64(), None);
        let v: Value = "hi".into();
        assert_eq!(v.as_str(), Some("hi"));
        assert_eq!(v.as_f64(), None);
    }

    #[test]
    fn nested_lookup() {
        let v = crate::json::object(vec![(
            "a",
            crate::json::object(vec![("b", Value::from(vec![1i64, 2, 3]))]),
        )]);
        let arr = v.get("a").unwrap().get("b").unwrap();
        assert_eq!(arr.at(2).unwrap().as_i64(), Some(3));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn req_helpers_error_messages() {
        let v = crate::json::object(vec![("n", 1.into())]);
        assert!(v.req_str("n").is_err());
        assert_eq!(v.req_usize("n").unwrap(), 1);
        assert!(v.req_usize("gone").unwrap_err().contains("gone"));
    }

    #[test]
    fn negative_to_usize_fails() {
        let v = crate::json::object(vec![("n", (-2i64).into())]);
        assert!(v.req_usize("n").is_err());
    }
}
