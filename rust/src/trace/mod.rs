//! Unified virtual-clock trace plane: spans, instants, async request
//! lifecycles and cross-plane flow edges over the simulation's virtual
//! clock, exported as Chrome/Perfetto trace-event JSON and CSV.
//!
//! The paper's whole argument rests on *measuring* a heterogeneous fleet
//! (§3.3's latency-adaptive budgets, Fig 4's latency axis), yet aggregate
//! end-of-run CSVs cannot attribute virtual time to phases or link events
//! across planes.  This module is the causal, per-event view: training
//! emits per-iteration spans (client compute → gradient upload → master
//! ingest/reduce → optimizer step → broadcast), serving emits per-request
//! lifecycle spans (begin at arrival, end at response with a
//! served/shed/coalesced outcome tag, batch-execution spans between), and
//! the co-simulation emits publication spans whose activation is causally
//! linked — a Perfetto *flow* arrow — to the first batch served on the
//! new version: the cross-plane edge nothing else can see.
//!
//! Design constraints, in order:
//!
//! 1. **Disabled must be free.**  [`TraceHandle`] is an
//!    `Option<Rc<RefCell<Tracer>>>`; every emission on a disabled handle
//!    is one `Option` discriminant check — no allocation, no `RefCell`
//!    traffic, no argument formatting (args are `Copy` stack values).
//!    The reduce micro-bench pins this (<2% on the merge hot loop).
//! 2. **Deterministic.**  Events carry virtual-clock milliseconds and a
//!    monotone sequence number; emission order is the single-threaded
//!    simulation's execution order, exports iterate only ordered
//!    structures — the same seed and config produce *byte-identical*
//!    exports (pinned by `tests/integration_trace.rs`).
//! 3. **Bounded.**  Events land in a ring buffer; at capacity the oldest
//!    event is dropped and counted, so tracing a huge run degrades to a
//!    suffix window instead of unbounded memory.
//!
//! Track convention: `pid` is the [`crate::serve::ProjectId`] (0 for
//! single-project training runs), `tid` 0 is the project's master, 1 its
//! publication pipeline, 1000+w training worker `w`, 2000+s serving
//! shard `s`.

pub mod analyze;
mod export;
pub mod report;

use std::cell::RefCell;
use std::collections::{BTreeSet, VecDeque};
use std::rc::Rc;

/// Default ring-buffer capacity (events).
pub const DEFAULT_CAPACITY: usize = 1 << 20;

/// One timeline row: a (process, thread) pair in the Chrome trace model.
/// `pid` names the project, `tid` the actor within it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Track {
    pub pid: u32,
    pub tid: u32,
}

impl Track {
    /// The project's training master (tid 0).
    pub fn master(pid: u32) -> Self {
        Self { pid, tid: 0 }
    }

    /// The project's snapshot-publication pipeline (tid 1).
    pub fn publisher(pid: u32) -> Self {
        Self { pid, tid: 1 }
    }

    /// Training worker `w` of the project (tid 1000+w).
    pub fn worker(pid: u32, w: u32) -> Self {
        Self { pid, tid: 1000 + w }
    }

    /// Serving shard `s` handling the project's traffic (tid 2000+s).
    pub fn shard(pid: u32, s: u32) -> Self {
        Self { pid, tid: 2000 + s }
    }

    /// Human thread name for exports (`M` metadata / CSV).
    pub fn thread_name(tid: u32) -> String {
        match tid {
            0 => "master".into(),
            1 => "publications".into(),
            t if t >= 2000 => format!("shard {}", t - 2000),
            t if t >= 1000 => format!("worker {}", t - 1000),
            t => format!("track {t}"),
        }
    }
}

/// A span/instant argument value.  All-`Copy` so disabled call sites
/// build their argument slices on the stack for free.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArgValue {
    U64(u64),
    F64(f64),
    Str(&'static str),
}

impl std::fmt::Display for ArgValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgValue::U64(v) => write!(f, "{v}"),
            ArgValue::F64(v) => write!(f, "{v}"),
            ArgValue::Str(v) => write!(f, "{v}"),
        }
    }
}

/// Event shape, mapping 1:1 onto Chrome trace-event phases.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Complete span (`ph: "X"`): starts at `ts`, lasts `dur_ms`.
    Span { dur_ms: f64 },
    /// Nestable async begin (`ph: "b"`), matched by (pid, cat, id).
    AsyncBegin { id: u64 },
    /// Nestable async end (`ph: "e"`).
    AsyncEnd { id: u64 },
    /// Instant (`ph: "i"`, thread scope).
    Instant,
    /// Flow start (`ph: "s"`), matched to its finish by (cat, id).
    FlowStart { id: u64 },
    /// Flow finish (`ph: "f"`, binding point `"e"`).
    FlowFinish { id: u64 },
    /// Counter sample (`ph: "C"`): the series values ride in `args`, one
    /// `F64` entry per series key — Perfetto renders each (track, name)
    /// as a stacked counter track.
    Counter,
}

/// One trace event on the virtual clock.
#[derive(Debug, Clone)]
pub struct Event {
    /// Monotone emission sequence (survives ring-buffer drops: the first
    /// retained event's `seq` equals the drop count).
    pub seq: u64,
    /// Virtual-clock timestamp (ms).
    pub ts_ms: f64,
    pub track: Track,
    /// Category: `train`, `serve` or `publish`.
    pub cat: &'static str,
    pub name: &'static str,
    pub kind: EventKind,
    pub args: Vec<(&'static str, ArgValue)>,
}

/// The recording state behind an enabled [`TraceHandle`].
#[derive(Debug)]
pub struct Tracer {
    events: VecDeque<Event>,
    capacity: usize,
    dropped: u64,
    seq: u64,
    /// Async begins minus ends — 0 once every request span closed.
    open_async: i64,
    /// Flow ids started but not yet finished.  `flow_end` on an id not in
    /// this set is a no-op, so serve code can emit finishes
    /// unconditionally: runs without publications produce no flow noise,
    /// and only the *first* finish per id emits (the causal edge is
    /// "publication → first service on that version").
    flows: BTreeSet<u64>,
}

impl Tracer {
    fn new(capacity: usize) -> Self {
        Self {
            events: VecDeque::new(),
            capacity: capacity.max(1),
            dropped: 0,
            seq: 0,
            open_async: 0,
            flows: BTreeSet::new(),
        }
    }

    fn push(&mut self, ts_ms: f64, track: Track, cat: &'static str, name: &'static str, kind: EventKind, args: &[(&'static str, ArgValue)]) {
        if self.events.len() >= self.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        match kind {
            EventKind::AsyncBegin { .. } => self.open_async += 1,
            EventKind::AsyncEnd { .. } => self.open_async -= 1,
            _ => {}
        }
        self.events.push_back(Event {
            seq: self.seq,
            ts_ms,
            track,
            cat,
            name,
            kind,
            args: args.to_vec(),
        });
        self.seq += 1;
    }

    fn events(&self) -> &VecDeque<Event> {
        &self.events
    }
}

/// A cheap, cloneable handle to one shared tracer — or to nothing.
///
/// Every plane (training masters, the serving engine, the cosim driver)
/// holds a clone; `off()` handles make every emission a no-op behind a
/// single `Option` check.  Single-threaded by design (the discrete-event
/// simulation is), hence `Rc`.
#[derive(Debug, Clone)]
pub struct TraceHandle(Option<Rc<RefCell<Tracer>>>);

impl Default for TraceHandle {
    fn default() -> Self {
        Self::off()
    }
}

impl TraceHandle {
    /// The disabled handle: every emission is a no-op.
    pub fn off() -> Self {
        Self(None)
    }

    /// A recording handle with the default ring capacity.
    pub fn recording() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }

    /// A recording handle with an explicit ring capacity (events).
    pub fn with_capacity(capacity: usize) -> Self {
        Self(Some(Rc::new(RefCell::new(Tracer::new(capacity)))))
    }

    pub fn is_on(&self) -> bool {
        self.0.is_some()
    }

    /// Complete span `[t0_ms, t1_ms]` on `track`.
    pub fn span(&self, track: Track, cat: &'static str, name: &'static str, t0_ms: f64, t1_ms: f64, args: &[(&'static str, ArgValue)]) {
        if let Some(t) = &self.0 {
            t.borrow_mut().push(
                t0_ms,
                track,
                cat,
                name,
                EventKind::Span { dur_ms: (t1_ms - t0_ms).max(0.0) },
                args,
            );
        }
    }

    /// Instant event at `ts_ms`.
    pub fn instant(&self, track: Track, cat: &'static str, name: &'static str, ts_ms: f64, args: &[(&'static str, ArgValue)]) {
        if let Some(t) = &self.0 {
            t.borrow_mut().push(ts_ms, track, cat, name, EventKind::Instant, args);
        }
    }

    /// Open an async lifecycle (e.g. a request), matched by (pid, cat, id).
    pub fn async_begin(&self, track: Track, cat: &'static str, name: &'static str, id: u64, ts_ms: f64, args: &[(&'static str, ArgValue)]) {
        if let Some(t) = &self.0 {
            t.borrow_mut().push(ts_ms, track, cat, name, EventKind::AsyncBegin { id }, args);
        }
    }

    /// Close an async lifecycle.  The outcome tag rides in `args`.
    pub fn async_end(&self, track: Track, cat: &'static str, name: &'static str, id: u64, ts_ms: f64, args: &[(&'static str, ArgValue)]) {
        if let Some(t) = &self.0 {
            t.borrow_mut().push(ts_ms, track, cat, name, EventKind::AsyncEnd { id }, args);
        }
    }

    /// Start a flow edge (arrow source).  A second start on a live id is
    /// ignored.
    pub fn flow_start(&self, track: Track, cat: &'static str, name: &'static str, id: u64, ts_ms: f64) {
        if let Some(t) = &self.0 {
            let mut t = t.borrow_mut();
            if t.flows.insert(id) {
                t.push(ts_ms, track, cat, name, EventKind::FlowStart { id }, &[]);
            }
        }
    }

    /// Finish a flow edge (arrow target).  No-op unless `id` has a live
    /// start; only the first finish per id emits.
    pub fn flow_end(&self, track: Track, cat: &'static str, name: &'static str, id: u64, ts_ms: f64) {
        if let Some(t) = &self.0 {
            let mut t = t.borrow_mut();
            if t.flows.remove(&id) {
                t.push(ts_ms, track, cat, name, EventKind::FlowFinish { id }, &[]);
            }
        }
    }

    /// Sample a counter series at `ts_ms`.  `name` follows the
    /// `<plane>/<resource>` convention (e.g. `serve/queue`,
    /// `publish/egress`); each `(key, value)` pair in `series` becomes one
    /// line of the Perfetto counter track.  Keys should arrive in a fixed
    /// order per name — the export sorts them anyway, so equal-seed runs
    /// stay byte-identical.
    pub fn counter(&self, track: Track, name: &'static str, ts_ms: f64, series: &[(&'static str, f64)]) {
        if let Some(t) = &self.0 {
            let args: Vec<(&'static str, ArgValue)> =
                series.iter().map(|&(k, v)| (k, ArgValue::F64(v))).collect();
            t.borrow_mut().push(ts_ms, track, "counter", name, EventKind::Counter, &args);
        }
    }

    /// Retained events (0 when disabled).
    pub fn len(&self) -> usize {
        self.0.as_ref().map_or(0, |t| t.borrow().events().len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events dropped by the ring bound.
    pub fn dropped(&self) -> u64 {
        self.0.as_ref().map_or(0, |t| t.borrow().dropped)
    }

    /// Async begins minus ends — 0 once every request lifecycle closed.
    pub fn open_async(&self) -> i64 {
        self.0.as_ref().map_or(0, |t| t.borrow().open_async)
    }

    /// Clone out the retained events (tests, custom exporters).
    pub fn snapshot(&self) -> Vec<Event> {
        self.0
            .as_ref()
            .map_or_else(Vec::new, |t| t.borrow().events().iter().cloned().collect())
    }

    /// Chrome/Perfetto trace-event JSON (load via `chrome://tracing` or
    /// <https://ui.perfetto.dev>).  Deterministic: object keys are sorted,
    /// events are in emission order, timestamps are virtual-clock µs.
    pub fn export_chrome_json(&self) -> String {
        match &self.0 {
            Some(t) => export::chrome_json(&t.borrow()),
            None => export::chrome_json(&Tracer::new(1)),
        }
    }

    /// Flat CSV export (one row per event) for ad-hoc analysis.
    pub fn export_csv(&self) -> String {
        match &self.0 {
            Some(t) => export::csv(&t.borrow()),
            None => export::csv(&Tracer::new(1)),
        }
    }

    /// Write both exports: Chrome JSON at `path`, CSV at `{path}.csv`.
    pub fn write(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.export_chrome_json())
            .map_err(|e| format!("write {path}: {e}"))?;
        let csv_path = format!("{path}.csv");
        std::fs::write(&csv_path, self.export_csv())
            .map_err(|e| format!("write {csv_path}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_is_inert() {
        let t = TraceHandle::off();
        t.span(Track::master(0), "train", "iteration", 0.0, 4.0, &[]);
        t.async_begin(Track::shard(0, 0), "serve", "request", 1, 0.0, &[]);
        t.flow_start(Track::publisher(0), "publish", "first-serve", 7, 0.0);
        t.flow_end(Track::publisher(0), "publish", "first-serve", 7, 1.0);
        assert!(!t.is_on());
        assert_eq!(t.len(), 0);
        assert_eq!(t.dropped(), 0);
        assert!(t.snapshot().is_empty());
    }

    #[test]
    fn ring_bound_drops_oldest_and_counts() {
        let t = TraceHandle::with_capacity(3);
        for i in 0..5u64 {
            t.instant(Track::master(0), "train", "tick", i as f64, &[("i", ArgValue::U64(i))]);
        }
        assert_eq!(t.len(), 3);
        assert_eq!(t.dropped(), 2);
        let events = t.snapshot();
        // Oldest two dropped; first retained seq equals the drop count.
        assert_eq!(events[0].seq, 2);
        assert_eq!(events.last().unwrap().seq, 4);
    }

    #[test]
    fn flow_end_without_start_is_a_no_op_and_first_finish_wins() {
        let t = TraceHandle::recording();
        t.flow_end(Track::shard(0, 0), "publish", "first-serve", 42, 1.0);
        assert_eq!(t.len(), 0, "finish without start must not emit");
        t.flow_start(Track::publisher(0), "publish", "first-serve", 42, 2.0);
        t.flow_start(Track::publisher(0), "publish", "first-serve", 42, 2.5);
        t.flow_end(Track::shard(0, 0), "publish", "first-serve", 42, 3.0);
        t.flow_end(Track::shard(0, 1), "publish", "first-serve", 42, 4.0);
        let kinds: Vec<EventKind> = t.snapshot().iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![EventKind::FlowStart { id: 42 }, EventKind::FlowFinish { id: 42 }],
            "exactly one start and one finish per id"
        );
    }

    #[test]
    fn async_balance_is_tracked() {
        let t = TraceHandle::recording();
        t.async_begin(Track::shard(0, 0), "serve", "request", 1, 0.0, &[]);
        t.async_begin(Track::shard(0, 0), "serve", "request", 2, 0.5, &[]);
        assert_eq!(t.open_async(), 2);
        t.async_end(Track::shard(0, 0), "serve", "request", 1, 1.0, &[("outcome", ArgValue::Str("served"))]);
        assert_eq!(t.open_async(), 1);
        t.async_end(Track::shard(0, 0), "serve", "request", 2, 1.5, &[("outcome", ArgValue::Str("shed"))]);
        assert_eq!(t.open_async(), 0);
    }

    #[test]
    fn chrome_export_shape_and_determinism() {
        let build = || {
            let t = TraceHandle::recording();
            t.span(
                Track::master(0),
                "train",
                "iteration",
                0.0,
                4000.0,
                &[("iteration", ArgValue::U64(0)), ("vectors", ArgValue::U64(128))],
            );
            t.async_begin(Track::shard(1, 2), "serve", "request", 9, 10.0, &[]);
            t.async_end(
                Track::shard(1, 2),
                "serve",
                "request",
                9,
                12.5,
                &[("outcome", ArgValue::Str("served"))],
            );
            t.flow_start(Track::publisher(1), "publish", "first-serve", 7, 11.0);
            t.flow_end(Track::shard(1, 2), "publish", "first-serve", 7, 12.0);
            t.export_chrome_json()
        };
        let json = build();
        assert_eq!(json, build(), "same emissions → byte-identical export");
        let doc = crate::json::parse(&json).unwrap();
        let events = doc.req_array("traceEvents").unwrap();
        // 5 emissions + metadata (2 processes + 3 tracks).
        assert_eq!(events.len(), 10);
        let phases: Vec<&str> = events
            .iter()
            .map(|e| e.req_str("ph").unwrap())
            .collect();
        assert_eq!(phases.iter().filter(|p| **p == "M").count(), 5);
        for ph in ["X", "b", "e", "s", "f"] {
            assert!(phases.contains(&ph), "missing phase {ph}");
        }
        // Span timestamps are µs: 4000 ms → 4_000_000 µs.
        let span = events.iter().find(|e| e.req_str("ph").unwrap() == "X").unwrap();
        assert_eq!(span.req_f64("dur").unwrap(), 4_000_000.0);
        assert_eq!(span.get("args").unwrap().req_f64("vectors").unwrap(), 128.0);
        // Flow finish carries the binding point.
        let f = events.iter().find(|e| e.req_str("ph").unwrap() == "f").unwrap();
        assert_eq!(f.req_str("bp").unwrap(), "e");
        assert_eq!(doc.req_str("displayTimeUnit").unwrap(), "ms");
    }

    #[test]
    fn counter_exports_as_c_phase_and_is_deterministic() {
        let build = || {
            let t = TraceHandle::recording();
            t.counter(Track::shard(0, 1), "serve/queue", 5.0, &[("depth", 3.0), ("in_flight", 8.0)]);
            t.counter(Track::publisher(0), "publish/egress", 6.5, &[("backlog_ms", 120.25)]);
            t.export_chrome_json()
        };
        let json = build();
        assert_eq!(json, build(), "same emissions → byte-identical export");
        let doc = crate::json::parse(&json).unwrap();
        let events = doc.req_array("traceEvents").unwrap();
        let counters: Vec<_> = events
            .iter()
            .filter(|e| e.req_str("ph").unwrap() == "C")
            .collect();
        assert_eq!(counters.len(), 2);
        let q = counters
            .iter()
            .find(|e| e.req_str("name").unwrap() == "serve/queue")
            .unwrap();
        // Counter timestamps are µs like every other phase.
        assert_eq!(q.req_f64("ts").unwrap(), 5_000.0);
        let args = q.get("args").unwrap();
        assert_eq!(args.req_f64("depth").unwrap(), 3.0);
        assert_eq!(args.req_f64("in_flight").unwrap(), 8.0);
    }

    #[test]
    fn counter_rides_the_csv_export() {
        let t = TraceHandle::recording();
        t.counter(Track::master(2), "train/pending-gradients", 8.0, &[("pending", 4.0)]);
        let csv = t.export_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2, "header + 1 counter row");
        assert!(lines[1].contains(",C,"), "phase column must be C: {}", lines[1]);
        assert!(lines[1].contains("train/pending-gradients"));
        assert!(lines[1].contains("pending=4"));
    }

    #[test]
    fn disabled_handle_ignores_counters() {
        let t = TraceHandle::off();
        t.counter(Track::master(0), "train/fleet", 0.0, &[("clients", 3.0)]);
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn csv_export_has_one_row_per_event() {
        let t = TraceHandle::recording();
        t.span(Track::worker(0, 3), "train", "compute", 1.0, 2.0, &[("examples", ArgValue::U64(5))]);
        t.instant(Track::master(0), "train", "broadcast", 2.0, &[]);
        let csv = t.export_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 events");
        assert_eq!(lines[0], "seq,ph,ts_ms,pid,tid,cat,name,id,dur_ms,args");
        assert!(lines[1].contains("compute") && lines[1].contains("examples=5"));
    }
}
