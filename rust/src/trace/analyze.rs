//! Trace analysis: flame rollups, critical paths and counter statistics
//! over a recorded trace — the "where did the virtual time go" half of
//! the observability plane.
//!
//! The tracer (PR 6) records *events*; this module turns them into
//! *attribution*:
//!
//! - **Flame rollup** — per (pid, tid, cat, name) wall-time vs self-time
//!   over the `X` spans, children subtracted from their enclosing span
//!   (the master's `reduce` span is self-time carved out of `iteration`).
//! - **Iteration critical path** — for each training iteration span, the
//!   longest causally-ordered chain that bounds it: the slowest merged
//!   worker's `compute → upload → ingest`, plus the sync-barrier
//!   remainder to the iteration's end.  By construction the segment sum
//!   equals the iteration's wall-time (the barrier closes the gap), so
//!   `coverage ≈ 1.0` is an internal consistency check, not an accident.
//! - **Request critical path** — for each served request lifecycle
//!   (async `b`/`e` pair), `queued → execute → reply` around the batch
//!   span that answered it; cache hits and coalesced waiters (no batch of
//!   their own) collapse to a single `direct` segment.
//! - **Counter statistics** — per (pid, tid, name, series key):
//!   min / mean / max and the *time-weighted* average (a queue that
//!   spikes to 50 for 1 ms and sits at 2 for a second is not "mean 26").
//! - **Saturation verdicts** — per plane and project, which resource
//!   dominates the critical path ("merge-bound", "queue-bound", …) and
//!   whether the egress budget carried a backlog.
//!
//! Input is either an in-memory [`super::Tracer`] snapshot
//! ([`TraceAnalysis::from_events`]) or a previously exported CSV
//! ([`TraceAnalysis::from_csv`]) — the CLI's `trace-report` subcommand
//! uses the latter, `--report` after a run the former.  Everything is
//! ordered (`BTreeMap`, explicit sorts with `total_cmp`): equal traces
//! produce byte-identical reports.

use std::collections::BTreeMap;

use super::{Event, EventKind};

/// Timestamp slop when chaining spans whose boundaries were computed by
/// the same f64 arithmetic (ms).
const EPS_MS: f64 = 1e-6;

/// A trace event normalized away from the emission-side types: owned
/// strings, explicit phase code — the common shape of a `Tracer`
/// snapshot and a parsed CSV row.
#[derive(Debug, Clone, PartialEq)]
pub struct NormEvent {
    /// Chrome phase code: `X b e i s f C`.
    pub ph: char,
    pub ts_ms: f64,
    pub pid: u32,
    pub tid: u32,
    pub cat: String,
    pub name: String,
    pub id: Option<u64>,
    pub dur_ms: Option<f64>,
    /// `key=value` argument pairs (values kept as strings; counter series
    /// parse them as f64).
    pub args: Vec<(String, String)>,
}

impl NormEvent {
    fn end_ms(&self) -> f64 {
        self.ts_ms + self.dur_ms.unwrap_or(0.0)
    }

    fn arg_f64(&self, key: &str) -> Option<f64> {
        self.args
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.parse::<f64>().ok())
    }
}

/// One flame-rollup row: how much wall time a (track, cat, name) family
/// of spans covered, and how much of it was *self* time (nested child
/// spans on the same track subtracted).
#[derive(Debug, Clone, PartialEq)]
pub struct FlameRow {
    pub pid: u32,
    pub tid: u32,
    pub cat: String,
    pub name: String,
    pub count: u64,
    pub wall_ms: f64,
    pub self_ms: f64,
}

/// One named segment of a critical path.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    pub name: &'static str,
    pub dur_ms: f64,
}

/// The critical path of one training iteration: the slowest merged
/// worker's chain plus the barrier remainder.  `segments` sum to
/// `wall_ms` by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct IterationPath {
    pub pid: u32,
    /// Iteration index when the span carried it as an arg.
    pub iteration: Option<u64>,
    pub t0_ms: f64,
    pub wall_ms: f64,
    pub segments: Vec<Segment>,
}

impl IterationPath {
    /// Sum of the path's segment durations (≈ `wall_ms`).
    pub fn path_ms(&self) -> f64 {
        self.segments.iter().map(|s| s.dur_ms).sum()
    }
}

/// The critical path of one served request lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestPath {
    pub pid: u32,
    pub id: u64,
    pub begin_ms: f64,
    pub end_ms: f64,
    pub segments: Vec<Segment>,
}

/// Statistics over one counter series: (pid, tid, counter name, key).
#[derive(Debug, Clone, PartialEq)]
pub struct CounterStat {
    pub pid: u32,
    pub tid: u32,
    pub name: String,
    pub key: String,
    pub n: u64,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    /// Time-weighted average: each sample holds until the next one
    /// (step interpolation); a single sample is its own average.
    pub twa: f64,
}

/// A per-resource saturation verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct Verdict {
    /// `train p0`, `serve p1`, `publish p0` — plane + project.
    pub scope: String,
    /// The short verdict: `merge-bound`, `queue-bound`, `egress idle`, …
    pub verdict: String,
    /// Supporting shares / numbers.
    pub detail: String,
}

/// The full analysis of one trace.
#[derive(Debug, Clone, Default)]
pub struct TraceAnalysis {
    pub flame: Vec<FlameRow>,
    pub iterations: Vec<IterationPath>,
    pub requests: Vec<RequestPath>,
    pub counters: Vec<CounterStat>,
    pub verdicts: Vec<Verdict>,
}

impl TraceAnalysis {
    /// Analyze an in-memory tracer snapshot (`TraceHandle::snapshot()`).
    pub fn from_events(events: &[Event]) -> Self {
        let norm: Vec<NormEvent> = events.iter().map(normalize).collect();
        analyze(&norm)
    }

    /// Analyze a previously exported CSV (`<trace>.csv`,
    /// `seq,ph,ts_ms,pid,tid,cat,name,id,dur_ms,args`).
    pub fn from_csv(csv: &str) -> Result<Self, String> {
        let mut norm = Vec::new();
        for (lineno, line) in csv.lines().enumerate() {
            if lineno == 0 || line.is_empty() {
                continue; // header
            }
            norm.push(parse_csv_row(line).map_err(|e| format!("line {}: {e}", lineno + 1))?);
        }
        Ok(analyze(&norm))
    }
}

fn normalize(e: &Event) -> NormEvent {
    let (ph, id, dur_ms) = match e.kind {
        EventKind::Span { dur_ms } => ('X', None, Some(dur_ms)),
        EventKind::AsyncBegin { id } => ('b', Some(id), None),
        EventKind::AsyncEnd { id } => ('e', Some(id), None),
        EventKind::Instant => ('i', None, None),
        EventKind::FlowStart { id } => ('s', Some(id), None),
        EventKind::FlowFinish { id } => ('f', Some(id), None),
        EventKind::Counter => ('C', None, None),
    };
    NormEvent {
        ph,
        ts_ms: e.ts_ms,
        pid: e.track.pid,
        tid: e.track.tid,
        cat: e.cat.to_string(),
        name: e.name.to_string(),
        id,
        dur_ms,
        args: e
            .args
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect(),
    }
}

fn parse_csv_row(line: &str) -> Result<NormEvent, String> {
    // No exported field contains a comma (names/cats are static idents,
    // args join with ';'), so a bounded split is a full parse.
    let cols: Vec<&str> = line.splitn(10, ',').collect();
    if cols.len() != 10 {
        return Err(format!("expected 10 columns, got {}", cols.len()));
    }
    let ph = cols[1]
        .chars()
        .next()
        .ok_or_else(|| "empty phase".to_string())?;
    let ts_ms: f64 = cols[2].parse().map_err(|e| format!("ts_ms: {e}"))?;
    let pid: u32 = cols[3].parse().map_err(|e| format!("pid: {e}"))?;
    let tid: u32 = cols[4].parse().map_err(|e| format!("tid: {e}"))?;
    let id = if cols[7].is_empty() {
        None
    } else {
        Some(cols[7].parse::<u64>().map_err(|e| format!("id: {e}"))?)
    };
    let dur_ms = if cols[8].is_empty() {
        None
    } else {
        Some(cols[8].parse::<f64>().map_err(|e| format!("dur_ms: {e}"))?)
    };
    let args = if cols[9].is_empty() {
        Vec::new()
    } else {
        cols[9]
            .split(';')
            .map(|kv| match kv.split_once('=') {
                Some((k, v)) => Ok((k.to_string(), v.to_string())),
                None => Err(format!("malformed arg '{kv}'")),
            })
            .collect::<Result<Vec<_>, String>>()?
    };
    Ok(NormEvent {
        ph,
        ts_ms,
        pid,
        tid,
        cat: cols[5].to_string(),
        name: cols[6].to_string(),
        id,
        dur_ms,
        args,
    })
}

fn analyze(events: &[NormEvent]) -> TraceAnalysis {
    let flame = flame_rollup(events);
    let iterations = iteration_paths(events);
    let requests = request_paths(events);
    let counters = counter_stats(events);
    let verdicts = verdicts(&iterations, &requests, &counters);
    TraceAnalysis {
        flame,
        iterations,
        requests,
        counters,
        verdicts,
    }
}

// ---------------------------------------------------------------- flame

fn flame_rollup(events: &[NormEvent]) -> Vec<FlameRow> {
    // Group X spans per track, then walk each track's spans in
    // (start asc, end desc) order with a nesting stack: a span fully
    // inside the stack top is its child, and its duration comes out of
    // the parent's self-time.
    let mut by_track: BTreeMap<(u32, u32), Vec<&NormEvent>> = BTreeMap::new();
    for e in events.iter().filter(|e| e.ph == 'X') {
        by_track.entry((e.pid, e.tid)).or_default().push(e);
    }
    let mut rows: BTreeMap<(u32, u32, String, String), FlameRow> = BTreeMap::new();
    for ((pid, tid), mut spans) in by_track {
        spans.sort_by(|a, b| {
            a.ts_ms
                .total_cmp(&b.ts_ms)
                .then(b.end_ms().total_cmp(&a.end_ms()))
        });
        // Stack of (end_ms, row key) for open ancestors.
        let mut stack: Vec<(f64, (u32, u32, String, String))> = Vec::new();
        for s in spans {
            let dur = s.dur_ms.unwrap_or(0.0);
            while let Some((end, _)) = stack.last() {
                if *end <= s.ts_ms + EPS_MS {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some((_, parent_key)) = stack.last() {
                if let Some(parent) = rows.get_mut(parent_key) {
                    parent.self_ms -= dur;
                }
            }
            let key = (pid, tid, s.cat.clone(), s.name.clone());
            let row = rows.entry(key.clone()).or_insert_with(|| FlameRow {
                pid,
                tid,
                cat: s.cat.clone(),
                name: s.name.clone(),
                count: 0,
                wall_ms: 0.0,
                self_ms: 0.0,
            });
            row.count += 1;
            row.wall_ms += dur;
            row.self_ms += dur;
            stack.push((s.end_ms(), key));
        }
    }
    rows.into_values().collect()
}

// ------------------------------------------------- iteration critical path

fn iteration_paths(events: &[NormEvent]) -> Vec<IterationPath> {
    let mut paths = Vec::new();
    // Worker-plane spans per pid, pre-sorted by start time.
    let mut worker_spans: BTreeMap<u32, Vec<&NormEvent>> = BTreeMap::new();
    for e in events.iter().filter(|e| {
        e.ph == 'X' && e.cat == "train" && (1000..2000).contains(&e.tid)
    }) {
        worker_spans.entry(e.pid).or_default().push(e);
    }
    for e in events
        .iter()
        .filter(|e| e.ph == 'X' && e.cat == "train" && e.name == "iteration")
    {
        let t0 = e.ts_ms;
        let wall = e.dur_ms.unwrap_or(0.0);
        let t1 = t0 + wall;
        let empty = Vec::new();
        let workers = worker_spans.get(&e.pid).unwrap_or(&empty);
        // The chain that bounds the iteration ends at the *latest-ending*
        // ingest inside the window (the slowest merged submission — the
        // §3.3d barrier waits exactly for it).
        let ingest = workers
            .iter()
            .filter(|w| {
                w.name == "ingest" && w.ts_ms >= t0 - EPS_MS && w.end_ms() <= t1 + EPS_MS
            })
            .max_by(|a, b| {
                a.end_ms()
                    .total_cmp(&b.end_ms())
                    .then(a.ts_ms.total_cmp(&b.ts_ms))
            });
        let mut segments = Vec::new();
        if let Some(ing) = ingest {
            // Walk the chain backwards on the same worker track:
            // upload ends where ingest starts, compute ends where upload
            // starts.  A carryover ingest (started at t0) has no chain.
            let upload = workers.iter().find(|w| {
                w.name == "upload"
                    && w.tid == ing.tid
                    && (w.end_ms() - ing.ts_ms).abs() <= EPS_MS
            });
            let compute = upload.and_then(|u| {
                workers.iter().find(|w| {
                    w.name == "compute"
                        && w.tid == u.tid
                        && (w.end_ms() - u.ts_ms).abs() <= EPS_MS
                })
            });
            if let Some(c) = compute {
                segments.push(Segment {
                    name: "compute",
                    dur_ms: c.dur_ms.unwrap_or(0.0),
                });
            }
            if let Some(u) = upload {
                segments.push(Segment {
                    name: "upload",
                    dur_ms: u.dur_ms.unwrap_or(0.0),
                });
            }
            // Lead-in the chain does not explain (e.g. an upload with no
            // matching compute span): charge it explicitly so the path
            // still sums to the wall-time.
            let chain_start = compute
                .or(upload)
                .map_or(ing.ts_ms, |first| first.ts_ms);
            if chain_start > t0 + EPS_MS {
                segments.insert(
                    0,
                    Segment {
                        name: "pre-chain",
                        dur_ms: chain_start - t0,
                    },
                );
            }
            segments.push(Segment {
                name: "ingest",
                dur_ms: ing.dur_ms.unwrap_or(0.0),
            });
            let barrier = t1 - ing.end_ms();
            if barrier > EPS_MS {
                segments.push(Segment {
                    name: "barrier",
                    dur_ms: barrier,
                });
            }
        } else if wall > 0.0 {
            // No merged work this iteration: the whole window is the
            // iteration floor / barrier.
            segments.push(Segment {
                name: "barrier",
                dur_ms: wall,
            });
        }
        paths.push(IterationPath {
            pid: e.pid,
            iteration: e.arg_f64("iteration").map(|v| v as u64),
            t0_ms: t0,
            wall_ms: wall,
            segments,
        });
    }
    paths.sort_by(|a, b| a.pid.cmp(&b.pid).then(a.t0_ms.total_cmp(&b.t0_ms)));
    paths
}

// -------------------------------------------------- request critical path

fn request_paths(events: &[NormEvent]) -> Vec<RequestPath> {
    // Pair async begins/ends by (pid, id) — the tracer's matching rule.
    let mut begins: BTreeMap<(u32, u64), &NormEvent> = BTreeMap::new();
    let mut batch_spans: BTreeMap<(u32, u32), Vec<&NormEvent>> = BTreeMap::new();
    for e in events
        .iter()
        .filter(|e| e.ph == 'X' && e.cat == "serve" && e.name == "batch")
    {
        batch_spans.entry((e.pid, e.tid)).or_default().push(e);
    }
    let mut paths = Vec::new();
    for e in events.iter().filter(|e| e.cat == "serve" && e.name == "request") {
        match e.ph {
            'b' => {
                if let Some(id) = e.id {
                    begins.entry((e.pid, id)).or_insert(e);
                }
            }
            'e' => {
                let Some(id) = e.id else { continue };
                let Some(begin) = begins.remove(&(e.pid, id)) else {
                    continue;
                };
                let empty = Vec::new();
                let batches = batch_spans.get(&(e.pid, e.tid)).unwrap_or(&empty);
                // The batch that answered: latest-ending batch span on
                // this shard track inside the request's lifetime.
                let batch = batches
                    .iter()
                    .filter(|b| {
                        b.ts_ms >= begin.ts_ms - EPS_MS && b.end_ms() <= e.ts_ms + EPS_MS
                    })
                    .max_by(|a, b| a.end_ms().total_cmp(&b.end_ms()));
                let segments = match batch {
                    Some(b) => vec![
                        Segment {
                            name: "queued",
                            dur_ms: (b.ts_ms - begin.ts_ms).max(0.0),
                        },
                        Segment {
                            name: "execute",
                            dur_ms: b.dur_ms.unwrap_or(0.0),
                        },
                        Segment {
                            name: "reply",
                            dur_ms: (e.ts_ms - b.end_ms()).max(0.0),
                        },
                    ],
                    // Cache hit / coalesced / shed: no batch of its own.
                    None => vec![Segment {
                        name: "direct",
                        dur_ms: e.ts_ms - begin.ts_ms,
                    }],
                };
                paths.push(RequestPath {
                    pid: e.pid,
                    id,
                    begin_ms: begin.ts_ms,
                    end_ms: e.ts_ms,
                    segments,
                });
            }
            _ => {}
        }
    }
    paths.sort_by(|a, b| {
        a.pid
            .cmp(&b.pid)
            .then(a.begin_ms.total_cmp(&b.begin_ms))
            .then(a.id.cmp(&b.id))
    });
    paths
}

// ------------------------------------------------------------- counters

fn counter_stats(events: &[NormEvent]) -> Vec<CounterStat> {
    // Samples per (pid, tid, name, key), in emission (= time) order.
    let mut series: BTreeMap<(u32, u32, String, String), Vec<(f64, f64)>> = BTreeMap::new();
    for e in events.iter().filter(|e| e.ph == 'C') {
        for (k, v) in &e.args {
            if let Ok(val) = v.parse::<f64>() {
                series
                    .entry((e.pid, e.tid, e.name.clone(), k.clone()))
                    .or_default()
                    .push((e.ts_ms, val));
            }
        }
    }
    series
        .into_iter()
        .map(|((pid, tid, name, key), samples)| {
            let n = samples.len() as u64;
            let mut min = f64::INFINITY;
            let mut max = f64::NEG_INFINITY;
            let mut sum = 0.0;
            for &(_, v) in &samples {
                min = min.min(v);
                max = max.max(v);
                sum += v;
            }
            let mean = sum / n as f64;
            // Step-interpolated time-weighted average: each value holds
            // until the next sample.  Degenerate spans (one sample, or
            // all samples at one instant) fall back to the plain mean.
            let span = samples.last().unwrap().0 - samples[0].0;
            let twa = if span > 0.0 {
                let mut acc = 0.0;
                for w in samples.windows(2) {
                    acc += w[0].1 * (w[1].0 - w[0].0);
                }
                acc / span
            } else {
                mean
            };
            CounterStat {
                pid,
                tid,
                name,
                key,
                n,
                min,
                max,
                mean,
                twa,
            }
        })
        .collect()
}

// ------------------------------------------------------------- verdicts

fn segment_totals(paths: &[&[Segment]]) -> BTreeMap<&'static str, f64> {
    let mut totals = BTreeMap::new();
    for segs in paths {
        for s in *segs {
            *totals.entry(s.name).or_insert(0.0) += s.dur_ms;
        }
    }
    totals
}

fn dominant(totals: &BTreeMap<&'static str, f64>) -> Option<(&'static str, f64, f64)> {
    let sum: f64 = totals.values().sum();
    if sum <= 0.0 {
        return None;
    }
    totals
        .iter()
        .max_by(|a, b| a.1.total_cmp(b.1).then(a.0.cmp(b.0)))
        .map(|(name, ms)| (*name, *ms, ms / sum))
}

fn share_detail(totals: &BTreeMap<&'static str, f64>) -> String {
    let sum: f64 = totals.values().sum();
    totals
        .iter()
        .map(|(name, ms)| format!("{name} {:.1}%", 100.0 * ms / sum.max(1e-12)))
        .collect::<Vec<_>>()
        .join(", ")
}

fn verdicts(
    iterations: &[IterationPath],
    requests: &[RequestPath],
    counters: &[CounterStat],
) -> Vec<Verdict> {
    let mut out = Vec::new();
    // Training: which chain segment dominates each project's iterations.
    let mut train_pids: Vec<u32> = iterations.iter().map(|p| p.pid).collect();
    train_pids.dedup();
    for pid in train_pids {
        let paths: Vec<&[Segment]> = iterations
            .iter()
            .filter(|p| p.pid == pid)
            .map(|p| p.segments.as_slice())
            .collect();
        let totals = segment_totals(&paths);
        if let Some((name, _, share)) = dominant(&totals) {
            let verdict = match name {
                "compute" => "compute-bound",
                "upload" => "wire-bound",
                "ingest" => "merge-bound",
                "barrier" => "clock-bound",
                _ => "mixed",
            };
            out.push(Verdict {
                scope: format!("train p{pid}"),
                verdict: format!("{verdict} ({:.1}% of critical path)", 100.0 * share),
                detail: share_detail(&totals),
            });
        }
    }
    // Serving: queued vs execute vs reply across each project's requests,
    // cross-checked against the queue-depth counter and its fair-share cap.
    let mut serve_pids: Vec<u32> = requests.iter().map(|p| p.pid).collect();
    serve_pids.dedup();
    for pid in serve_pids {
        let paths: Vec<&[Segment]> = requests
            .iter()
            .filter(|p| p.pid == pid)
            .map(|p| p.segments.as_slice())
            .collect();
        let totals = segment_totals(&paths);
        if let Some((name, _, share)) = dominant(&totals) {
            let verdict = match name {
                "queued" => "queue-bound",
                "execute" => "compute-bound",
                "reply" => "wire-bound",
                "direct" => "cache-served",
                _ => "mixed",
            };
            let depth_max = counters
                .iter()
                .filter(|c| c.pid == pid && c.name == "serve/queue" && c.key == "depth")
                .map(|c| c.max)
                .fold(f64::NEG_INFINITY, f64::max);
            let cap_min = counters
                .iter()
                .filter(|c| c.pid == pid && c.name == "serve/fair-share-cap" && c.key == "cap")
                .map(|c| c.min)
                .fold(f64::INFINITY, f64::min);
            let mut detail = share_detail(&totals);
            if depth_max.is_finite() {
                detail.push_str(&format!("; queue depth max {depth_max:.0}"));
                if cap_min.is_finite() && depth_max + 1.0 >= cap_min {
                    detail.push_str(&format!(" (saturates fair-share cap {cap_min:.0})"));
                }
            }
            out.push(Verdict {
                scope: format!("serve p{pid}"),
                verdict: format!("{verdict} ({:.1}% of request time)", 100.0 * share),
                detail,
            });
        }
    }
    // Publication: did the shared egress link ever carry a backlog?
    for c in counters
        .iter()
        .filter(|c| c.name == "publish/egress" && c.key == "backlog_ms")
    {
        let (verdict, detail) = if c.max > EPS_MS {
            (
                format!("egress-backlogged (peak {:.1} ms)", c.max),
                format!(
                    "backlog twa {:.1} ms over {} publications",
                    c.twa, c.n
                ),
            )
        } else {
            (
                "egress idle".to_string(),
                format!("{} publications, no queued transfer", c.n),
            )
        };
        out.push(Verdict {
            scope: format!("publish p{}", c.pid),
            verdict,
            detail,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{TraceHandle, Track};

    /// Hand-built synthetic trace with a known critical path:
    /// iteration [0, 100]; worker 3's chain compute [0,30] → upload
    /// [30,50] → ingest [50,90]; reduce [0,90] nested in the iteration;
    /// barrier remainder 10.
    fn synthetic() -> TraceHandle {
        let t = TraceHandle::recording();
        let m = Track::master(0);
        let w = Track::worker(0, 3);
        t.span(m, "train", "iteration", 0.0, 100.0, &[]);
        t.span(m, "train", "reduce", 0.0, 90.0, &[]);
        t.span(w, "train", "compute", 0.0, 30.0, &[]);
        t.span(w, "train", "upload", 30.0, 50.0, &[]);
        t.span(w, "train", "ingest", 50.0, 90.0, &[]);
        // A faster worker that is NOT the critical chain.
        let w2 = Track::worker(0, 4);
        t.span(w2, "train", "compute", 0.0, 10.0, &[]);
        t.span(w2, "train", "upload", 10.0, 15.0, &[]);
        t.span(w2, "train", "ingest", 15.0, 40.0, &[]);
        t
    }

    #[test]
    fn iteration_critical_path_sums_to_wall_time() {
        let t = synthetic();
        let a = TraceAnalysis::from_events(&t.snapshot());
        assert_eq!(a.iterations.len(), 1);
        let p = &a.iterations[0];
        assert_eq!(p.wall_ms, 100.0);
        let names: Vec<&str> = p.segments.iter().map(|s| s.name).collect();
        assert_eq!(names, vec!["compute", "upload", "ingest", "barrier"]);
        let durs: Vec<f64> = p.segments.iter().map(|s| s.dur_ms).collect();
        assert_eq!(durs, vec![30.0, 20.0, 40.0, 10.0]);
        assert!((p.path_ms() - p.wall_ms).abs() < 1e-9);
    }

    #[test]
    fn carryover_ingest_has_no_chain_but_still_covers() {
        // Ingest starting at t0 (offset 0 = carried-over gradient): the
        // path is ingest + barrier and still sums to the wall time.
        let t = TraceHandle::recording();
        t.span(Track::master(0), "train", "iteration", 0.0, 50.0, &[]);
        t.span(Track::worker(0, 1), "train", "ingest", 0.0, 35.0, &[]);
        let a = TraceAnalysis::from_events(&t.snapshot());
        let p = &a.iterations[0];
        let names: Vec<&str> = p.segments.iter().map(|s| s.name).collect();
        assert_eq!(names, vec!["ingest", "barrier"]);
        assert!((p.path_ms() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn empty_iteration_is_all_barrier() {
        let t = TraceHandle::recording();
        t.span(Track::master(2), "train", "iteration", 10.0, 14.0, &[]);
        let a = TraceAnalysis::from_events(&t.snapshot());
        let p = &a.iterations[0];
        assert_eq!(p.pid, 2);
        assert_eq!(p.segments.len(), 1);
        assert_eq!(p.segments[0].name, "barrier");
        assert!((p.path_ms() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn flame_subtracts_children_from_self_time() {
        let t = synthetic();
        let a = TraceAnalysis::from_events(&t.snapshot());
        let iter_row = a
            .flame
            .iter()
            .find(|r| r.name == "iteration")
            .expect("iteration row");
        assert_eq!(iter_row.count, 1);
        assert_eq!(iter_row.wall_ms, 100.0);
        // reduce [0,90] is nested: 100 − 90 self.
        assert!((iter_row.self_ms - 10.0).abs() < 1e-9);
        let reduce_row = a.flame.iter().find(|r| r.name == "reduce").unwrap();
        assert!((reduce_row.self_ms - 90.0).abs() < 1e-9);
    }

    #[test]
    fn request_path_decomposes_around_the_batch() {
        let t = TraceHandle::recording();
        let s = Track::shard(0, 1);
        t.async_begin(s, "serve", "request", 7, 10.0, &[]);
        t.span(s, "serve", "batch", 20.0, 35.0, &[]);
        t.async_end(s, "serve", "request", 7, 40.0, &[]);
        // A cache hit with no batch span of its own.
        t.async_begin(s, "serve", "request", 8, 41.0, &[]);
        t.async_end(s, "serve", "request", 8, 43.5, &[]);
        let a = TraceAnalysis::from_events(&t.snapshot());
        assert_eq!(a.requests.len(), 2);
        let p = &a.requests[0];
        assert_eq!(p.id, 7);
        let segs: Vec<(&str, f64)> = p.segments.iter().map(|s| (s.name, s.dur_ms)).collect();
        assert_eq!(segs, vec![("queued", 10.0), ("execute", 15.0), ("reply", 5.0)]);
        let hit = &a.requests[1];
        assert_eq!(hit.segments.len(), 1);
        assert_eq!(hit.segments[0].name, "direct");
        assert!((hit.segments[0].dur_ms - 2.5).abs() < 1e-9);
    }

    #[test]
    fn counter_stats_are_time_weighted() {
        let t = TraceHandle::recording();
        let s = Track::shard(0, 0);
        t.counter(s, "serve/queue", 0.0, &[("depth", 0.0)]);
        t.counter(s, "serve/queue", 10.0, &[("depth", 4.0)]);
        t.counter(s, "serve/queue", 20.0, &[("depth", 2.0)]);
        let a = TraceAnalysis::from_events(&t.snapshot());
        assert_eq!(a.counters.len(), 1);
        let c = &a.counters[0];
        assert_eq!((c.n, c.min, c.max), (3, 0.0, 4.0));
        assert!((c.mean - 2.0).abs() < 1e-9);
        // Step twa over [0,20]: 0·10 + 4·10 = 40 / 20 = 2.
        assert!((c.twa - 2.0).abs() < 1e-9);
        // Single-sample series fall back to the value itself.
        let t2 = TraceHandle::recording();
        t2.counter(s, "serve/cache", 5.0, &[("size", 7.0)]);
        let a2 = TraceAnalysis::from_events(&t2.snapshot());
        assert_eq!(a2.counters[0].twa, 7.0);
    }

    #[test]
    fn csv_round_trip_matches_in_memory_analysis() {
        let t = synthetic();
        t.counter(Track::shard(0, 0), "serve/queue", 1.0, &[("depth", 3.0)]);
        let from_mem = TraceAnalysis::from_events(&t.snapshot());
        let from_csv = TraceAnalysis::from_csv(&t.export_csv()).expect("csv parses");
        assert_eq!(from_mem.iterations, from_csv.iterations);
        assert_eq!(from_mem.flame, from_csv.flame);
        assert_eq!(from_mem.counters, from_csv.counters);
        assert_eq!(from_mem.verdicts, from_csv.verdicts);
    }

    #[test]
    fn verdict_names_the_dominant_segment() {
        let t = synthetic();
        let a = TraceAnalysis::from_events(&t.snapshot());
        let v = a
            .verdicts
            .iter()
            .find(|v| v.scope == "train p0")
            .expect("train verdict");
        // ingest (40 ms) dominates the 100 ms path.
        assert!(v.verdict.starts_with("merge-bound"), "{}", v.verdict);
        assert!(v.detail.contains("ingest 40.0%"), "{}", v.detail);
    }

    #[test]
    fn malformed_csv_is_an_error_not_a_panic() {
        assert!(TraceAnalysis::from_csv("seq,ph\n1,X\n").is_err());
        let ok = TraceAnalysis::from_csv("seq,ph,ts_ms,pid,tid,cat,name,id,dur_ms,args\n");
        assert!(ok.is_ok(), "header-only CSV is an empty trace");
        assert!(ok.unwrap().iterations.is_empty());
    }
}
