//! Trace exporters: Chrome/Perfetto trace-event JSON and flat CSV.
//!
//! Both exports are deterministic: events are written in emission order,
//! JSON object keys are sorted (the `json` module's `Object` is a
//! `BTreeMap`), and all timestamps are virtual-clock values — no wall
//! time anywhere.  Chrome's trace-event format wants microseconds; the
//! simulation runs in milliseconds, so `ts`/`dur` are `ms * 1000`.

use crate::json::{object, to_string, Value};

use super::{ArgValue, Event, EventKind, Track, Tracer};

fn num_u64(v: u64) -> Value {
    Value::Number(v as f64)
}

fn arg_value(v: ArgValue) -> Value {
    match v {
        ArgValue::U64(x) => num_u64(x),
        ArgValue::F64(x) => Value::Number(x),
        ArgValue::Str(s) => Value::String(s.to_string()),
    }
}

fn args_object(args: &[(&'static str, ArgValue)]) -> Value {
    object(args.iter().map(|(k, v)| (*k, arg_value(*v))).collect())
}

fn base_fields(e: &Event, ph: &str) -> Vec<(&'static str, Value)> {
    vec![
        ("ph", Value::String(ph.to_string())),
        ("pid", num_u64(e.track.pid as u64)),
        ("tid", num_u64(e.track.tid as u64)),
        ("ts", Value::Number(e.ts_ms * 1000.0)),
        ("cat", Value::String(e.cat.to_string())),
        ("name", Value::String(e.name.to_string())),
    ]
}

fn event_json(e: &Event) -> Value {
    match e.kind {
        EventKind::Span { dur_ms } => {
            let mut fields = base_fields(e, "X");
            fields.push(("dur", Value::Number(dur_ms * 1000.0)));
            fields.push(("args", args_object(&e.args)));
            object(fields)
        }
        EventKind::AsyncBegin { id } => {
            let mut fields = base_fields(e, "b");
            fields.push(("id", num_u64(id)));
            fields.push(("args", args_object(&e.args)));
            object(fields)
        }
        EventKind::AsyncEnd { id } => {
            let mut fields = base_fields(e, "e");
            fields.push(("id", num_u64(id)));
            fields.push(("args", args_object(&e.args)));
            object(fields)
        }
        EventKind::Instant => {
            let mut fields = base_fields(e, "i");
            fields.push(("s", Value::String("t".to_string())));
            fields.push(("args", args_object(&e.args)));
            object(fields)
        }
        EventKind::FlowStart { id } => {
            let mut fields = base_fields(e, "s");
            fields.push(("id", num_u64(id)));
            object(fields)
        }
        EventKind::FlowFinish { id } => {
            let mut fields = base_fields(e, "f");
            fields.push(("id", num_u64(id)));
            // Bind the arrow head to the *enclosing* slice at this
            // timestamp rather than the next one to begin.
            fields.push(("bp", Value::String("e".to_string())));
            object(fields)
        }
        EventKind::Counter => {
            // Perfetto draws one counter track per (pid, name); each args
            // key is a series line within it.
            let mut fields = base_fields(e, "C");
            fields.push(("args", args_object(&e.args)));
            object(fields)
        }
    }
}

fn metadata_event(pid: u32, tid: u32, name: &str, value: String) -> Value {
    object(vec![
        ("ph", Value::String("M".to_string())),
        ("pid", num_u64(pid as u64)),
        ("tid", num_u64(tid as u64)),
        ("name", Value::String(name.to_string())),
        ("args", object(vec![("name", Value::String(value))])),
    ])
}

/// Full Chrome trace-event document.
pub fn chrome_json(tracer: &Tracer) -> String {
    let mut events: Vec<Value> = Vec::with_capacity(tracer.events().len() + 16);
    // Name processes (projects) and threads (tracks) first so viewers
    // label rows before any data event references them.
    let tracks: std::collections::BTreeSet<Track> =
        tracer.events().iter().map(|e| e.track).collect();
    let pids: std::collections::BTreeSet<u32> = tracks.iter().map(|t| t.pid).collect();
    for pid in &pids {
        events.push(metadata_event(*pid, 0, "process_name", format!("project p{pid}")));
    }
    for track in &tracks {
        events.push(metadata_event(
            track.pid,
            track.tid,
            "thread_name",
            Track::thread_name(track.tid),
        ));
    }
    events.extend(tracer.events().iter().map(event_json));
    let doc = object(vec![
        ("displayTimeUnit", Value::String("ms".to_string())),
        ("traceEvents", Value::Array(events)),
    ]);
    to_string(&doc)
}

fn phase_code(kind: &EventKind) -> &'static str {
    match kind {
        EventKind::Span { .. } => "X",
        EventKind::AsyncBegin { .. } => "b",
        EventKind::AsyncEnd { .. } => "e",
        EventKind::Instant => "i",
        EventKind::FlowStart { .. } => "s",
        EventKind::FlowFinish { .. } => "f",
        EventKind::Counter => "C",
    }
}

/// Flat CSV (one row per event) for spreadsheet / pandas analysis.
pub fn csv(tracer: &Tracer) -> String {
    let mut out = String::from("seq,ph,ts_ms,pid,tid,cat,name,id,dur_ms,args\n");
    for e in tracer.events() {
        let (id, dur) = match e.kind {
            EventKind::Span { dur_ms } => (String::new(), format!("{dur_ms}")),
            EventKind::AsyncBegin { id }
            | EventKind::AsyncEnd { id }
            | EventKind::FlowStart { id }
            | EventKind::FlowFinish { id } => (format!("{id}"), String::new()),
            EventKind::Instant | EventKind::Counter => (String::new(), String::new()),
        };
        let args = e
            .args
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(";");
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{}\n",
            e.seq,
            phase_code(&e.kind),
            e.ts_ms,
            e.track.pid,
            e.track.tid,
            e.cat,
            e.name,
            id,
            dur,
            args
        ));
    }
    out
}
