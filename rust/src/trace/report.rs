//! Rendering for [`super::analyze::TraceAnalysis`]: a stable-ordered
//! text report (the `trace-report` / `--report` CLI output) and a JSON
//! document for downstream tooling.
//!
//! This module only *builds* strings/values — it never prints (the
//! determinism lint bans stray prints outside the CLI layer; `main.rs`
//! owns the terminal).  Ordering is inherited from the analyzer's sorted
//! outputs, so equal traces render byte-identical reports.

use std::collections::BTreeMap;

use crate::json::{object, to_string_pretty, Value};

use super::analyze::{IterationPath, RequestPath, Segment, TraceAnalysis};
use super::Track;

/// Per-(pid, segment-name) aggregate used by both renderers.
fn aggregate_segments<'a, I>(paths: I) -> BTreeMap<(u32, &'static str), (u64, f64)>
where
    I: Iterator<Item = (u32, &'a [Segment])>,
{
    let mut agg: BTreeMap<(u32, &'static str), (u64, f64)> = BTreeMap::new();
    for (pid, segs) in paths {
        for s in segs {
            let e = agg.entry((pid, s.name)).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += s.dur_ms;
        }
    }
    agg
}

/// The human-readable report.
pub fn render_text(a: &TraceAnalysis) -> String {
    let mut out = String::new();
    out.push_str("== trace report ==\n");

    // -- verdicts first: the "so what" line per resource.
    out.push_str("\n-- saturation verdicts --\n");
    if a.verdicts.is_empty() {
        out.push_str("(no spans to attribute)\n");
    }
    for v in &a.verdicts {
        out.push_str(&format!("{:<12} {}  [{}]\n", v.scope, v.verdict, v.detail));
    }

    // -- training critical paths.
    if !a.iterations.is_empty() {
        out.push_str("\n-- training critical paths (per iteration) --\n");
        out.push_str("pid iter t0_ms wall_ms path_ms coverage segments\n");
        for p in &a.iterations {
            let segs = p
                .segments
                .iter()
                .map(|s| format!("{}={:.3}", s.name, s.dur_ms))
                .collect::<Vec<_>>()
                .join(" ");
            out.push_str(&format!(
                "{} {} {:.3} {:.3} {:.3} {:.1}% {}\n",
                p.pid,
                p.iteration.map_or("-".to_string(), |i| i.to_string()),
                p.t0_ms,
                p.wall_ms,
                p.path_ms(),
                coverage_pct(p),
                segs,
            ));
        }
        let agg = aggregate_segments(
            a.iterations.iter().map(|p| (p.pid, p.segments.as_slice())),
        );
        out.push_str("training totals: ");
        out.push_str(&render_agg(&agg));
        out.push('\n');
    }

    // -- request critical paths, aggregated (one line per request would
    // drown the report at serving rates).
    if !a.requests.is_empty() {
        out.push_str("\n-- request critical paths (aggregate) --\n");
        let agg = aggregate_segments(
            a.requests.iter().map(|p| (p.pid, p.segments.as_slice())),
        );
        out.push_str(&format!("requests analyzed: {}\n", a.requests.len()));
        out.push_str("serving totals: ");
        out.push_str(&render_agg(&agg));
        out.push('\n');
    }

    // -- flame rollup.
    if !a.flame.is_empty() {
        out.push_str("\n-- flame rollup (X spans; self = children subtracted) --\n");
        out.push_str("pid tid(track) cat name count wall_ms self_ms\n");
        for r in &a.flame {
            out.push_str(&format!(
                "{} {}({}) {} {} {} {:.3} {:.3}\n",
                r.pid,
                r.tid,
                Track::thread_name(r.tid),
                r.cat,
                r.name,
                r.count,
                r.wall_ms,
                r.self_ms,
            ));
        }
    }

    // -- counter statistics.
    if !a.counters.is_empty() {
        out.push_str("\n-- counters (min/mean/max, twa = time-weighted avg) --\n");
        out.push_str("pid tid(track) name key n min mean max twa\n");
        for c in &a.counters {
            out.push_str(&format!(
                "{} {}({}) {} {} {} {:.3} {:.3} {:.3} {:.3}\n",
                c.pid,
                c.tid,
                Track::thread_name(c.tid),
                c.name,
                c.key,
                c.n,
                c.min,
                c.mean,
                c.max,
                c.twa,
            ));
        }
    }
    out
}

fn coverage_pct(p: &IterationPath) -> f64 {
    if p.wall_ms <= 0.0 {
        return 100.0;
    }
    100.0 * p.path_ms() / p.wall_ms
}

fn render_agg(agg: &BTreeMap<(u32, &'static str), (u64, f64)>) -> String {
    agg.iter()
        .map(|((pid, name), (n, ms))| format!("p{pid}/{name} n={n} total={ms:.3}ms"))
        .collect::<Vec<_>>()
        .join(", ")
}

/// The machine-readable report (pretty-printed; keys sorted by the
/// `json` module's `BTreeMap` backing).
pub fn render_json(a: &TraceAnalysis) -> String {
    let iterations: Vec<Value> = a
        .iterations
        .iter()
        .map(|p| {
            object(vec![
                ("pid", Value::Number(p.pid as f64)),
                (
                    "iteration",
                    p.iteration.map_or(Value::Null, |i| Value::Number(i as f64)),
                ),
                ("t0_ms", Value::Number(p.t0_ms)),
                ("wall_ms", Value::Number(p.wall_ms)),
                ("path_ms", Value::Number(p.path_ms())),
                ("segments", segments_value(&p.segments)),
            ])
        })
        .collect();
    let requests: Vec<Value> = a
        .requests
        .iter()
        .map(|p: &RequestPath| {
            object(vec![
                ("pid", Value::Number(p.pid as f64)),
                ("id", Value::Number(p.id as f64)),
                ("begin_ms", Value::Number(p.begin_ms)),
                ("end_ms", Value::Number(p.end_ms)),
                ("segments", segments_value(&p.segments)),
            ])
        })
        .collect();
    let flame: Vec<Value> = a
        .flame
        .iter()
        .map(|r| {
            object(vec![
                ("pid", Value::Number(r.pid as f64)),
                ("tid", Value::Number(r.tid as f64)),
                ("cat", Value::String(r.cat.clone())),
                ("name", Value::String(r.name.clone())),
                ("count", Value::Number(r.count as f64)),
                ("wall_ms", Value::Number(r.wall_ms)),
                ("self_ms", Value::Number(r.self_ms)),
            ])
        })
        .collect();
    let counters: Vec<Value> = a
        .counters
        .iter()
        .map(|c| {
            object(vec![
                ("pid", Value::Number(c.pid as f64)),
                ("tid", Value::Number(c.tid as f64)),
                ("name", Value::String(c.name.clone())),
                ("key", Value::String(c.key.clone())),
                ("n", Value::Number(c.n as f64)),
                ("min", Value::Number(c.min)),
                ("mean", Value::Number(c.mean)),
                ("max", Value::Number(c.max)),
                ("twa", Value::Number(c.twa)),
            ])
        })
        .collect();
    let verdicts: Vec<Value> = a
        .verdicts
        .iter()
        .map(|v| {
            object(vec![
                ("scope", Value::String(v.scope.clone())),
                ("verdict", Value::String(v.verdict.clone())),
                ("detail", Value::String(v.detail.clone())),
            ])
        })
        .collect();
    let doc = object(vec![
        ("iterations", Value::Array(iterations)),
        ("requests", Value::Array(requests)),
        ("flame", Value::Array(flame)),
        ("counters", Value::Array(counters)),
        ("verdicts", Value::Array(verdicts)),
    ]);
    to_string_pretty(&doc)
}

fn segments_value(segments: &[Segment]) -> Value {
    Value::Array(
        segments
            .iter()
            .map(|s| {
                object(vec![
                    ("name", Value::String(s.name.to_string())),
                    ("dur_ms", Value::Number(s.dur_ms)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::analyze::TraceAnalysis;
    use crate::trace::{TraceHandle, Track};

    fn sample() -> TraceAnalysis {
        let t = TraceHandle::recording();
        t.span(Track::master(0), "train", "iteration", 0.0, 100.0, &[]);
        t.span(Track::worker(0, 1), "train", "ingest", 40.0, 90.0, &[]);
        t.counter(Track::shard(0, 0), "serve/queue", 0.0, &[("depth", 2.0)]);
        TraceAnalysis::from_events(&t.snapshot())
    }

    #[test]
    fn text_report_is_deterministic_and_covers_sections() {
        let a = sample();
        let text = render_text(&a);
        assert_eq!(text, render_text(&a), "same analysis → identical text");
        assert!(text.contains("== trace report =="));
        assert!(text.contains("training critical paths"));
        assert!(text.contains("serve/queue"));
        assert!(text.contains("100.0%"), "full coverage by construction:\n{text}");
    }

    #[test]
    fn json_report_parses_and_round_trips_key_numbers() {
        let a = sample();
        let json = render_json(&a);
        assert_eq!(json, render_json(&a));
        let doc = crate::json::parse(&json).unwrap();
        let iters = doc.req_array("iterations").unwrap();
        assert_eq!(iters.len(), 1);
        assert_eq!(iters[0].req_f64("wall_ms").unwrap(), 100.0);
        assert_eq!(iters[0].req_f64("path_ms").unwrap(), 100.0);
        let counters = doc.req_array("counters").unwrap();
        assert_eq!(counters[0].req_str("name").unwrap(), "serve/queue");
    }
}
