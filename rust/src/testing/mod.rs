//! Property-testing substrate (proptest is unavailable offline).
//!
//! A small seeded-case harness: generate `N` random cases from a [`Pcg32`],
//! run the property, and on failure report the seed so the case can be
//! replayed exactly (`MLITB_PROP_SEED=<seed>` reruns a single case).
//! Used by the allocation-invariant and coordinator-state property tests.

use crate::rng::Pcg32;

/// Number of cases per property (override with MLITB_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("MLITB_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `n` seeded cases.  Each case gets its own PRNG forked
/// from the base seed; failures panic with the replay seed.
pub fn check(name: &str, prop: impl Fn(&mut Pcg32) -> Result<(), String>) {
    // Replay mode: single pinned case.
    if let Ok(seed) = std::env::var("MLITB_PROP_SEED") {
        let seed: u64 = seed.parse().expect("MLITB_PROP_SEED must be u64");
        let mut rng = Pcg32::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed (replay seed {seed}): {msg}");
        }
        return;
    }
    let n = default_cases();
    // Base seed derived from the property name: stable across runs, varied
    // across properties.
    let base = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
    for case in 0..n {
        let seed = base.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Pcg32::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{n}: {msg}\n\
                 replay with: MLITB_PROP_SEED={seed}"
            );
        }
    }
}

/// Generator helpers for common shapes.
pub mod gen {
    use crate::rng::Pcg32;

    /// usize in [lo, hi] inclusive.
    pub fn usize_in(rng: &mut Pcg32, lo: usize, hi: usize) -> usize {
        lo + rng.gen_range_usize(hi - lo + 1)
    }

    /// f32 vector with entries in [-1, 1].
    pub fn f32_vec(rng: &mut Pcg32, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.gen_f32() * 2.0 - 1.0).collect()
    }

    /// Random event sequence of joins/leaves/adds for allocator fuzzing.
    #[derive(Debug, Clone, Copy, PartialEq)]
    pub enum AllocEvent {
        AddData(usize),
        Join,
        Leave,
        Shed(usize),
    }

    pub fn alloc_events(rng: &mut Pcg32, n: usize) -> Vec<AllocEvent> {
        (0..n)
            .map(|_| match rng.gen_range_usize(10) {
                0..=2 => AllocEvent::AddData(usize_in(rng, 1, 500)),
                3..=6 => AllocEvent::Join,
                7..=8 => AllocEvent::Leave,
                _ => AllocEvent::Shed(usize_in(rng, 1, 100)),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("always-true", |_rng| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert!(count >= 1);
    }

    #[test]
    #[should_panic(expected = "replay with")]
    fn failing_property_reports_seed() {
        check("always-false", |_rng| Err("nope".into()));
    }

    #[test]
    fn generators_produce_in_range() {
        let mut rng = Pcg32::new(1);
        for _ in 0..100 {
            let v = gen::usize_in(&mut rng, 5, 9);
            assert!((5..=9).contains(&v));
        }
        let xs = gen::f32_vec(&mut rng, 50);
        assert_eq!(xs.len(), 50);
        assert!(xs.iter().all(|x| (-1.0..=1.0).contains(x)));
    }
}
